"""Model configuration: one schema covering all assigned architecture families.

``block_pattern`` is cycled over the layer stack (pattern-scan, DESIGN.md §3):
e.g. gemma3's 5:1 local:global is ``("local",)*5 + ("attn",)`` and
recurrentgemma's 1:2 is ``("rec", "rec", "attn")``.  Layers are stacked per
pattern position and iterated with ``lax.scan``; the remainder
(n_layers % len(pattern)) is unrolled.
"""

from __future__ import annotations

import dataclasses

from repro.core.bitlinear import QuantConfig


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int

    # layer mix
    block_pattern: tuple = ("attn",)  # attn | local | rec | ssd
    window: int = 1024                # sliding window for "local" layers
    ffn_kind: str = "dense"           # dense | moe

    # attention options
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 1_000_000.0

    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25

    # SSM (mamba2) / RG-LRU (recurrentgemma)
    d_inner: int = 0
    ssm_state: int = 0
    ssm_heads: int = 0
    conv_width: int = 4

    # encoder-decoder (seamless)
    enc_layers: int = 0
    enc_seq: int = 0                  # stub audio frontend: frames per sample

    # modality frontend stub
    frontend: str = ""                # "" | vision | audio
    frontend_tokens: int = 0

    # numerics / technique
    quant: QuantConfig = QuantConfig(mode="qat")
    kv_dtype: str = "int8"            # int8 (beyond-paper) | bf16
    attn_block: int = 1024            # online-softmax KV block
    norm_eps: float = 1e-6
    dtype: str = "float32"            # compute dtype for tests; bf16 at scale
    remat: bool = False               # activation checkpointing over blocks
    # residual-stream sharding constraint [B, S, D] (None = GSPMD decides);
    # e.g. (("pod","data"), None, "model") pins batch-DP (+ optional d_model
    # TP slice).  Requires a mesh context (jax.set_mesh) at trace time.
    act_shard: tuple = ()

    @property
    def padded_vocab(self) -> int:
        """Embedding rows padded to a 256 multiple so the vocab dim shards
        cleanly on any mesh (standard practice; pad logits are masked)."""
        return ((self.vocab + 255) // 256) * 256

    def pattern_layers(self) -> tuple[int, int]:
        """(n_scan_repeats, n_remainder_layers)."""
        p = len(self.block_pattern)
        return self.n_layers // p, self.n_layers % p

    def layer_kinds(self) -> list:
        reps, rem = self.pattern_layers()
        return list(self.block_pattern) * reps + list(self.block_pattern[:rem])

    def is_encdec(self) -> bool:
        return self.enc_layers > 0

    def is_subquadratic(self) -> bool:
        """True if attention cost is windowed / recurrent (long_500k eligible)."""
        kinds = set(self.block_pattern)
        return kinds <= {"local", "rec", "ssd"} or "attn" not in kinds or (
            "local" in kinds or "rec" in kinds or "ssd" in kinds
        )

    def with_quant(self, quant: QuantConfig) -> "ModelConfig":
        return dataclasses.replace(self, quant=quant)

    def with_plan(self, plan) -> "ModelConfig":
        """Override the mpGEMM KernelPlan."""
        return dataclasses.replace(
            self, quant=dataclasses.replace(self.quant, plan=plan))

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)
