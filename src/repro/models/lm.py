"""Model assembly: pattern-scanned layer stacks, LM / enc-dec heads, decode state.

Entry points (all pure functions over a params pytree):
  init(key, cfg)                      -> params
  forward(params, batch, cfg)         -> (logits, aux)   teacher-forced
  loss_fn(params, batch, cfg)         -> (loss, metrics)
  init_state(cfg, batch, max_seq)     -> decode caches for every layer
  prefill(params, batch, cfg, state)  -> (last_logits, state)
  decode_step(params, tok, pos, cfg, state) -> (logits, state)

Layer stacking uses pattern-scan (DESIGN.md §3): one lax.scan over
``n_layers // len(pattern)`` repeats of the (possibly heterogeneous) pattern,
remainder layers unrolled.  This keeps HLO size O(pattern) instead of
O(n_layers) — the difference between minutes and hours of XLA compile time
for the 512-chip dry-runs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import bitlinear
from repro.models import layers as L
from repro.models.config import ModelConfig

F32 = jnp.float32


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------


def block_init(key, cfg: ModelConfig, kind: str) -> dict:
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    p: dict = {"ln1": L.rms_norm_init(d)}
    if kind in ("attn", "local", "enc", "xattn"):
        p["attn"] = L.attn_init(ks[0], cfg)
    if kind == "xattn":
        p["lnx"] = L.rms_norm_init(d)
        p["xattn"] = L.attn_init(ks[1], cfg)
    if kind == "rec":
        p["mix"] = L.rglru_init(ks[0], cfg)
    if kind == "ssd":
        p["mix"] = L.ssd_init(ks[0], cfg)
    if cfg.d_ff > 0 and kind != "ssd":
        p["ln2"] = L.rms_norm_init(d)
        p["ffn"] = L.moe_init(ks[2], cfg) if cfg.ffn_kind == "moe" else L.ffn_init(ks[2], cfg)
    return p


def block_state_init(cfg: ModelConfig, kind: str, batch: int, max_seq: int):
    if kind in ("attn", "local"):
        return L.attn_state_init(cfg, kind, batch, max_seq)
    if kind == "xattn":
        st = L.attn_state_init(cfg, "attn", batch, max_seq)
        kvshape = (batch, cfg.enc_seq, cfg.n_kv_heads, cfg.d_head)
        st["ck"] = jnp.zeros(kvshape, jnp.bfloat16)
        st["cv"] = jnp.zeros(kvshape, jnp.bfloat16)
        return st
    if kind == "enc":
        return ()
    if kind == "rec":
        return L.rglru_state_init(cfg, batch)
    if kind == "ssd":
        return L.ssd_state_init(cfg, batch)
    raise ValueError(kind)


def constrain_acts(x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Pin the residual stream's sharding (needs jax.set_mesh at trace time)."""
    if cfg.act_shard:
        spec = jax.sharding.PartitionSpec(*cfg.act_shard[: x.ndim])
        return jax.lax.with_sharding_constraint(x, spec)
    return x


def block_apply(kind, p, x, cfg: ModelConfig, *, state=None, pos=None, enc_out=None,
                table=None, chunked=False):
    """Returns (x, new_state, aux).

    table/chunked flow to self-attention only: a [B, L] block table switches
    the KV cache to the paged pool layout (serving), and chunked marks S > 1
    writes as prefill chunks that must attend over the whole cache.
    """
    aux = jnp.zeros((), F32)
    x = constrain_acts(x, cfg)
    if kind in ("attn", "local", "enc", "xattn"):
        # re-constrain after the norm: its f32 internals must not become the
        # resharding point (measured 1.75 TB/device of f32 gathers otherwise)
        h = constrain_acts(L.rms_norm(p["ln1"], x, cfg.norm_eps), cfg)
        a, new_state = L.attn_apply(
            p["attn"], h, cfg, "local" if kind == "local" else "attn",
            state=state if kind != "xattn" else _self_cache(state),
            pos=pos, bidirectional=(kind == "enc"),
            table=table if kind != "xattn" else None,
            chunked=chunked if kind != "xattn" else False,
        )
        x = x + a
        if kind == "xattn":
            if state is not None:
                new_state = dict(state, **(new_state or {}))
                if enc_out is not None:  # prefill: compute & store cross kv
                    ck, cv = L.cross_kv(p["xattn"], enc_out, cfg)
                    new_state["ck"] = ck.astype(jnp.bfloat16)
                    new_state["cv"] = cv.astype(jnp.bfloat16)
                ckv = (new_state["ck"].astype(L.cdt(cfg)), new_state["cv"].astype(L.cdt(cfg)))
            else:
                ckv = L.cross_kv(p["xattn"], enc_out, cfg)
            hx = L.rms_norm(p["lnx"], x, cfg.norm_eps)
            x = x + L.cross_attn_apply(p["xattn"], hx, cfg, ckv)
    elif kind == "rec":
        h = constrain_acts(L.rms_norm(p["ln1"], x, cfg.norm_eps), cfg)
        a, new_state = L.rglru_apply(p["mix"], h, cfg, state=state, pos=pos)
        x = x + a
    elif kind == "ssd":
        h = constrain_acts(L.rms_norm(p["ln1"], x, cfg.norm_eps), cfg)
        a, new_state = L.ssd_apply(p["mix"], h, cfg, state=state, pos=pos)
        x = x + a
    else:
        raise ValueError(kind)

    if "ffn" in p:
        h = constrain_acts(L.rms_norm(p["ln2"], x, cfg.norm_eps), cfg)
        if cfg.ffn_kind == "moe":
            x = x + L.moe_apply(p["ffn"], h, cfg)
            aux = aux + L.moe_aux_loss(p["ffn"], h, cfg)
        else:
            x = x + L.ffn_apply(p["ffn"], h, cfg)
    return x, new_state, aux


def _self_cache(state):
    if state is None:
        return None
    return {k: v for k, v in state.items() if k in ("k", "v", "ks", "vs", "pos")}


# ---------------------------------------------------------------------------
# Pattern-scanned stack
# ---------------------------------------------------------------------------


def stack_init(key, cfg: ModelConfig, pattern=None, n_layers=None) -> dict:
    pattern = pattern or cfg.block_pattern
    n_layers = n_layers or cfg.n_layers
    reps, rem = n_layers // len(pattern), n_layers % len(pattern)
    scanned = []
    for i, kind in enumerate(pattern):
        keys = jax.random.split(jax.random.fold_in(key, i), max(reps, 1))
        scanned.append(jax.vmap(lambda k: block_init(k, cfg, kind))(keys) if reps else None)
    rest = [
        block_init(jax.random.fold_in(key, 10_000 + i), cfg, pattern[i])
        for i in range(rem)
    ]
    return {"scan": tuple(scanned), "rest": rest}


def stack_state_init(cfg: ModelConfig, batch: int, max_seq: int, pattern=None, n_layers=None):
    pattern = pattern or cfg.block_pattern
    n_layers = n_layers or cfg.n_layers
    reps, rem = n_layers // len(pattern), n_layers % len(pattern)

    def stacked(kind):
        one = block_state_init(cfg, kind, batch, max_seq)
        return jax.tree_util.tree_map(lambda a: jnp.broadcast_to(a, (reps,) + a.shape), one)

    scan_states = tuple(stacked(k) for k in pattern) if reps else tuple(None for _ in pattern)
    rest_states = [block_state_init(cfg, pattern[i], batch, max_seq) for i in range(rem)]
    return {"scan": scan_states, "rest": rest_states}


def stack_apply(params, x, cfg: ModelConfig, *, states=None, pos=None,
                enc_out=None, pattern=None, table=None, chunked=False):
    pattern = pattern or cfg.block_pattern
    reps = None
    for s in params["scan"]:
        if s is not None:
            reps = jax.tree_util.tree_leaves(s)[0].shape[0]
    new_scan_states = None

    if reps:
        if states is None:
            def body(carry, xs):
                x, aux = carry
                for i, kind in enumerate(pattern):
                    x, _, a = block_apply(kind, xs[i], x, cfg, enc_out=enc_out)
                    aux = aux + a
                return (x, aux), None

            if cfg.remat:
                body = jax.checkpoint(body)
            (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), F32)), params["scan"])
        else:
            def body(carry, xs):
                x, aux = carry
                ps, ss = xs
                new_ss = []
                for i, kind in enumerate(pattern):
                    x, ns, a = block_apply(kind, ps[i], x, cfg, state=ss[i],
                                           pos=pos, enc_out=enc_out,
                                           table=table, chunked=chunked)
                    aux = aux + a
                    new_ss.append(ns)
                return (x, aux), tuple(new_ss)

            (x, aux), new_scan_states = jax.lax.scan(
                body, (x, jnp.zeros((), F32)), (params["scan"], states["scan"])
            )
    else:
        aux = jnp.zeros((), F32)

    new_rest = []
    for i, p in enumerate(params["rest"]):
        kind = pattern[i]
        st = states["rest"][i] if states is not None else None
        x, ns, a = block_apply(kind, p, x, cfg, state=st, pos=pos, enc_out=enc_out,
                               table=table, chunked=chunked)
        aux = aux + a
        new_rest.append(ns)

    new_states = None
    if states is not None:
        new_states = {"scan": new_scan_states, "rest": new_rest}
    return x, new_states, aux


# ---------------------------------------------------------------------------
# Full model
# ---------------------------------------------------------------------------


def init(key, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 5)
    params = {
        "emb": jax.random.normal(ks[0], (cfg.padded_vocab, cfg.d_model), F32) * 0.02,
        "ln_f": L.rms_norm_init(cfg.d_model),
        "stack": stack_init(ks[1], cfg),
    }
    if cfg.is_encdec():
        params["enc_stack"] = stack_init(ks[2], cfg, pattern=("enc",), n_layers=cfg.enc_layers)
        params["enc_ln_f"] = L.rms_norm_init(cfg.d_model)
        # decoder layers are self+cross
        params["stack"] = stack_init(ks[1], cfg, pattern=("xattn",), n_layers=cfg.n_layers)
    return params


def _embed(params, tokens, cfg: ModelConfig, frontend_emb=None):
    # cast the (vocab-sharded) table before the gather: the [B, S, D] result
    # materializes in compute dtype, not f32
    x = params["emb"].astype(jnp.dtype(cfg.dtype))[tokens]
    if frontend_emb is not None:
        x = jnp.concatenate([frontend_emb.astype(x.dtype), x], axis=1)
    return x


def _head(params, x, cfg: ModelConfig):
    x = L.rms_norm(params["ln_f"], x, cfg.norm_eps)
    logits = jax.lax.dot_general(
        x, params["emb"].astype(x.dtype),
        (((x.ndim - 1,), (1,)), ((), ())),
        preferred_element_type=F32,
    )  # tied head; vocab padded to a 256 multiple for sharding
    if cfg.padded_vocab != cfg.vocab:
        pad_mask = jnp.arange(cfg.padded_vocab) < cfg.vocab
        logits = jnp.where(pad_mask, logits, -1e30)
    if cfg.act_shard:
        spec = jax.sharding.PartitionSpec(cfg.act_shard[0], None, "model")
        logits = jax.lax.with_sharding_constraint(logits, spec)
    return logits


def encode(params, enc_emb, cfg: ModelConfig):
    """Encoder pass (seamless): stub frontend embeddings -> memory."""
    x = enc_emb.astype(jnp.dtype(cfg.dtype))
    x, _, _ = stack_apply(params["enc_stack"], x, cfg, pattern=("enc",))
    return L.rms_norm(params["enc_ln_f"], x, cfg.norm_eps)


def forward(params, batch: dict, cfg: ModelConfig):
    """Teacher-forced forward. batch: tokens [B,S] (+ frontend_emb / enc_emb)."""
    enc_out = None
    if cfg.is_encdec():
        enc_out = encode(params, batch["enc_emb"], cfg)
        x = _embed(params, batch["tokens"], cfg)
        x, _, aux = stack_apply(params["stack"], x, cfg, enc_out=enc_out, pattern=("xattn",))
    else:
        x = _embed(params, batch["tokens"], cfg, batch.get("frontend_emb"))
        x, _, aux = stack_apply(params["stack"], x, cfg)
    return _head(params, x, cfg), aux


def loss_fn(params, batch: dict, cfg: ModelConfig):
    logits, aux = forward(params, batch, cfg)
    labels = batch["labels"]
    n_front = logits.shape[1] - labels.shape[1]
    if n_front:
        logits = logits[:, n_front:]
    lp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(lp, labels[..., None], axis=-1)[..., 0]
    mask = batch.get("mask", jnp.ones_like(labels, F32))
    loss = (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    total = loss + 0.01 * aux
    return total, {"nll": loss, "moe_aux": aux}


def init_state(cfg: ModelConfig, batch: int, max_seq: int):
    pattern = ("xattn",) if cfg.is_encdec() else None
    return stack_state_init(cfg, batch, max_seq, pattern=pattern)


def init_paged_state(cfg: ModelConfig, batch: int, num_blocks: int,
                     block_size: int):
    """Decode state with PAGED attention caches (serving, DESIGN.md §7).

    Attention layers get a batch-free block pool [num_blocks + 1, block_size,
    ...] shared by every slot (the +1 is the trash block); indirection happens
    through the [batch, L] block table passed to :func:`decode_step` /
    :func:`prefill_chunk`.  Recurrent / conv states stay per-slot (they are
    O(d_inner), not O(seq) — nothing to page).
    """
    if cfg.is_encdec():
        raise ValueError("paged serving supports decoder-only self-attention "
                         "stacks (enc-dec cross caches are per-request dense)")
    pattern = cfg.block_pattern
    reps, rem = cfg.pattern_layers()

    def one(kind):
        if kind in ("attn", "local"):
            return L.paged_attn_state_init(cfg, num_blocks, block_size)
        return block_state_init(cfg, kind, batch, max_seq=block_size)

    def stacked(kind):
        st = one(kind)
        return jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a, (reps,) + a.shape), st)

    scan = tuple(stacked(k) for k in pattern) if reps else tuple(None for _ in pattern)
    rest = [one(pattern[i]) for i in range(rem)]
    return {"scan": scan, "rest": rest}


def prefill(params, batch: dict, cfg: ModelConfig, state):
    """Fill caches from a prompt; returns (last-position logits, state)."""
    enc_out = None
    pattern = None
    if cfg.is_encdec():
        enc_out = encode(params, batch["enc_emb"], cfg)
        pattern = ("xattn",)
        x = _embed(params, batch["tokens"], cfg)
    else:
        x = _embed(params, batch["tokens"], cfg, batch.get("frontend_emb"))
    x, state, _ = stack_apply(params["stack"], x, cfg, states=state, pos=0,
                              enc_out=enc_out, pattern=pattern)
    return _head(params, x[:, -1:], cfg), state


def decode_step(params, tok: jax.Array, pos: jax.Array, cfg: ModelConfig, state,
                *, table=None):
    """One token [B, 1] at absolute position pos -> (logits [B,1,V], state).

    With ``table`` [B, L] the attention caches are paged block pools
    (init_paged_state) and reads/writes go through the block-gather path.
    """
    pattern = ("xattn",) if cfg.is_encdec() else None
    x = _embed(params, tok, cfg)
    x, state, _ = stack_apply(params["stack"], x, cfg, states=state, pos=pos,
                              pattern=pattern, table=table)
    return _head(params, x, cfg), state


def prefill_chunk(params, tok: jax.Array, pos: jax.Array, cfg: ModelConfig,
                  state, *, table=None):
    """Consume a prompt CHUNK [B, C] starting at absolute position ``pos``.

    Unlike :func:`prefill` (whole prompt, fresh-KV attention) this attends
    over the cache itself, so chunk N sees chunks 0..N−1; the returned logits
    are for the chunk's LAST position only ([B, 1, V]).  C > 1 flattens to
    batch N = C in the mpGEMM dispatch — chunks ride the GEMM (MAD/MXU)
    regime while single-token decode keeps the GEMV regime (DESIGN.md §5/§7).
    """
    if cfg.is_encdec():
        raise ValueError("chunked prefill supports decoder-only stacks")
    x = _embed(params, tok, cfg)
    x, state, _ = stack_apply(params["stack"], x, cfg, states=state, pos=pos,
                              table=table, chunked=True)
    return _head(params, x[:, -1:], cfg), state


def prefill_chunk_batched(params, tok: jax.Array, pos: jax.Array,
                          cfg: ModelConfig, state, *, table=None):
    """Consume prompt chunks for S sequences AT ONCE: tok [S, C], pos [S, C].

    The batched-concurrent-prefill core (DESIGN.md §7): the S chunks flatten
    to one mpGEMM batch N = S·C — one GEMM-regime call and one dispatch
    decision replace S per-slot calls at N = C.  ``pos`` is an explicit
    per-token position matrix; entries < 0 are masked padding (whole padding
    rows, or the right-padded tail of a short final chunk): they write only
    to the trash slot/block, are invisible to attention, and are identity
    steps for recurrent (RG-LRU / SSD) state and conv history.  Returns
    logits at each row's LAST VALID position ([S, 1, V]) plus the state —
    padding rows return garbage logits the caller must ignore.
    """
    if cfg.is_encdec():
        raise ValueError("chunked prefill supports decoder-only stacks")
    x = _embed(params, tok, cfg)
    x, state, _ = stack_apply(params["stack"], x, cfg, states=state, pos=pos,
                              table=table, chunked=True)
    n_valid = jnp.sum((pos >= 0).astype(jnp.int32), axis=1)
    last = jnp.maximum(n_valid - 1, 0)[:, None, None]            # [S, 1, 1]
    return _head(params, jnp.take_along_axis(x, last, axis=1), cfg), state


def verify_chunk_batched(params, tok: jax.Array, pos: jax.Array,
                         cfg: ModelConfig, state, *, table=None):
    """Score W positions per sequence in ONE call: tok [B, W], pos [B, W]
    → (logits [B, W, V], state).  The speculative-verify forward
    (DESIGN.md §10).

    Identical cache semantics to :func:`prefill_chunk_batched` — chunked
    attention over the whole cache including this call's own writes, and
    ``pos`` entries < 0 are masked padding (trash-slot writes, invisible to
    attention) — but the head runs over EVERY position, not just the last
    valid one: the engine needs the target's next-token distribution at all
    k+1 verify positions to longest-prefix-match the k drafted tokens and
    mint the bonus token from the same call.  W > 1 flattens to mpGEMM
    batch N = B·W, so verification rides the GEMM/MAD regime while the
    drafting it replaces would have been W single-token GEMV-regime steps.
    Logits at padded positions are garbage the caller must ignore.
    """
    if cfg.is_encdec():
        raise ValueError("speculative verify supports decoder-only stacks")
    x = _embed(params, tok, cfg)
    x, state, _ = stack_apply(params["stack"], x, cfg, states=state, pos=pos,
                              table=table, chunked=True)
    return _head(params, x, cfg), state


def pack(params, cfg: ModelConfig):
    """Quantize+pack every BitLinear for inference (the paper's convert step)."""
    return bitlinear.pack_tree(params, cfg.quant)


def param_count(params) -> int:
    leaves = jax.tree_util.tree_leaves(params)
    return sum(l.size for l in leaves if hasattr(l, "size"))
