"""Layer zoo: attention (GQA / local / cross), SwiGLU FFN, MoE, RG-LRU, SSD.

Every projection is a BitLinear (the paper's technique); norms, gates,
routers and recurrence parameters stay fp32 (DESIGN.md §4).  All blocks share
one calling convention so the pattern-scan stacker can mix kinds:

    y, new_state = block_apply(kind, params, x, cfg, state=..., pos=...)

state=None → stateless full-sequence forward (training);
state=empty cache, pos=0 → prefill (fills the cache);
state=cache, pos=t, x of seq-len 1 → one decode step.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import bitlinear
from repro.core.bitlinear import BitLinearParams
from repro.models.config import ModelConfig

F32 = jnp.float32
NEG = -1e30


def cdt(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# Norms & RoPE
# ---------------------------------------------------------------------------


def rms_norm_init(d: int):
    return {"w": jnp.ones((d,), F32)}


def rms_norm(p, x, eps: float):
    xf = x.astype(F32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * p["w"]).astype(x.dtype)


def rope(x: jax.Array, pos: jax.Array, theta: float) -> jax.Array:
    """x: [B, S, H, dh]; pos: [B, S] int32 absolute positions (per sequence)."""
    dh = x.shape[-1]
    half = dh // 2
    freq = theta ** (-jnp.arange(half, dtype=F32) / half)
    ang = pos.astype(F32)[..., None] * freq                    # [B, S, half]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half].astype(F32), x[..., half:].astype(F32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# KV cache (int8-quantized option — beyond-paper: ternary weights make decode
# KV-traffic-dominated, so the cache gets the same bits-per-byte treatment)
# ---------------------------------------------------------------------------


def _cache_len(cfg: ModelConfig, kind: str, max_seq: int) -> int:
    if kind == "local":
        return min(cfg.window, max_seq)  # ring buffer: local layers never
        # need more than `window` slots (what makes gemma3 long_500k fit)
    return max_seq


def attn_state_init(cfg: ModelConfig, kind: str, batch: int, max_seq: int) -> dict:
    # +1 trash slot: writes for paused sequences (position < 0) land there and
    # are excluded from reads by the pos >= 0 mask — lets one jitted decode
    # step serve continuous-batching slots in different phases.
    # Length padded to a 256 multiple so the sequence dim shards cleanly on
    # any mesh axis (ring modulus is unchanged; pad slots stay pos=-1).
    w = -(-(_cache_len(cfg, kind, max_seq) + 1) // 256) * 256
    kvh, dh = cfg.n_kv_heads, cfg.d_head
    if cfg.kv_dtype == "int8":
        z = jnp.zeros((batch, w, kvh, dh), jnp.int8)
        s = jnp.zeros((batch, w, kvh), F32)
        cache = {"k": z, "v": z, "ks": s, "vs": s}
    else:
        z = jnp.zeros((batch, w, kvh, dh), jnp.bfloat16)
        cache = {"k": z, "v": z}
    cache["pos"] = jnp.full((batch, w), -1, jnp.int32)  # absolute pos per slot
    return cache


def _kv_quant(v: jax.Array) -> tuple[jax.Array, jax.Array]:
    """[B, S, KV, dh] fp -> (int8, per-[B,S,KV] scale)."""
    s = jnp.maximum(jnp.max(jnp.abs(v.astype(F32)), axis=-1), 1e-6) / 127.0
    q = jnp.clip(jnp.round(v.astype(F32) / s[..., None]), -127, 127)
    return q.astype(jnp.int8), s


def _cache_write(cache: dict, k, v, positions: jax.Array, kind: str, cfg: ModelConfig) -> dict:
    """Write S new kv rows at per-sequence positions [B, S] (ring for local).

    Negative positions (paused continuous-batching slots) write to the trash
    slot (index w) and record pos = -1 → invisible to attention.
    """
    b, wp1 = cache["k"].shape[:2]
    w = wp1 - 1
    s = k.shape[1]
    if s > w:  # ring buffer shorter than the write: only the tail survives
        k, v = k[:, -w:], v[:, -w:]
        positions = positions[:, -w:]
        s = w
    active = positions >= 0
    slots = jnp.where(active, positions % w, w)             # [B, S]
    positions = jnp.where(active, positions, -1)
    bi = jnp.arange(b)[:, None]
    out = dict(cache)
    if "ks" in cache:
        kq, ks = _kv_quant(k)
        vq, vs = _kv_quant(v)
        out["k"] = cache["k"].at[bi, slots].set(kq)
        out["v"] = cache["v"].at[bi, slots].set(vq)
        out["ks"] = cache["ks"].at[bi, slots].set(ks)
        out["vs"] = cache["vs"].at[bi, slots].set(vs)
    else:
        out["k"] = cache["k"].at[bi, slots].set(k.astype(cache["k"].dtype))
        out["v"] = cache["v"].at[bi, slots].set(v.astype(cache["v"].dtype))
    out["pos"] = cache["pos"].at[bi, slots].set(positions)
    return out


def _cache_read(cache: dict, dtype) -> tuple[jax.Array, jax.Array, jax.Array]:
    if "ks" in cache:
        k = cache["k"].astype(dtype) * cache["ks"][..., None].astype(dtype)
        v = cache["v"].astype(dtype) * cache["vs"][..., None].astype(dtype)
    else:
        k, v = cache["k"].astype(dtype), cache["v"].astype(dtype)
    return k, v, cache["pos"]  # pos: [B, W]


def _cache_read_raw(cache: dict):
    """Raw cache + scales for block-local dequant: (k, v, ks, vs, pos).
    ks/vs are None for the bf16 cache."""
    return (cache["k"], cache["v"], cache.get("ks"), cache.get("vs"),
            cache["pos"])


# ---------------------------------------------------------------------------
# Paged KV cache (serving subsystem, DESIGN.md §7): one pool of fixed-size
# blocks shared by every sequence, indirected through a per-sequence block
# table.  Pools have NO batch dim — the table is the only per-slot state.
# ---------------------------------------------------------------------------


def paged_attn_state_init(cfg: ModelConfig, num_blocks: int, block_size: int) -> dict:
    """Block pool for one attention layer: [num_blocks + 1, block_size, ...].

    The last block is the trash block: paused slots (pos < 0) and unallocated
    table entries land there; its pos rows stay −1 so reads always mask it.
    Unlike the dense ring cache, local (windowed) layers allocate full-length
    logical ranges — the window is enforced by the attention mask, and block
    frees for out-of-window history are a scheduler policy, not a layout one.
    """
    nb1 = num_blocks + 1
    kvh, dh = cfg.n_kv_heads, cfg.d_head
    if cfg.kv_dtype == "int8":
        z = jnp.zeros((nb1, block_size, kvh, dh), jnp.int8)
        s = jnp.zeros((nb1, block_size, kvh), F32)
        cache = {"k": z, "v": z, "ks": s, "vs": s}
    else:
        z = jnp.zeros((nb1, block_size, kvh, dh), jnp.bfloat16)
        cache = {"k": z, "v": z}
    cache["pos"] = jnp.full((nb1, block_size), -1, jnp.int32)
    return cache


def _paged_cache_write(cache: dict, k, v, positions: jax.Array,
                       table: jax.Array) -> dict:
    """Scatter S new kv rows through the block table.

    k/v: [B, S, KV, dh]; positions: [B, S] absolute (−1 → trash block);
    table: [B, L] physical block ids (unallocated entries point at trash).
    """
    nb1, bs = cache["k"].shape[:2]
    trash = nb1 - 1
    active = positions >= 0
    lblk = jnp.minimum(jnp.maximum(positions, 0) // bs, table.shape[1] - 1)
    phys = jnp.take_along_axis(table, lblk, axis=1)             # [B, S]
    phys = jnp.where(active, phys, trash)
    off = jnp.where(active, positions % bs, 0)
    pos_w = jnp.where(active, positions, -1)
    out = dict(cache)
    if "ks" in cache:
        kq, ks = _kv_quant(k)
        vq, vs = _kv_quant(v)
        out["k"] = cache["k"].at[phys, off].set(kq)
        out["v"] = cache["v"].at[phys, off].set(vq)
        out["ks"] = cache["ks"].at[phys, off].set(ks)
        out["vs"] = cache["vs"].at[phys, off].set(vs)
    else:
        out["k"] = cache["k"].at[phys, off].set(k.astype(cache["k"].dtype))
        out["v"] = cache["v"].at[phys, off].set(v.astype(cache["v"].dtype))
    out["pos"] = cache["pos"].at[phys, off].set(pos_w)
    return out


def _paged_read_raw(cache: dict, table: jax.Array):
    """Block-gather the pool into per-sequence [B, L·bs, ...] views.

    Gather order is LOGICAL block order, so the result is position-ordered
    regardless of physical block placement — downstream attention is
    identical to the dense layout (same (k, v, ks, vs, pos) contract).
    """
    b, l = table.shape
    bs = cache["k"].shape[1]

    def gather(a):
        g = a[table]                                            # [B, L, bs, ...]
        return g.reshape((b, l * bs) + a.shape[2:])

    ks = gather(cache["ks"]) if "ks" in cache else None
    vs = gather(cache["vs"]) if "ks" in cache else None
    return gather(cache["k"]), gather(cache["v"]), ks, vs, gather(cache["pos"])


# ---------------------------------------------------------------------------
# Attention core: online-softmax blockwise (prefill/train) + cached decode
# ---------------------------------------------------------------------------


def blockwise_attention(
    q: jax.Array,       # [B, H, Sq, dh]
    k: jax.Array,       # [B, KV, Skv, dh]  (fp, or int8 with k_scale)
    v: jax.Array,
    *,
    q_pos: jax.Array,   # [B, Sq] absolute positions (or None → bidirectional)
    k_pos: jax.Array,   # [B, Skv]
    causal: bool,
    window: int | None,
    block_k: int,
    k_scale: jax.Array | None = None,  # [B, KV, Skv] int8-KV dequant scales
    v_scale: jax.Array | None = None,
) -> jax.Array:
    """FlashAttention-style online softmax over KV blocks: O(Sq·block) memory.

    Required for the 32k-prefill and 500k shapes — the naive [Sq, Skv] score
    tensor would be hundreds of GiB at those sizes.  Positions are per
    sequence ([B, ...]) so continuous-batching decode (slots at different
    positions) shares one step function.
    """
    b, h, sq, dh = q.shape
    kvh = k.shape[1]
    g = h // kvh
    scale = 1.0 / math.sqrt(dh)
    qf = q.reshape(b, kvh, g, sq, dh).astype(F32)

    bk = min(block_k, k.shape[2])
    nb = k.shape[2] // bk
    rem = k.shape[2] - nb * bk

    def attend(carry, kb, vb, kpb, ksb=None, vsb=None):
        # kb/vb: [B, KV, bk, dh]; kpb: [B, bk]; ksb/vsb: [B, KV, bk]
        # Block-local int8-KV dequant (perf iteration q3-1, EXPERIMENTS §Perf):
        # only a [bk]-sized f32 tile ever materializes — the full-cache f32
        # copy cost 4× the cache bytes per decode step AND forced GSPMD
        # reshards of cache-sized tensors when kv_heads ∤ model axis.
        m, l, acc = carry
        kf = kb.astype(F32) * ksb[..., None] if ksb is not None else kb.astype(F32)
        vf = vb.astype(F32) * vsb[..., None] if vsb is not None else vb.astype(F32)
        s = jnp.einsum("bkgsd,bktd->bkgst", qf, kf) * scale
        if q_pos is not None:
            mask = kpb[:, None, :] >= 0
            if causal:
                mask = mask & (kpb[:, None, :] <= q_pos[:, :, None])
            if window is not None:
                mask = mask & (q_pos[:, :, None] - kpb[:, None, :] < window)
            mask = mask[:, None, None]                     # [B,1,1,Sq,bk]
        else:
            mask = jnp.ones((1, 1, 1, 1, 1), bool)
        s = jnp.where(mask, s, NEG)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.where(mask, jnp.exp(s - m_new[..., None]), 0.0)
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bkgst,bktd->bkgsd", p, vf
        )
        return m_new, l_new, acc_new

    init = (
        jnp.full((b, kvh, g, sq), NEG, F32),
        jnp.zeros((b, kvh, g, sq), F32),
        jnp.zeros((b, kvh, g, sq, dh), F32),
    )
    zp = jnp.zeros((b, bk), jnp.int32)
    has_scale = k_scale is not None
    if nb > 0:
        kb_s = jnp.moveaxis(k[:, :, : nb * bk].reshape(b, kvh, nb, bk, dh), 2, 0)
        vb_s = jnp.moveaxis(v[:, :, : nb * bk].reshape(b, kvh, nb, bk, dh), 2, 0)
        kp_s = (
            jnp.moveaxis(k_pos[:, : nb * bk].reshape(b, nb, bk), 1, 0)
            if q_pos is not None
            else jnp.zeros((nb, b, bk), jnp.int32)
        )
        xs = (kb_s, vb_s, kp_s)
        if has_scale:
            xs = xs + (
                jnp.moveaxis(k_scale[:, :, : nb * bk].reshape(b, kvh, nb, bk), 2, 0),
                jnp.moveaxis(v_scale[:, :, : nb * bk].reshape(b, kvh, nb, bk), 2, 0),
            )

        # remat the block body: backward recomputes per-block probabilities
        # instead of saving [Sq, Skv]-worth of them — this is what keeps the
        # flash-attention memory bound in training too.
        @jax.checkpoint
        def body(c, xs):
            return attend(c, *xs), None

        init, _ = jax.lax.scan(body, init, xs)
    if rem:
        init = attend(
            init,
            k[:, :, nb * bk:],
            v[:, :, nb * bk:],
            k_pos[:, nb * bk:] if q_pos is not None else zp[:, :rem],
            k_scale[:, :, nb * bk:] if has_scale else None,
            v_scale[:, :, nb * bk:] if has_scale else None,
        )
    m, l, acc = init
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, h, sq, dh)


# ---------------------------------------------------------------------------
# Attention block
# ---------------------------------------------------------------------------


def attn_init(key, cfg: ModelConfig, cross: bool = False) -> dict:
    ks = jax.random.split(key, 6)
    d, h, kvh, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    p = {
        "q": bitlinear.init(ks[0], d, h * dh, bias=cfg.qkv_bias),
        "k": bitlinear.init(ks[1], d, kvh * dh, bias=cfg.qkv_bias),
        "v": bitlinear.init(ks[2], d, kvh * dh, bias=cfg.qkv_bias),
        "o": bitlinear.init(ks[3], h * dh, d),
    }
    if cfg.qk_norm:
        p["qn"] = rms_norm_init(dh)
        p["kn"] = rms_norm_init(dh)
    return p


def _project_qkv(p, x, xkv, cfg: ModelConfig):
    b, s, _ = x.shape
    skv = xkv.shape[1]
    q = bitlinear.apply(p["q"], x, cfg.quant).reshape(b, s, cfg.n_heads, cfg.d_head)
    k = bitlinear.apply(p["k"], xkv, cfg.quant).reshape(b, skv, cfg.n_kv_heads, cfg.d_head)
    v = bitlinear.apply(p["v"], xkv, cfg.quant).reshape(b, skv, cfg.n_kv_heads, cfg.d_head)
    if cfg.qk_norm:
        q = rms_norm(p["qn"], q, cfg.norm_eps)
        k = rms_norm(p["kn"], k, cfg.norm_eps)
    return q, k, v


def attn_apply(
    p: dict,
    x: jax.Array,
    cfg: ModelConfig,
    kind: str,
    *,
    state: dict | None = None,
    pos: jax.Array | None = None,
    bidirectional: bool = False,
    table: jax.Array | None = None,
    chunked: bool = False,
):
    """Self-attention ('attn' global causal, 'local' windowed, encoder bidi).

    pos: None (train, 0-based), scalar (prefill / lockstep decode), [B]
    (continuous-batching decode with per-slot positions), or [B, S] (an
    explicit per-token position matrix — batched concurrent prefill, where
    pos = −1 marks masked padding tokens that must neither be cached nor
    attended).
    table: [B, L] block table → the cache is a paged pool (serving).
    chunked: S > 1 writes are a prefill CHUNK — attend over the whole cache
    (which already contains earlier chunks), not just the fresh k/v.
    """
    b, s, _ = x.shape
    window = cfg.window if kind == "local" else None
    pos0 = jnp.asarray(0 if pos is None else pos, jnp.int32)
    if pos0.ndim == 2:
        positions = pos0                                          # [B, S]
    else:
        if pos0.ndim == 0:
            pos0 = jnp.broadcast_to(pos0, (b,))
        positions = pos0[:, None] + jnp.arange(s, dtype=jnp.int32)[None]

    q, k, v = _project_qkv(p, x, x, cfg)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)

    new_state = state
    if state is not None:
        if table is not None:
            new_state = _paged_cache_write(state, k, v, positions, table)
        else:
            new_state = _cache_write(state, k, v, positions, kind, cfg)
        if s == 1:  # decode: attend over the cache
            # Direct (non-scan) attention: one einsum over the cache length.
            # Unlike the KV-block scan this partitions cleanly when the cache
            # seq dim is sharded (perf iteration q-2: the scan's reshape +
            # moveaxis forced GSPMD to all-gather the whole stacked cache —
            # 19.3 GB/device/step on qwen3 decode_32k).
            kc, vc, ks, vs, kp = (
                _paged_read_raw(new_state, table) if table is not None
                else _cache_read_raw(new_state))
            out = _decode_attention(q, kc, vc, ks, vs, kp, positions, window)
            return _attn_out(p, out, cfg, b, s), new_state
        if table is not None or chunked:
            # Chunked prefill: earlier chunks live only in the cache, so the
            # chunk queries blockwise-attend over the (quantized) cache —
            # which also matches token-by-token prefill numerics exactly:
            # both read every key, including a token's own, post-quant.
            kc, vc, ks, vs, kp = (
                _paged_read_raw(new_state, table) if table is not None
                else _cache_read_raw(new_state))
            out = blockwise_attention(
                jnp.swapaxes(q, 1, 2), jnp.swapaxes(kc, 1, 2),
                jnp.swapaxes(vc, 1, 2),
                q_pos=positions, k_pos=kp, causal=True, window=window,
                block_k=cfg.attn_block,
                k_scale=None if ks is None else jnp.swapaxes(ks, 1, 2),
                v_scale=None if vs is None else jnp.swapaxes(vs, 1, 2),
            )
            return _attn_out(p, out, cfg, b, s), new_state
    out = blockwise_attention(
        jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2),
        q_pos=None if bidirectional else positions,
        k_pos=positions, causal=not bidirectional, window=window,
        block_k=cfg.attn_block,
    )
    return _attn_out(p, out, cfg, b, s), new_state


def _decode_attention(q, kc, vc, ks, vs, kp, positions, window):
    """One-token attention over the whole cache, GSPMD-partition-friendly.

    q: [B, 1, H, dh]; kc/vc: [B, S, KV, dh] (int8 or bf16); ks/vs: [B, S, KV]
    scales or None; kp: [B, S] absolute positions; positions: [B, 1].
    Every op is elementwise or a contraction over dh / S — a seq- or
    kv-head-sharded cache partitions into local partials + one tiny
    all-reduce (softmax max/sum and the [B, H, dh] output).
    """
    b, _, h, dh = q.shape
    kvh = kc.shape[2]
    g = h // kvh
    scale = 1.0 / math.sqrt(dh)
    # convert the int8 cache to the COMPUTE dtype (bf16 at scale), not f32:
    # the converted operand is the dominant decode HBM traffic (perf
    # iteration q-3: 2 B instead of 4 B per cached element; accumulation
    # stays f32 via preferred_element_type)
    ct = q.dtype
    qf = q.reshape(b, kvh, g, dh)
    s = jnp.einsum("bkgd,bskd->bkgs", qf, kc.astype(ct),
                   preferred_element_type=F32) * scale              # [B,KV,G,S]
    if ks is not None:
        s = s * jnp.moveaxis(ks.astype(F32), 1, 2)[:, :, None, :]
    mask = (kp >= 0) & (kp <= positions)                            # [B, S]
    if window is not None:
        mask = mask & (positions - kp < window)
    mask = mask[:, None, None, :]
    s = jnp.where(mask, s, NEG)
    m = s.max(axis=-1, keepdims=True)
    p = jnp.where(mask, jnp.exp(s - m), 0.0)
    l = jnp.maximum(p.sum(axis=-1, keepdims=True), 1e-30)
    p = p / l
    if vs is not None:
        p = p * jnp.moveaxis(vs.astype(F32), 1, 2)[:, :, None, :]
    out = jnp.einsum("bkgs,bskd->bkgd", p.astype(ct), vc.astype(ct),
                     preferred_element_type=F32)
    return out.reshape(b, h, 1, dh)


def _attn_out(p, out, cfg, b, s):
    out = jnp.swapaxes(out, 1, 2).reshape(b, s, cfg.n_heads * cfg.d_head)
    return bitlinear.apply(p["o"], out.astype(cdt(cfg)), cfg.quant)


def cross_attn_apply(p, x, cfg: ModelConfig, enc_kv: tuple):
    """Decoder cross-attention to precomputed encoder (k, v)."""
    b, s, _ = x.shape
    q = bitlinear.apply(p["q"], x, cfg.quant).reshape(b, s, cfg.n_heads, cfg.d_head)
    if cfg.qk_norm:
        q = rms_norm(p["qn"], q, cfg.norm_eps)
    k, v = enc_kv
    out = blockwise_attention(
        jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2),
        q_pos=None, k_pos=jnp.arange(k.shape[1]), causal=False, window=None,
        block_k=cfg.attn_block,
    )
    return _attn_out(p, out, cfg, b, s)


def cross_kv(p, enc_out: jax.Array, cfg: ModelConfig):
    b, s, _ = enc_out.shape
    k = bitlinear.apply(p["k"], enc_out, cfg.quant).reshape(b, s, cfg.n_kv_heads, cfg.d_head)
    v = bitlinear.apply(p["v"], enc_out, cfg.quant).reshape(b, s, cfg.n_kv_heads, cfg.d_head)
    if cfg.qk_norm:
        k = rms_norm(p["kn"], k, cfg.norm_eps)
    return k, v


# ---------------------------------------------------------------------------
# FFN: SwiGLU (dense) and MoE
# ---------------------------------------------------------------------------


def ffn_init(key, cfg: ModelConfig, d_ff: int | None = None) -> dict:
    ks = jax.random.split(key, 3)
    d, f = cfg.d_model, d_ff or cfg.d_ff
    return {
        "gate": bitlinear.init(ks[0], d, f),
        "up": bitlinear.init(ks[1], d, f),
        "down": bitlinear.init(ks[2], f, d),
    }


def ffn_apply(p, x, cfg: ModelConfig):
    g = bitlinear.apply(p["gate"], x, cfg.quant)
    u = bitlinear.apply(p["up"], x, cfg.quant)
    return bitlinear.apply(p["down"], (jax.nn.silu(g.astype(F32)) * u.astype(F32)).astype(x.dtype), cfg.quant)


def moe_init(key, cfg: ModelConfig) -> dict:
    kr, ke = jax.random.split(key)
    # Router stays fp32 (tiny, accuracy-critical — DESIGN.md §4).
    router = jax.random.normal(kr, (cfg.n_experts, cfg.d_model), F32) * 0.02
    expert_keys = jax.random.split(ke, cfg.n_experts)
    experts = jax.vmap(lambda k: ffn_init(k, cfg))(expert_keys)
    return {"router": router, "experts": experts}


def moe_apply(p, x, cfg: ModelConfig):
    """Token-choice top-k with per-expert capacity (dropped-token semantics).

    Dispatch is a single scatter into [E, C, D] buffers (EP shards E on the
    model axis → the scatter/gather lower to all-to-alls), expert FFNs run
    vmapped over stacked BitLinear params.
    """
    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_experts, cfg.top_k
    xf = x.reshape(t, d)

    logits = xf.astype(F32) @ p["router"].T                    # [T, E]
    gates = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(gates, k)                       # [T, k]
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)

    cap = int(math.ceil(t * k / e * cfg.capacity_factor))
    flat_e = topi.reshape(-1)                                  # [T·k]
    flat_g = topv.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(t), k)

    # Sort-based dispatch ranks (perf iteration l4-1, EXPERIMENTS §Perf):
    # the one-hot cumsum costs O(T·k·E) flops and a [T·k, E] int32 buffer
    # (0.5 GB/device at llama4 train_4k scale); an argsort + searchsorted
    # computes identical ranks in O(T·k·log(T·k)).
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    first = jnp.searchsorted(sorted_e, jnp.arange(e))          # [E]
    rank_sorted = jnp.arange(t * k) - first[sorted_e]
    rank = jnp.zeros((t * k,), jnp.int32).at[order].set(rank_sorted)
    keep = rank < cap
    slot = jnp.where(keep, rank, cap)                          # cap → dropped

    buf = jnp.zeros((e, cap + 1, d), x.dtype)
    buf = buf.at[flat_e, slot].add(xf[flat_t])                 # [E, C+1, D]
    y_buf = jax.vmap(lambda pe, xe: ffn_apply(pe, xe[None], cfg)[0])(
        p["experts"], buf[:, :cap]
    )                                                          # [E, C, D]
    y_buf = jnp.concatenate([y_buf, jnp.zeros((e, 1, d), y_buf.dtype)], axis=1)
    y = (y_buf[flat_e, slot].astype(F32) * flat_g[:, None]).reshape(t, k, d).sum(1)
    return y.reshape(b, s, d).astype(x.dtype)


def moe_aux_loss(p, x, cfg: ModelConfig):
    """Load-balance auxiliary loss (Switch-style): E·Σ f_e·P_e."""
    logits = x.reshape(-1, cfg.d_model).astype(F32) @ p["router"].T
    probs = jax.nn.softmax(logits, -1)
    top1 = jnp.argmax(probs, -1)
    f = jnp.mean(jax.nn.one_hot(top1, cfg.n_experts, dtype=F32), axis=0)
    pr = jnp.mean(probs, axis=0)
    return cfg.n_experts * jnp.sum(f * pr)


# ---------------------------------------------------------------------------
# RG-LRU recurrent block (recurrentgemma / Griffin)
# ---------------------------------------------------------------------------


def rglru_init(key, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 5)
    d, dr = cfg.d_model, cfg.d_inner
    return {
        "in": bitlinear.init(ks[0], d, dr),
        "gate": bitlinear.init(ks[1], d, dr),
        "out": bitlinear.init(ks[2], dr, d),
        "conv_w": jax.random.normal(ks[3], (cfg.conv_width, dr), F32) * 0.1,
        # RG-LRU gates: elementwise fp32 (tiny) — DESIGN.md §Arch-applicability
        "lam": jnp.ones((dr,), F32) * 2.0,       # a = sigmoid(lam) ≈ 0.88
        "wr": jnp.zeros((dr,), F32), "br": jnp.zeros((dr,), F32),
        "wi": jnp.zeros((dr,), F32), "bi": jnp.zeros((dr,), F32),
    }


def rglru_state_init(cfg: ModelConfig, batch: int) -> dict:
    return {
        "h": jnp.zeros((batch, cfg.d_inner), F32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.d_inner), F32),
    }


def _causal_conv(x: jax.Array, w: jax.Array, hist: jax.Array | None,
                 n_valid: jax.Array | None = None):
    """Depthwise causal conv along time. x: [B, S, C]; w: [cw, C].

    ``n_valid`` ([B] int32) marks per-row valid PREFIX lengths (batched
    concurrent prefill pads short final chunks on the right): the carried
    history then ends at each row's last valid input, ``xp[n : n + cw - 1]``,
    instead of the tail of the padded row.  ``n_valid = 0`` rows keep their
    history untouched.  None → the dense tail (every input valid)."""
    cw = w.shape[0]
    if hist is None:
        hist = jnp.zeros((x.shape[0], cw - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([hist, x.astype(F32)], axis=1)
    y = sum(xp[:, i : i + x.shape[1]] * w[i] for i in range(cw))
    if cw > 1:
        if n_valid is None:
            new_hist = xp[:, -(cw - 1):]
        else:
            idx = n_valid[:, None] + jnp.arange(cw - 1, dtype=jnp.int32)[None]
            new_hist = jnp.take_along_axis(xp, idx[:, :, None], axis=1)
    else:
        new_hist = hist
    return y, new_hist


def rglru_apply(p, x, cfg: ModelConfig, *, state=None, pos=None):
    xin = bitlinear.apply(p["in"], x, cfg.quant).astype(F32)     # [B, S, dr]
    gate = bitlinear.apply(p["gate"], x, cfg.quant).astype(F32)
    hist = state["conv"] if state is not None else None
    posm = None if pos is None else jnp.asarray(pos)
    # A [B, S] position matrix (batched concurrent prefill) marks padding
    # tokens with pos < 0: they must be IDENTITY steps of the recurrence and
    # invisible to the conv history carry (padding is on the right, so real
    # prefix outputs are untouched either way).
    tok_mask = (posm >= 0) if (posm is not None and posm.ndim == 2
                               and x.shape[1] > 1) else None
    nv = None if tok_mask is None else jnp.sum(tok_mask.astype(jnp.int32), axis=1)
    xc, new_hist = _causal_conv(xin, p["conv_w"], hist, n_valid=nv)

    r = jax.nn.sigmoid(xc * p["wr"] + p["br"])                   # recurrence gate
    i = jax.nn.sigmoid(xc * p["wi"] + p["bi"])                   # input gate
    log_a = 8.0 * r * jax.nn.log_sigmoid(p["lam"])               # a_t = a^(8 r_t)
    a = jnp.exp(log_a)
    bterm = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * xc)
    if tok_mask is not None:  # identity transition: h unchanged, exact (·1, +0)
        a = jnp.where(tok_mask[..., None], a, 1.0)
        bterm = jnp.where(tok_mask[..., None], bterm, 0.0)

    if state is not None and x.shape[1] == 1:
        h = a[:, 0] * state["h"] + bterm[:, 0]
        if pos is not None:  # paused continuous-batching slots keep their state
            act = (jnp.broadcast_to(jnp.asarray(pos), (x.shape[0],)) >= 0)
            h = jnp.where(act[:, None], h, state["h"])
            new_hist = jnp.where(act[:, None, None], new_hist, state["conv"])
        y = h[:, None]
        new_state = {"h": h, "conv": new_hist}
    else:
        aa, bb = jax.lax.associative_scan(
            lambda l, r_: (l[0] * r_[0], l[1] * r_[0] + r_[1]), (a, bterm), axis=1
        )
        y = bb
        if state is not None:
            # chunked prefill: fold the carried hidden state in — h_t with
            # init h0 is cumprod(a)_t · h0 + (zero-init response)_t.
            y = aa * state["h"][:, None] + bb
        new_state = None if state is None else {"h": y[:, -1], "conv": new_hist}
    out = y * jax.nn.gelu(gate)
    return bitlinear.apply(p["out"], out.astype(x.dtype), cfg.quant), new_state


# ---------------------------------------------------------------------------
# SSD block (mamba2)
# ---------------------------------------------------------------------------


def ssd_init(key, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 4)
    d, di, s, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    conv_ch = di + 2 * s
    return {
        "in": bitlinear.init(ks[0], d, 2 * di + 2 * s + h),
        "out": bitlinear.init(ks[1], di, d),
        "conv_w": jax.random.normal(ks[2], (cfg.conv_width, conv_ch), F32) * 0.1,
        "A_log": jnp.zeros((h,), F32),          # A = -exp(A_log) = -1
        "D": jnp.ones((h,), F32),
        "dt_bias": jnp.zeros((h,), F32),
        "norm": rms_norm_init(di),
    }


def ssd_state_init(cfg: ModelConfig, batch: int) -> dict:
    ph = cfg.d_inner // cfg.ssm_heads
    return {
        "h": jnp.zeros((batch, cfg.ssm_heads, ph, cfg.ssm_state), F32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.d_inner + 2 * cfg.ssm_state), F32),
    }


def _ssd_chunked(a_log, xbar, bm, cm, chunk: int, h0=None):
    """Pure-jnp SSD (state-space duality), same math as kernels/ssd_scan.

    a_log [B,L,H]; xbar [B,L,H,P]; bm/cm [B,L,S] (single group shared by
    heads).  lax.scan over chunks carries the [B,H,P,S] state; ``h0`` is the
    initial carry (chunked serving prefill), zeros when None.
    """
    b, l, h = a_log.shape
    p = xbar.shape[-1]
    s = bm.shape[-1]
    nc = l // chunk
    al = a_log.reshape(b, nc, chunk, h)
    xb = xbar.reshape(b, nc, chunk, h, p)
    bmc = bm.reshape(b, nc, chunk, s)
    cmc = cm.reshape(b, nc, chunk, s)
    la = jnp.cumsum(al, axis=2)                                  # [B,NC,Q,H]
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))

    scores = jnp.einsum("bnis,bnjs->bnij", cmc, bmc)             # [B,NC,Q,Q]
    decay = jnp.exp(la[:, :, :, None] - la[:, :, None, :, :])    # [B,NC,Q,Q,H]
    att = jnp.where(tri[None, None, :, :, None], scores[..., None] * decay, 0.0)
    y_intra = jnp.einsum("bnijh,bnjhp->bnihp", att, xb)

    # chunk summaries
    wdec = jnp.exp(la[:, :, -1:, :] - la)                        # [B,NC,Q,H]
    s_chunk = jnp.einsum("bnjh,bnjs,bnjhp->bnhps", wdec, bmc, xb)
    a_chunk = jnp.exp(la[:, :, -1])                              # [B,NC,H]

    def step(hc, inp):
        a_c, s_c = inp                                           # [B,H], [B,H,P,S]
        out = hc
        hc = a_c[:, :, None, None] * hc + s_c
        return hc, out

    if h0 is None:
        h0 = jnp.zeros((b, h, p, s), F32)
    h_last, h_in = jax.lax.scan(
        step, h0, (jnp.moveaxis(a_chunk, 1, 0), jnp.moveaxis(s_chunk, 1, 0))
    )
    h_in = jnp.moveaxis(h_in, 0, 1)                              # [B,NC,H,P,S]
    y_inter = jnp.einsum("bnih,bnis,bnhps->bnihp", jnp.exp(la), cmc, h_in)
    y = (y_intra + y_inter).reshape(b, l, h, p)
    return y, h_last


def ssd_apply(p, x, cfg: ModelConfig, *, state=None, pos=None, chunk: int = 64):
    b, l, _ = x.shape
    di, s, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    ph = di // h
    zxbcdt = bitlinear.apply(p["in"], x, cfg.quant).astype(F32)
    z, xr, bmat, cmat, dt = jnp.split(zxbcdt, [di, 2 * di, 2 * di + s, 2 * di + 2 * s], axis=-1)

    hist = state["conv"] if state is not None else None
    posm = None if pos is None else jnp.asarray(pos)
    tok_mask = (posm >= 0) if (posm is not None and posm.ndim == 2
                               and l > 1) else None  # see rglru_apply
    nv = None if tok_mask is None else jnp.sum(tok_mask.astype(jnp.int32), axis=1)
    xbc, new_hist = _causal_conv(jnp.concatenate([xr, bmat, cmat], -1),
                                 p["conv_w"], hist, n_valid=nv)
    xbc = jax.nn.silu(xbc)
    xr, bmat, cmat = jnp.split(xbc, [di, di + s], axis=-1)

    dt = jax.nn.softplus(dt + p["dt_bias"])                      # [B,L,H]
    a_log = -jnp.exp(p["A_log"]) * dt
    xh = xr.reshape(b, l, h, ph)
    xbar = xh * dt[..., None]
    if tok_mask is not None:  # identity SSM step: decay 1, no state injection
        a_log = jnp.where(tok_mask[..., None], a_log, 0.0)
        xbar = jnp.where(tok_mask[..., None, None], xbar, 0.0)

    if state is not None and l == 1:
        hprev = state["h"]
        hnew = jnp.exp(a_log[:, 0])[:, :, None, None] * hprev + jnp.einsum(
            "bhp,bs->bhps", xbar[:, 0], bmat[:, 0]
        )
        if pos is not None:  # paused continuous-batching slots keep their state
            act = (jnp.broadcast_to(jnp.asarray(pos), (b,)) >= 0)
            hnew = jnp.where(act[:, None, None, None], hnew, hprev)
            new_hist = jnp.where(act[:, None, None], new_hist, state["conv"])
        y = jnp.einsum("bs,bhps->bhp", cmat[:, 0], hnew)[:, None]
        new_state = {"h": hnew, "conv": new_hist}
    else:
        c = min(chunk, l)
        if l % c:  # chunked serving prefill may pass non-multiple lengths
            c = l
        y, h_last = _ssd_chunked(a_log, xbar, bmat, cmat, c,
                                 h0=state["h"] if state is not None else None)
        new_state = None if state is None else {"h": h_last, "conv": new_hist}

    y = y + p["D"][None, None, :, None] * xh                      # skip term
    y = y.reshape(b, l, di) * jax.nn.silu(z)
    y = rms_norm(p["norm"], y.astype(cdt(cfg)), cfg.norm_eps)
    return bitlinear.apply(p["out"], y, cfg.quant), new_state
