"""I2_S fused decode+matmul Pallas TPU kernel (paper §3.2.2, TPU-adapted).

Contract:  y_int32[N, M] = x_q[N, K] (int8) · W_t[M, K]^T,
with W stored packed 4 ternary digits / byte (2 bpw in HBM).

TPU adaptation (DESIGN.md §2): the packed bytes stream HBM→VMEM and are
decoded *in VMEM* with shift/mask on the VPU — the unpacked int8 operand
never exists in HBM, which is exactly the property that makes the 2 bpw
memory-roofline real.  To avoid lane-dim reshuffles entirely, the kernel
uses a split-plane formulation:

    byte b packs digits c0..c3 of weights w[4k..4k+3];
    digit plane i:  D_i[m, k4] = ((p >> 2i) & 3) - 1         (shape [M, K/4])
    activation plane i:  X_i[n, k4] = x[n, 4·k4 + i]          (shape [N, K/4])
    y = Σ_i  X_i · D_i^T        (four int8 MXU dots, K/4 contraction each)

The X planes are produced once by the ops.py wrapper (a cheap strided view);
inside the kernel there is no reshape, repeat, gather, or iota — only
shifts, masks, subtracts and dots, all natively layout-friendly.

Grid: (N/bn, M/bm, K4/bk4) with the contraction axis innermost; the int32
accumulator tile lives in the output VMEM block across the k steps.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _i2s_kernel(x0, x1, x2, x3, p_ref, out_ref):
    """One (bn, bm) output tile, one bk4-wide slice of the contraction."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    p = p_ref[...]  # uint8 [bm, bk4]
    acc = out_ref[...]
    for i, x_ref in enumerate((x0, x1, x2, x3)):
        d = (((p >> (2 * i)) & 0x3).astype(jnp.int8) - 1)  # [bm, bk4] in {-1,0,1}
        acc = acc + jax.lax.dot_general(
            x_ref[...], d,
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.int32,
        )
    out_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("bn", "bm", "bk4", "interpret"))
def i2s_matmul(
    x_planes: tuple[jax.Array, jax.Array, jax.Array, jax.Array],
    packed: jax.Array,
    *,
    bn: int = 128,
    bm: int = 128,
    bk4: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """x_planes: 4 × int8 [N, K/4] (deinterleaved); packed: uint8 [M, K/4].

    Returns int32 [N, M].  Requires N % bn == M % bm == (K/4) % bk4 == 0
    (the ops.py wrapper pads).  bm, bn multiples of 128 keep the MXU dims
    hardware-aligned; bk4=128 puts a full 128-lane packed tile in VMEM
    (VMEM per step: bm·bk4 packed bytes + 4·bn·bk4 act bytes + 4·bn·bm acc).
    """
    n, k4 = x_planes[0].shape
    m = packed.shape[0]
    grid = (n // bn, m // bm, k4 // bk4)

    x_spec = pl.BlockSpec((bn, bk4), lambda i, j, k: (i, k))
    p_spec = pl.BlockSpec((bm, bk4), lambda i, j, k: (j, k))
    o_spec = pl.BlockSpec((bn, bm), lambda i, j, k: (i, j))

    return pl.pallas_call(
        _i2s_kernel,
        grid=grid,
        in_specs=[x_spec, x_spec, x_spec, x_spec, p_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.int32),
        interpret=interpret,
    )(*x_planes, packed)
