"""True element-wise-LUT mpGEMV on the MXU (paper Algorithm 3; TL1_0 / TL1_1).

This kernel keeps the paper's *table-lookup* computation model rather than
decoding weights: the wrapper precomputes the 9-entry eLUT of every
activation pair group (paper Phase 1 / ``tl1_build_lut``), and the kernel
accumulates ``Σ_g LUT[g, code[m, g]]``.

TPU adaptation of the lookup (DESIGN.md §2): there is no `vpshufb`, so the
lookup is expressed as a compare-and-accumulate contraction — for each code
value c, ``(codes == c)`` forms a 0/1 int8 mask that multiplies LUT column c
on the MXU.  Napkin math: this inflates MXU work by ~C²/g ≈ 4.5× over the
arithmetic-decode kernels, so it only wins in the *extremely* memory-bound
regime (batch-1 decode GEMV, where the MXU idles anyway and HBM bytes are
everything).  That is precisely the regime the paper targets on CPU.

Losslessness (paper §3.2.1): eLUT entries of int8 pairs need int16.
  * TL1_1 (lossless): the int16 LUT is split into low/high byte planes and
    looked up twice, then recombined as ``acc_hi·256 + acc_lo`` — the
    **pack-and-unpack** technique, mapped to two int8 MXU contractions.
  * TL1_0 (lossy): the wrapper requantizes the LUT to int8 (T-MAC style,
    per-tensor scale) and the kernel does a single contraction.

Weight layout: original tl1 bytes (code pair (2t, 2t+1) per byte) — the lo
nibble plane is the even groups, the hi plane the odd groups, so the wrapper
supplies the eLUT deinterleaved into even/odd group tables.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _lut_gemv_kernel(lut_even, lut_odd, p_ref, out_ref, *, lossless: bool):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    p = p_ref[...].astype(jnp.int16)  # [bm, gb/2] packed code bytes
    lo = p & 0xF                      # codes of even groups
    hi = (p >> 4) & 0xF               # codes of odd groups
    acc = out_ref[...]
    for codes, lut_ref in ((lo, lut_even), (hi, lut_odd)):
        lut = lut_ref[...]            # [gb/2, 9] int32 (int16-range values)
        for c in range(9):
            mask = (codes == c).astype(jnp.int8)            # [bm, gb/2]
            col = lut[:, c]                                  # [gb/2]
            if lossless:
                # pack-and-unpack: two int8-range lookups, recombined exactly.
                col_lo = (col & 0xFF).astype(jnp.int32)      # unsigned low byte
                col_hi = (col >> 8).astype(jnp.int32)        # arithmetic high
                acc_lo = jnp.dot(mask.astype(jnp.int32), col_lo,
                                 preferred_element_type=jnp.int32)
                acc_hi = jnp.dot(mask.astype(jnp.int32), col_hi,
                                 preferred_element_type=jnp.int32)
                acc = acc + (acc_hi * 256 + acc_lo)[:, None]
            else:
                acc = acc + jnp.dot(
                    mask.astype(jnp.int32), col,
                    preferred_element_type=jnp.int32,
                )[:, None]
    out_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("bm", "g_blk", "lossless", "interpret"))
def tl1_lut_gemv(
    lut_even: jax.Array,
    lut_odd: jax.Array,
    packed: jax.Array,
    *,
    bm: int = 128,
    g_blk: int = 256,
    lossless: bool = True,
    interpret: bool = False,
) -> jax.Array:
    """lut_even/odd: int32 [G/2, 9] (eLUT of even/odd activation pair groups);
    packed: uint8 [M, G/2] tl1 bytes (G = K/2 groups).  Returns int32 [M, 1].

    Requires M % bm == 0 and (G/2) % (g_blk/2) == 0.
    """
    m = packed.shape[0]
    gh = packed.shape[1]  # G/2 bytes per row
    ghb = g_blk // 2
    grid = (m // bm, gh // ghb)

    lut_spec = pl.BlockSpec((ghb, 9), lambda i, k: (k, 0))
    p_spec = pl.BlockSpec((bm, ghb), lambda i, k: (i, k))
    o_spec = pl.BlockSpec((bm, 1), lambda i, k: (i, 0))

    return pl.pallas_call(
        functools.partial(_lut_gemv_kernel, lossless=lossless),
        grid=grid,
        in_specs=[lut_spec, lut_spec, p_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((m, 1), jnp.int32),
        interpret=interpret,
    )(lut_even, lut_odd, packed)
