"""Pure-jnp / numpy oracles for every Pallas kernel.

Two layers of assurance:
  * the canonical pure-jnp semantics live in ``repro.core`` (mpgemm / quant /
    packing) and are re-exported here as the primary oracles;
  * ``*_naive`` numpy loop implementations are fully independent (no shared
    code with either the kernels or core) for tiny-shape spot checks.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mpgemm as _mpgemm
from repro.core import packing as _packing
from repro.core import quant as _quant
from repro.core.qtensor import PackedWeight, unpack_weight

# ---------------------------------------------------------------------------
# Canonical oracles (shared semantics with repro.core)
# ---------------------------------------------------------------------------


def mpgemm_int32(x_q: jax.Array, w_t: jax.Array) -> jax.Array:
    """int8 [N, K] × ternary int8 [M, K] -> int32 [N, M]."""
    return jax.lax.dot_general(
        x_q, w_t, (((1,), (1,)), ((), ())), preferred_element_type=jnp.int32
    )


def mpgemm_packed(x_q: jax.Array, pw: PackedWeight) -> jax.Array:
    return mpgemm_int32(x_q, unpack_weight(pw).astype(jnp.int8))


def absmax_int8(x: jax.Array):
    return _quant.absmax_int8(x)


def tl1_lut_int32(x_q: jax.Array, pw: PackedWeight, lossless: bool) -> jax.Array:
    """Algorithm 3 semantics, int32 result before scaling (N=1 gemv oracle)."""
    y = _mpgemm.tl1_lut(x_q, jnp.float32(1.0), pw, lossless=lossless)
    if lossless:
        return jnp.round(y).astype(jnp.int32)
    return y  # lossy variant has a non-integer LUT scale folded in


def ssd_sequential(a_log, xbar, b, c):
    """O(L) sequential recurrence oracle: y_t = C_t · (a_t h_{t-1} + B_t ⊗ x̄_t)."""

    def step(h, inp):
        al, xb, bm, cm = inp
        h = jnp.exp(al) * h + jnp.outer(xb, bm)  # [P, S]
        return h, h @ cm

    bh, L = a_log.shape
    p, s = xbar.shape[-1], b.shape[-1]

    def per_seq(al, xb, bm, cm):
        h0 = jnp.zeros((p, s), jnp.float32)
        _, y = jax.lax.scan(step, h0, (al, xb, bm, cm))
        return y

    return jax.vmap(per_seq)(a_log, xbar, b, c)


# ---------------------------------------------------------------------------
# Independent numpy loop oracles (tiny shapes only)
# ---------------------------------------------------------------------------


def ternary_matmul_naive(x_q: np.ndarray, w_t: np.ndarray) -> np.ndarray:
    """Triple loop, no vectorization, no shared code."""
    n, k = x_q.shape
    m = w_t.shape[0]
    out = np.zeros((n, m), np.int64)
    for i in range(n):
        for j in range(m):
            acc = 0
            for t in range(k):
                acc += int(x_q[i, t]) * int(w_t[j, t])
            out[i, j] = acc
    return out.astype(np.int32)


def tl2_pack_naive(w_row: np.ndarray) -> tuple[list[int], list[int]]:
    """Paper Table 6 semantics for one row (groups of 3 -> (sign, idx))."""
    signs, idxs = [], []
    for g in range(0, len(w_row), 3):
        v = (w_row[g] + 1) * 9 + (w_row[g + 1] + 1) * 3 + (w_row[g + 2] + 1)
        if v > 13:
            signs.append(1)
            idxs.append(26 - v)
        else:
            signs.append(0)
            idxs.append(int(v))
    return idxs, signs


def lut_gemv_naive(x_q: np.ndarray, w_t: np.ndarray) -> np.ndarray:
    """Algorithm 3 executed literally: enumerate the 9-entry eLUT, look up."""
    k = x_q.shape[0]
    m = w_t.shape[0]
    lut = np.zeros((k // 2, 9), np.int64)
    for g in range(k // 2):
        for c in range(9):
            d0, d1 = c // 3 - 1, c % 3 - 1
            lut[g, c] = int(x_q[2 * g]) * d0 + int(x_q[2 * g + 1]) * d1
    out = np.zeros(m, np.int64)
    for j in range(m):
        for g in range(k // 2):
            code = (int(w_t[j, 2 * g]) + 1) * 3 + (int(w_t[j, 2 * g + 1]) + 1)
            out[j] += lut[g, code]
    return out.astype(np.int32)


__all__ = [
    "mpgemm_int32",
    "mpgemm_packed",
    "absmax_int8",
    "tl1_lut_int32",
    "ssd_sequential",
    "ternary_matmul_naive",
    "tl2_pack_naive",
    "lut_gemv_naive",
]
