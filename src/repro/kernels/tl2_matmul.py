"""TL2 fused decode+matmul Pallas TPU kernel (paper §3.1, Algorithm 4, TPU-adapted).

Contract: y_int32[N, M] = x_q[N, K] (int8) · W_t[M, K]^T, with W stored at
**1.67 bpw**: a 4-bit index plane + a 1-bit sign plane per group of 3 ternary
weights (element-wise mirror consolidation + signed-unsigned weight
splitting, paper §3.1.1–3.1.2), in the ``tl2k`` kernel layout
(``repro.core.packing.tl2k_pack``) — the TPU analogue of the paper's
LUT-centric data layout.

Decode per K-tile of G groups (all static lane slices, no interleaves):

    lo = idx & 0xF          # indices of groups [0, G/2)
    hi = idx >> 4           # indices of groups [G/2, G)
    for b in 0..7:          # sign bit-plane b covers groups [b·G/8, (b+1)·G/8)
        s   = (sign >> b) & 1                       # [bm, G/8]
        i_b = (lo | hi)[:, lane slice for b]        # [bm, G/8]
        v   = i_b·(1 - 2s) + 26·s                   # mirror decode; arithmetic
                                                    # equivalent of Eq. 5's
                                                    # sign = XOR(sign, sign+x)
        d0, d1, d2 = v//9 - 1, (v//3)%3 - 1, v%3 - 1    # base-3 digits (VPU
                                                        # mul-shift div/mod)
        acc += x0_b·d0ᵀ + x1_b·d1ᵀ + x2_b·d2ᵀ           # int8 MXU dots

The activation is pre-deinterleaved by ops.py into three digit planes
x_i[n, g] = x[n, 3g + i].  The paper's 9/14-entry `vpshufb` tables have no
TPU analogue (DESIGN.md §2); arithmetic base-3 decode replaces them while
preserving the 1.67 bpw HBM format — which is what the memory roofline sees.

K handling: requires K % (3·g_tile) == 0; general K uses block-fitting
weight splitting (paper §3.1.2) — ops.py routes the tail through tl1.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _tl2_kernel(x0, x1, x2, idx_ref, sign_ref, out_ref, *, g_tile: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    idx = idx_ref[...].astype(jnp.int16)   # [bm, G/2] packed nibbles
    sign = sign_ref[...]                   # [bm, G/8] packed sign bits (uint8)
    lo = idx & 0xF
    hi = (idx >> 4) & 0xF
    w8 = g_tile // 8
    acc = out_ref[...]
    for b in range(8):
        s = ((sign >> b) & 1).astype(jnp.int16)                 # [bm, G/8]
        half = lo if b < 4 else hi
        off = (b % 4) * w8
        i_b = jax.lax.slice_in_dim(half, off, off + w8, axis=1)  # [bm, G/8]
        v = i_b * (1 - 2 * s) + 26 * s                           # 0..26
        digits = (v // 9, (v // 3) % 3, v % 3)
        lane0 = b * w8
        for x_ref, d16 in zip((x0, x1, x2), digits):
            d = d16.astype(jnp.int8) - 1
            xb = jax.lax.slice_in_dim(x_ref[...], lane0, lane0 + w8, axis=1)
            acc = acc + jax.lax.dot_general(
                xb, d, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.int32,
            )
    out_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("bn", "bm", "g_tile", "interpret"))
def tl2_matmul(
    x_planes: tuple[jax.Array, jax.Array, jax.Array],
    idx_plane: jax.Array,
    sign_plane: jax.Array,
    *,
    bn: int = 128,
    bm: int = 128,
    g_tile: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """x_planes: 3 × int8 [N, K/3] (digit-deinterleaved, tile order);
    idx_plane: uint8 [M, K/6]; sign_plane: uint8 [M, K/24] (tl2k layout).

    Returns int32 [N, M].  One grid k-step covers one g_tile-group K-tile
    (3·g_tile weights); VMEM per step ≈ bm·g_tile·(1/2 + 1/8) packed bytes +
    3·bn·g_tile activation bytes + bn·bm·4 accumulator bytes.
    """
    n, g_total = x_planes[0].shape
    m = idx_plane.shape[0]
    grid = (n // bn, m // bm, g_total // g_tile)

    x_spec = pl.BlockSpec((bn, g_tile), lambda i, j, k: (i, k))
    i_spec = pl.BlockSpec((bm, g_tile // 2), lambda i, j, k: (j, k))
    s_spec = pl.BlockSpec((bm, g_tile // 8), lambda i, j, k: (j, k))
    o_spec = pl.BlockSpec((bn, bm), lambda i, j, k: (i, j))

    return pl.pallas_call(
        functools.partial(_tl2_kernel, g_tile=g_tile),
        grid=grid,
        in_specs=[x_spec, x_spec, x_spec, i_spec, s_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.int32),
        interpret=interpret,
    )(*x_planes, idx_plane, sign_plane)
