"""Parametric ELUT Pallas TPU kernels (paper §3 + Appendix ELUT, TPU-adapted).

One kernel family generated from ``(b, g, code width)`` covers every plain
code-plane format — including the bit-contiguous sub-byte layouts and the
zero-occupancy skip walk (DESIGN.md §11) — plus tl2's mirror-consolidated
layout through the same digit decoder:

  * :func:`elut_matmul` — fused decode+MAD: packed code bytes stream
    HBM→VMEM at the format's true bpw and are decoded on the VPU with
    shift/mask/div-mod-by-b (div/mod by a constant lowers to
    multiply-shift; power-of-two bases lower to pure shifts), then hit the
    MXU as int8 digit-plane dots.  Ternary (3, 2, 4) is bit-identical to
    the old tl1_matmul; (3, 1, 2) to i2s_matmul; (4, 2, 4) / (8, 2, 8)
    are the int2/int3 instances through the same code.

    Decode walks one *unit* at a time (``unit_bytes`` bytes holding
    ``codes_per_unit`` whole codes — 1 byte / 8/field_bits codes for the
    byte-aligned formats, lcm(code_bits, 8)/8 bytes for the bit-contiguous
    ``_bc`` stream, e.g. int3_bc's 3-byte/4-code/8-weight unit):

        for code slot c in 0..codes_per_unit-1:
            code = static shift/OR reassembly of the unit bytes
            for digit position i in 0..g-1:
                D = (code // b^(g-1-i)) % b - b//2        # [bm, units]
                acc += X_{c·g+i} · D^T                    # int8 MXU dot

    where X_j[n, u] = x[n, wpu·u + j] are the deinterleaved activation
    planes produced once by the ops.py wrapper (wpu weights per unit).

  * :func:`elut_matmul_skip` / :func:`elut_lut_gemv_skip` — the
    zero-occupancy walk for ``_z`` formats: an extra uint8 occupancy plane
    (``packing.occupancy_map``) marks which K-blocks of each output row
    hold any nonzero weight, and the kernel wraps each block's
    decode+accumulate in ``pl.when(any occupied)``.  A block is skipped
    only when EVERY row of the M-tile is zero there (column-structured
    sparsity); the skip is exact — a zero block's digits are all zero, its
    dot contributes exactly 0, and integer adds commute — so the skip walk
    is bit-identical to the dense walk by construction (gated at atol=0 by
    the conformance harness and tests/test_sparsity.py).

  * :func:`elut_lut_gemv` — the true *table-lookup* computation model for
    the extreme memory-bound batch-1 decode regime: the wrapper precomputes
    the C = b^g-entry eLUT per activation group (Phase 1 /
    ``packing.elut_build_lut``) and the kernel accumulates
    ``Σ_g LUT[g, code[m, g]]``.  No TPU `vpshufb` exists, so the lookup is
    a compare-and-accumulate contraction: for each code value c,
    ``(codes == c)`` is a 0/1 int8 mask multiplying LUT column c on the
    MXU — ~C/g = b^g/g more MXU work than MAD (tl1 4.5×, int2 8×,
    int3 32×), irrelevant when the MXU idles and HBM bytes are everything.

    Losslessness (paper §3.2.1) is parametric: eLUT entries of int8 groups
    need int16, so the lossless ``_1`` variant splits the int16 table into
    low/high byte planes, looks up twice, and recombines exactly
    (``acc_hi·256 + acc_lo`` — the **pack-and-unpack** technique); the
    lossy ``_0`` variant takes a single int8-requantized table.

  * :func:`tl2_mirror_matmul` — tl2's mirror-consolidated sign+index
    layout folded into this family: the 4-bit index and 1-bit sign planes
    decode to the group value v = idx·(1−2s) + 26s, whose base-3 digits
    come from the SAME parametric digit decoder as every other format
    (``_code_digits``).  This retired the separate ``tl2_matmul.py``
    kernel file (bit-identical by the shared oracle contract:
    tests/test_kernels.py pins kernel ≡ XLA int32 reference exactly).

Both mat paths have ``*_grouped`` variants for per-group weight scales
(DESIGN.md §2): the K walk splits at scale-group boundaries (``group_bytes``
packed bytes per group), each group's int32 partial finishes exactly, and
one fp32 multiply per (group, output) folds it into the fp32 accumulator —
the per-tensor kernels above are untouched, so ``group_scale_cols=None``
stays bit-identical.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.packing import bc_unit


# ---------------------------------------------------------------------------
# Shared parametric decode helpers
# ---------------------------------------------------------------------------


def _unit_params(field_bits: int, code_bits: int) -> tuple[int, int, int]:
    """(effective code width, unit_bytes, codes_per_unit) for a format.

    ``code_bits == 0`` selects the byte-aligned field layout (one-byte
    units, 8/field_bits codes each); nonzero selects the bit-contiguous
    stream (lcm(code_bits, 8)/8-byte units).
    """
    if code_bits:
        ub, cpu = bc_unit(code_bits)
        return code_bits, ub, cpu
    return field_bits, 1, 8 // field_bits


def _unit_codes(p, cb: int, ub: int, cpu: int):
    """uint8 bytes [R, nb] -> cpu code arrays [R, nb/ub] (values 0..2^cb-1).

    Static shift/OR reassembly only — the same arithmetic as
    ``packing.elut_codes`` / ``elut_codes_bc``, inlined so the two decoders
    agree by construction.  Single-byte units keep the legacy int16
    shift-and-mask (bit-identical to the pre-refactor kernels).
    """
    mask = (1 << cb) - 1
    if ub == 1:
        p16 = p.astype(jnp.int16)
        return [(p16 >> (c * cb)) & mask for c in range(cpu)]
    pu = p.astype(jnp.int32).reshape(p.shape[0], -1, ub)
    codes = []
    for c in range(cpu):
        off = c * cb
        code = None
        for by in range(off // 8, (off + cb - 1) // 8 + 1):
            sh = 8 * by - off   # byte ``by``'s bit-0 position within the code
            pb = pu[..., by]
            part = pb << sh if sh >= 0 else pb >> -sh
            code = part if code is None else code | part
        codes.append(code & mask)
    return codes


def _code_digits(code, b: int, g: int):
    """Code array -> g big-endian base-b digit arrays (values 0..b-1).

    Shared by every kernel in the family, including the tl2 mirror decode
    (its group value v ∈ 0..26 is a base-3, g=3 code).
    """
    return [(code // (b ** (g - 1 - i))) % b for i in range(g)]


# ---------------------------------------------------------------------------
# Arithmetic-decode MAD path (GEMM regime)
# ---------------------------------------------------------------------------


def _elut_mad_kernel(*refs, b: int, g: int, cb: int, ub: int, cpu: int):
    *x_refs, p_ref, out_ref = refs
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    offset = b // 2
    acc = out_ref[...]
    plane = 0
    for code in _unit_codes(p_ref[...], cb, ub, cpu):
        for d16 in _code_digits(code, b, g):
            d = d16.astype(jnp.int8) - offset
            acc = acc + jax.lax.dot_general(
                x_refs[plane][...], d,
                (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.int32,
            )
            plane += 1
    out_ref[...] = acc


@functools.partial(jax.jit, static_argnames=(
    "b", "g", "field_bits", "code_bits", "bn", "bm", "bkc", "interpret"))
def elut_matmul(
    x_planes: tuple,
    packed: jax.Array,
    *,
    b: int,
    g: int,
    field_bits: int,
    code_bits: int = 0,
    bn: int = 128,
    bm: int = 128,
    bkc: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """x_planes: wpu × int8 [N, K/wpu] (deinterleaved, wpu weights per
    decode unit); packed: uint8 [M, Kbytes] ELUT code bytes.  Returns
    int32 [N, M].

    Requires N % bn == M % bm == Kbytes % bkc == 0 and bkc a multiple of
    unit_bytes (the ops.py wrapper pads N and picks aligned blocks; K
    alignment is the format's k_align).  Grid (N/bn, M/bm, Kbytes/bkc)
    with the contraction axis innermost and the int32 accumulator tile
    living in the output VMEM block across the k steps.
    """
    cb, ub, cpu = _unit_params(field_bits, code_bits)
    n, ku = x_planes[0].shape
    m, kb = packed.shape
    if bkc % ub or kb % bkc:
        raise ValueError(f"bkc={bkc} must be a unit-aligned divisor of {kb}")
    grid = (n // bn, m // bm, kb // bkc)

    x_spec = pl.BlockSpec((bn, bkc // ub), lambda i, j, k: (i, k))
    p_spec = pl.BlockSpec((bm, bkc), lambda i, j, k: (j, k))
    o_spec = pl.BlockSpec((bn, bm), lambda i, j, k: (i, j))

    return pl.pallas_call(
        functools.partial(_elut_mad_kernel, b=b, g=g, cb=cb, ub=ub, cpu=cpu),
        grid=grid,
        in_specs=[x_spec] * len(x_planes) + [p_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.int32),
        interpret=interpret,
    )(*x_planes, packed)


# ---------------------------------------------------------------------------
# Zero-occupancy skip walk (DESIGN.md §11)
#
# One extra uint8 occupancy plane row per ``block_bytes`` packed bytes; each
# block's decode+dot runs under ``pl.when(any occupied)``.  Skipping is
# exact: a zero block's digits are identically 0, its dot contributes
# exactly 0 to the int32 accumulator, and integer adds commute — so the
# result equals the dense walk bit for bit whether or not any block skips.
# ---------------------------------------------------------------------------


def _elut_mad_skip_kernel(*refs, b: int, g: int, cb: int, ub: int, cpu: int,
                          block_bytes: int):
    *x_refs, p_ref, occ_ref, out_ref = refs
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    offset = b // 2
    p = p_ref[...]
    occ = occ_ref[...]                    # [bm, blocks in this k-step]
    bu = block_bytes // ub                # x-plane units per block
    for blk in range(p.shape[1] // block_bytes):

        @pl.when(jnp.any(occ[:, blk] != 0))
        def _live(blk=blk):
            ps = p[:, blk * block_bytes:(blk + 1) * block_bytes]
            acc = None
            plane = 0
            for code in _unit_codes(ps, cb, ub, cpu):
                for d16 in _code_digits(code, b, g):
                    d = d16.astype(jnp.int8) - offset
                    xs = x_refs[plane][:, blk * bu:(blk + 1) * bu]
                    part = jax.lax.dot_general(
                        xs, d, (((1,), (1,)), ((), ())),
                        preferred_element_type=jnp.int32,
                    )
                    acc = part if acc is None else acc + part
                    plane += 1
            out_ref[...] += acc


@functools.partial(jax.jit, static_argnames=(
    "b", "g", "field_bits", "code_bits", "block_bytes", "bn", "bm", "bkc",
    "interpret"))
def elut_matmul_skip(
    x_planes: tuple,
    packed: jax.Array,
    occ: jax.Array,
    *,
    b: int,
    g: int,
    field_bits: int,
    block_bytes: int,
    code_bits: int = 0,
    bn: int = 128,
    bm: int = 128,
    bkc: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Zero-skip variant of :func:`elut_matmul`.  occ: uint8
    [M, Kbytes/block_bytes] occupancy plane (``packing.occupancy_map``;
    block_bytes = occ_block/wpu · unit_bytes packed bytes per block).
    Returns int32 [N, M], bit-identical to the dense walk.

    Requires bkc % block_bytes == 0 (K blocks cover whole occupancy
    blocks) on top of the :func:`elut_matmul` tiling contract.
    """
    cb, ub, cpu = _unit_params(field_bits, code_bits)
    if bkc % block_bytes or block_bytes % ub:
        raise ValueError(
            f"bkc={bkc} must cover whole {block_bytes}-byte occupancy "
            f"blocks of whole {ub}-byte units")
    n, _ = x_planes[0].shape
    m, kb = packed.shape
    grid = (n // bn, m // bm, kb // bkc)
    opb = bkc // block_bytes  # occupancy blocks per K step

    x_spec = pl.BlockSpec((bn, bkc // ub), lambda i, j, k: (i, k))
    p_spec = pl.BlockSpec((bm, bkc), lambda i, j, k: (j, k))
    z_spec = pl.BlockSpec((bm, opb), lambda i, j, k: (j, k))
    o_spec = pl.BlockSpec((bn, bm), lambda i, j, k: (i, j))

    return pl.pallas_call(
        functools.partial(_elut_mad_skip_kernel, b=b, g=g, cb=cb, ub=ub,
                          cpu=cpu, block_bytes=block_bytes),
        grid=grid,
        in_specs=[x_spec] * len(x_planes) + [p_spec, z_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.int32),
        interpret=interpret,
    )(*x_planes, packed, occ)


# ---------------------------------------------------------------------------
# Arithmetic-decode MAD path with per-group weight scales
#
# The K reduction splits at scale-group boundaries (``group_bytes`` packed
# byte columns per group): each group's digit-plane dots accumulate into an
# exact int32 partial, which ONE fp32 multiply by the group's scale row then
# folds into the fp32 output tile — scale application at accumulator
# granularity, so the integer part of the computation stays as exact as the
# per-tensor kernel's.  The per-tensor kernels above are untouched
# (group_scale_cols=None stays bit-identical by construction).
# ---------------------------------------------------------------------------


def _elut_mad_grouped_kernel(*refs, b: int, g: int, cb: int, ub: int,
                             cpu: int, group_bytes: int):
    *x_refs, p_ref, s_ref, out_ref = refs
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    offset = b // 2
    p = p_ref[...]
    gu = group_bytes // ub               # x-plane units per scale group
    acc = out_ref[...]
    for s in range(p.shape[1] // group_bytes):
        ps = p[:, s * group_bytes:(s + 1) * group_bytes]
        acc32 = None
        plane = 0
        for code in _unit_codes(ps, cb, ub, cpu):
            for d16 in _code_digits(code, b, g):
                d = d16.astype(jnp.int8) - offset
                xs = x_refs[plane][:, s * gu:(s + 1) * gu]
                part = jax.lax.dot_general(
                    xs, d, (((1,), (1,)), ((), ())),
                    preferred_element_type=jnp.int32,
                )
                acc32 = part if acc32 is None else acc32 + part
                plane += 1
        acc = acc + acc32.astype(jnp.float32) * s_ref[s, :][None, :]
    out_ref[...] = acc


@functools.partial(jax.jit, static_argnames=(
    "b", "g", "field_bits", "code_bits", "group_bytes", "bn", "bm", "bkc",
    "interpret"))
def elut_matmul_grouped(
    x_planes: tuple,
    packed: jax.Array,
    scales: jax.Array,
    *,
    b: int,
    g: int,
    field_bits: int,
    group_bytes: int,
    code_bits: int = 0,
    bn: int = 128,
    bm: int = 128,
    bkc: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Grouped-scale variant of :func:`elut_matmul`.  scales: fp32
    [K/G, M] group-major scale plane (G = group_bytes/unit_bytes · wpu
    weight columns per group).  Returns fp32 [N, M] with the weight scales
    applied (the wrapper multiplies the activation scale).

    Requires bkc % group_bytes == 0 (K blocks cover whole scale groups) on
    top of the :func:`elut_matmul` tiling contract.
    """
    cb, ub, cpu = _unit_params(field_bits, code_bits)
    if bkc % group_bytes or group_bytes % ub:
        raise ValueError(
            f"bkc={bkc} must cover whole scale groups of {group_bytes} "
            f"bytes of whole {ub}-byte units")
    n, _ = x_planes[0].shape
    m, kb = packed.shape
    grid = (n // bn, m // bm, kb // bkc)
    gpb = bkc // group_bytes  # scale groups per K block

    x_spec = pl.BlockSpec((bn, bkc // ub), lambda i, j, k: (i, k))
    p_spec = pl.BlockSpec((bm, bkc), lambda i, j, k: (j, k))
    s_spec = pl.BlockSpec((gpb, bm), lambda i, j, k: (k, j))
    o_spec = pl.BlockSpec((bn, bm), lambda i, j, k: (i, j))

    return pl.pallas_call(
        functools.partial(_elut_mad_grouped_kernel, b=b, g=g, cb=cb, ub=ub,
                          cpu=cpu, group_bytes=group_bytes),
        grid=grid,
        in_specs=[x_spec] * len(x_planes) + [p_spec, s_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.float32),
        interpret=interpret,
    )(*x_planes, packed, scales.astype(jnp.float32))


# ---------------------------------------------------------------------------
# True-LUT GEMV path (batch-1 decode regime)
# ---------------------------------------------------------------------------


def _gemv_block_acc(codes_list, lut_refs, row_slice, n_entries: int,
                    lossless: bool):
    """Compare-and-accumulate lookup over one byte range.

    codes_list: per-code-slot [bm, units] code arrays; lut_refs[c] rows
    ``row_slice`` hold the tables of those groups.  Returns the finished
    (acc_lo, acc_hi) int32 pair (acc_hi is None when lossy).
    """
    acc_lo = None
    acc_hi = None
    for codes, lut_ref in zip(codes_list, lut_refs):
        lut = lut_ref[...][row_slice, :]          # [units, C] int32
        for c in range(n_entries):
            m01 = (codes == c).astype(jnp.int8)   # [bm, units]
            col = lut[:, c]                        # [units]
            if lossless:
                # pack-and-unpack: two int8-range lookups, exact recombine
                col_lo = (col & 0xFF).astype(jnp.int32)   # unsigned low byte
                col_hi = (col >> 8).astype(jnp.int32)     # arithmetic high
                part_lo = jnp.dot(m01.astype(jnp.int32), col_lo,
                                  preferred_element_type=jnp.int32)
                part_hi = jnp.dot(m01.astype(jnp.int32), col_hi,
                                  preferred_element_type=jnp.int32)
                acc_lo = part_lo if acc_lo is None else acc_lo + part_lo
                acc_hi = part_hi if acc_hi is None else acc_hi + part_hi
            else:
                part = jnp.dot(m01.astype(jnp.int32), col,
                               preferred_element_type=jnp.int32)
                acc_lo = part if acc_lo is None else acc_lo + part
    return acc_lo, acc_hi


def _elut_gemv_kernel(*refs, n_entries: int, cb: int, ub: int, cpu: int,
                      lossless: bool):
    *lut_refs, p_ref, out_ref = refs
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    codes_list = _unit_codes(p_ref[...], cb, ub, cpu)
    acc_lo, acc_hi = _gemv_block_acc(
        codes_list, lut_refs, slice(None), n_entries, lossless)
    y32 = (acc_hi * 256 + acc_lo) if lossless else acc_lo
    out_ref[...] += y32[:, None]


@functools.partial(jax.jit, static_argnames=(
    "n_entries", "field_bits", "code_bits", "bm", "byte_blk", "lossless",
    "interpret"))
def elut_lut_gemv(
    lut_planes: tuple,
    packed: jax.Array,
    *,
    n_entries: int,
    field_bits: int,
    code_bits: int = 0,
    bm: int = 128,
    byte_blk: int = 128,
    lossless: bool = True,
    interpret: bool = False,
) -> jax.Array:
    """lut_planes: codes_per_unit × int32 [G/cpu, C] — the eLUT
    deinterleaved by code slot within the decode unit (for tl1's
    2-per-byte nibbles these are the even/odd group tables; int3_bc's
    3-byte unit has 4); packed: uint8 [M, n_bytes] code bytes
    (n_bytes = G/cpu · unit_bytes).  Returns int32 [M, 1].

    Requires M % bm == 0 and n_bytes % byte_blk == 0 with byte_blk a
    multiple of unit_bytes.
    """
    cb, ub, cpu = _unit_params(field_bits, code_bits)
    m, n_bytes = packed.shape
    if byte_blk % ub or n_bytes % byte_blk:
        raise ValueError(
            f"byte_blk={byte_blk} must be a unit-aligned divisor of {n_bytes}")
    grid = (m // bm, n_bytes // byte_blk)

    lut_spec = pl.BlockSpec((byte_blk // ub, n_entries), lambda i, k: (k, 0))
    p_spec = pl.BlockSpec((bm, byte_blk), lambda i, k: (i, k))
    o_spec = pl.BlockSpec((bm, 1), lambda i, k: (i, 0))

    return pl.pallas_call(
        functools.partial(_elut_gemv_kernel, n_entries=n_entries, cb=cb,
                          ub=ub, cpu=cpu, lossless=lossless),
        grid=grid,
        in_specs=[lut_spec] * len(lut_planes) + [p_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((m, 1), jnp.int32),
        interpret=interpret,
    )(*lut_planes, packed)


def _elut_gemv_skip_kernel(*refs, n_entries: int, cb: int, ub: int, cpu: int,
                           lossless: bool, block_bytes: int):
    *lut_refs, p_ref, occ_ref, out_ref = refs
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    p = p_ref[...]
    occ = occ_ref[...]                    # [bm, blocks in this byte step]
    bu = block_bytes // ub                # LUT rows (units) per block
    for blk in range(p.shape[1] // block_bytes):

        @pl.when(jnp.any(occ[:, blk] != 0))
        def _live(blk=blk):
            ps = p[:, blk * block_bytes:(blk + 1) * block_bytes]
            codes_list = _unit_codes(ps, cb, ub, cpu)
            acc_lo, acc_hi = _gemv_block_acc(
                codes_list, lut_refs, slice(blk * bu, (blk + 1) * bu),
                n_entries, lossless)
            y32 = (acc_hi * 256 + acc_lo) if lossless else acc_lo
            out_ref[...] += y32[:, None]


@functools.partial(jax.jit, static_argnames=(
    "n_entries", "field_bits", "code_bits", "block_bytes", "bm", "byte_blk",
    "lossless", "interpret"))
def elut_lut_gemv_skip(
    lut_planes: tuple,
    packed: jax.Array,
    occ: jax.Array,
    *,
    n_entries: int,
    field_bits: int,
    block_bytes: int,
    code_bits: int = 0,
    bm: int = 128,
    byte_blk: int = 128,
    lossless: bool = True,
    interpret: bool = False,
) -> jax.Array:
    """Zero-skip variant of :func:`elut_lut_gemv`.  occ: uint8
    [M, n_bytes/block_bytes] occupancy plane.  Returns int32 [M, 1],
    bit-identical to the dense walk: in a skipped block every code is the
    all-zero-digit code, whose eLUT entry is dot(a, 0) = 0, so the dense
    walk would add exactly 0 there (DESIGN.md §11).

    Requires byte_blk % block_bytes == 0 on top of the
    :func:`elut_lut_gemv` tiling contract.
    """
    cb, ub, cpu = _unit_params(field_bits, code_bits)
    if byte_blk % block_bytes or block_bytes % ub:
        raise ValueError(
            f"byte_blk={byte_blk} must cover whole {block_bytes}-byte "
            f"occupancy blocks of whole {ub}-byte units")
    m, n_bytes = packed.shape
    grid = (m // bm, n_bytes // byte_blk)
    opb = byte_blk // block_bytes  # occupancy blocks per byte step

    lut_spec = pl.BlockSpec((byte_blk // ub, n_entries), lambda i, k: (k, 0))
    p_spec = pl.BlockSpec((bm, byte_blk), lambda i, k: (i, k))
    z_spec = pl.BlockSpec((bm, opb), lambda i, k: (i, k))
    o_spec = pl.BlockSpec((bm, 1), lambda i, k: (i, 0))

    return pl.pallas_call(
        functools.partial(_elut_gemv_skip_kernel, n_entries=n_entries, cb=cb,
                          ub=ub, cpu=cpu, lossless=lossless,
                          block_bytes=block_bytes),
        grid=grid,
        in_specs=[lut_spec] * len(lut_planes) + [p_spec, z_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((m, 1), jnp.int32),
        interpret=interpret,
    )(*lut_planes, packed, occ)


# ---------------------------------------------------------------------------
# True-LUT GEMV path with per-group weight scales
#
# Same compare-and-accumulate lookup, but the byte walk splits at scale-group
# boundaries: the int16 pack-and-unpack accumulation (acc_hi·256 + acc_lo)
# completes EXACTLY within each group before its single fp32 scale multiply —
# the lossless contract survives grouping because no scale ever touches a
# partial table entry, only a finished per-group int32 accumulator.
# ---------------------------------------------------------------------------


def _elut_gemv_grouped_kernel(*refs, n_entries: int, cb: int, ub: int,
                              cpu: int, lossless: bool, group_bytes: int):
    *lut_refs, p_ref, s_ref, out_ref = refs
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    p = p_ref[...]
    gu = group_bytes // ub                # LUT rows (units) per scale group
    acc = out_ref[...]
    for s in range(p.shape[1] // group_bytes):
        ps = p[:, s * group_bytes:(s + 1) * group_bytes]
        codes_list = _unit_codes(ps, cb, ub, cpu)
        acc_lo, acc_hi = _gemv_block_acc(
            codes_list, lut_refs, slice(s * gu, (s + 1) * gu),
            n_entries, lossless)
        y32 = (acc_hi * 256 + acc_lo) if lossless else acc_lo  # [bm] int32
        acc = acc + y32.astype(jnp.float32)[:, None] * s_ref[s, :][:, None]
    out_ref[...] = acc


@functools.partial(jax.jit, static_argnames=(
    "n_entries", "field_bits", "code_bits", "group_bytes", "bm", "byte_blk",
    "lossless", "interpret"))
def elut_lut_gemv_grouped(
    lut_planes: tuple,
    packed: jax.Array,
    scales: jax.Array,
    *,
    n_entries: int,
    field_bits: int,
    group_bytes: int,
    code_bits: int = 0,
    bm: int = 128,
    byte_blk: int = 128,
    lossless: bool = True,
    interpret: bool = False,
) -> jax.Array:
    """Grouped-scale variant of :func:`elut_lut_gemv`.  scales: fp32
    [K/G, M] group-major scale plane (G = group_bytes/unit_bytes · wpu
    weight columns).  Returns fp32 [M, 1] with the weight scales applied;
    the wrapper multiplies the activation scale (and the lossy table scale,
    which is global and therefore commutes out of the group sum).

    Requires byte_blk % group_bytes == 0 on top of the
    :func:`elut_lut_gemv` tiling contract.
    """
    cb, ub, cpu = _unit_params(field_bits, code_bits)
    if byte_blk % group_bytes or group_bytes % ub:
        raise ValueError(
            f"byte_blk={byte_blk} must cover whole scale groups of "
            f"{group_bytes} bytes of whole {ub}-byte units")
    m, n_bytes = packed.shape
    grid = (m // bm, n_bytes // byte_blk)
    gpb = byte_blk // group_bytes  # scale groups per byte block

    lut_spec = pl.BlockSpec((byte_blk // ub, n_entries), lambda i, k: (k, 0))
    p_spec = pl.BlockSpec((bm, byte_blk), lambda i, k: (i, k))
    s_spec = pl.BlockSpec((gpb, bm), lambda i, k: (k, i))
    o_spec = pl.BlockSpec((bm, 1), lambda i, k: (i, 0))

    return pl.pallas_call(
        functools.partial(_elut_gemv_grouped_kernel, n_entries=n_entries,
                          cb=cb, ub=ub, cpu=cpu, lossless=lossless,
                          group_bytes=group_bytes),
        grid=grid,
        in_specs=[lut_spec] * len(lut_planes) + [p_spec, s_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((m, 1), jnp.float32),
        interpret=interpret,
    )(*lut_planes, packed, scales.astype(jnp.float32))


# ---------------------------------------------------------------------------
# TL2 mirror-consolidated layout, folded into the parametric family
# (paper §3.1, Algorithm 4, TPU-adapted; formerly kernels/tl2_matmul.py).
#
# Contract: y_int32[N, M] = x_q[N, K] (int8) · W_t[M, K]^T, with W stored at
# **1.67 bpw**: a 4-bit index plane + a 1-bit sign plane per group of 3
# ternary weights (element-wise mirror consolidation + signed-unsigned
# weight splitting, paper §3.1.1–3.1.2), in the ``tl2k`` kernel layout
# (``repro.core.packing.tl2k_pack``).
#
# Decode per K-tile of G groups (all static lane slices, no interleaves):
#
#     lo = idx & 0xF          # indices of groups [0, G/2)
#     hi = idx >> 4           # indices of groups [G/2, G)
#     for bit in 0..7:        # sign plane bit covers groups [bit·G/8, …)
#         s   = (sign >> bit) & 1                     # [bm, G/8]
#         i_b = (lo | hi)[:, lane slice for bit]      # [bm, G/8]
#         v   = i_b·(1 - 2s) + 26·s                   # mirror decode (Eq. 5)
#         d0, d1, d2 = base-3 digits of v             # _code_digits(v, 3, 3)
#         acc += x0_b·d0ᵀ + x1_b·d1ᵀ + x2_b·d2ᵀ       # int8 MXU dots
#
# The group value v ∈ 0..26 is just a base-3 g=3 code, so the digit decode
# is the family's shared ``_code_digits`` — tl2 differs from the plain
# code-plane formats only in how the code array is *assembled* (mirror
# index + sign instead of packed fields).  The paper's 9/14-entry `vpshufb`
# tables have no TPU analogue (DESIGN.md §2); arithmetic decode replaces
# them while preserving the 1.67 bpw HBM format.
# ---------------------------------------------------------------------------


def _tl2_mirror_kernel(x0, x1, x2, idx_ref, sign_ref, out_ref, *, g_tile: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    idx = idx_ref[...].astype(jnp.int16)   # [bm, G/2] packed nibbles
    sign = sign_ref[...]                   # [bm, G/8] packed sign bits (uint8)
    lo = idx & 0xF
    hi = (idx >> 4) & 0xF
    w8 = g_tile // 8
    acc = out_ref[...]
    for b in range(8):
        s = ((sign >> b) & 1).astype(jnp.int16)                 # [bm, G/8]
        half = lo if b < 4 else hi
        off = (b % 4) * w8
        i_b = jax.lax.slice_in_dim(half, off, off + w8, axis=1)  # [bm, G/8]
        v = i_b * (1 - 2 * s) + 26 * s                           # 0..26
        lane0 = b * w8
        for x_ref, d16 in zip((x0, x1, x2), _code_digits(v, 3, 3)):
            d = d16.astype(jnp.int8) - 1
            xb = jax.lax.slice_in_dim(x_ref[...], lane0, lane0 + w8, axis=1)
            acc = acc + jax.lax.dot_general(
                xb, d, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.int32,
            )
    out_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("bn", "bm", "g_tile", "interpret"))
def tl2_mirror_matmul(
    x_planes: tuple[jax.Array, jax.Array, jax.Array],
    idx_plane: jax.Array,
    sign_plane: jax.Array,
    *,
    bn: int = 128,
    bm: int = 128,
    g_tile: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """x_planes: 3 × int8 [N, K/3] (digit-deinterleaved, tile order);
    idx_plane: uint8 [M, K/6]; sign_plane: uint8 [M, K/24] (tl2k layout).

    Returns int32 [N, M].  One grid k-step covers one g_tile-group K-tile
    (3·g_tile weights); VMEM per step ≈ bm·g_tile·(1/2 + 1/8) packed bytes +
    3·bn·g_tile activation bytes + bn·bm·4 accumulator bytes.

    K handling: requires K % (3·g_tile) == 0; general K uses block-fitting
    weight splitting (paper §3.1.2) — ops.py routes the tail through tl1.
    """
    n, g_total = x_planes[0].shape
    m = idx_plane.shape[0]
    grid = (n // bn, m // bm, g_total // g_tile)

    x_spec = pl.BlockSpec((bn, g_tile), lambda i, j, k: (i, k))
    i_spec = pl.BlockSpec((bm, g_tile // 2), lambda i, j, k: (j, k))
    s_spec = pl.BlockSpec((bm, g_tile // 8), lambda i, j, k: (j, k))
    o_spec = pl.BlockSpec((bn, bm), lambda i, j, k: (i, j))

    return pl.pallas_call(
        functools.partial(_tl2_mirror_kernel, g_tile=g_tile),
        grid=grid,
        in_specs=[x_spec, x_spec, x_spec, i_spec, s_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.int32),
        interpret=interpret,
    )(*x_planes, idx_plane, sign_plane)
