"""Parametric ELUT Pallas TPU kernels (paper §3 + Appendix ELUT, TPU-adapted).

One kernel family generated from ``(b, g, field_bits)`` replaces the three
near-duplicate base-3 kernels this repo used to carry (i2s_matmul,
tl1_matmul, lut_gemv):

  * :func:`elut_matmul` — fused decode+MAD: packed code bytes stream
    HBM→VMEM at the format's true bpw and are decoded on the VPU with
    shift/mask/div-mod-by-b (div/mod by a constant lowers to
    multiply-shift; power-of-two bases lower to pure shifts), then hit the
    MXU as int8 digit-plane dots.  Ternary (3, 2, 4) is bit-identical to
    the old tl1_matmul; (3, 1, 2) to i2s_matmul; (4, 2, 4) / (8, 2, 4|8)
    are the int2/int3 instances through the same code.

    Decode per byte column (wpb = g · 8/field_bits weights per byte):

        for field f in 0..8/field_bits-1:
            code = (p >> f·field_bits) & mask
            for digit position i in 0..g-1:
                D = (code // b^(g-1-i)) % b - b//2       # [bm, K/wpb]
                acc += X_{f·g+i} · D^T                    # int8 MXU dot

    where X_j[n, kb] = x[n, wpb·kb + j] are the deinterleaved activation
    planes produced once by the ops.py wrapper.

  * :func:`elut_lut_gemv` — the true *table-lookup* computation model for
    the extreme memory-bound batch-1 decode regime: the wrapper precomputes
    the C = b^g-entry eLUT per activation group (Phase 1 /
    ``packing.elut_build_lut``) and the kernel accumulates
    ``Σ_g LUT[g, code[m, g]]``.  No TPU `vpshufb` exists, so the lookup is
    a compare-and-accumulate contraction: for each code value c,
    ``(codes == c)`` is a 0/1 int8 mask multiplying LUT column c on the
    MXU — ~C/g = b^g/g more MXU work than MAD (tl1 4.5×, int2 8×,
    int3 32×), irrelevant when the MXU idles and HBM bytes are everything.

    Losslessness (paper §3.2.1) is parametric: eLUT entries of int8 groups
    need int16, so the lossless ``_1`` variant splits the int16 table into
    low/high byte planes, looks up twice, and recombines exactly
    (``acc_hi·256 + acc_lo`` — the **pack-and-unpack** technique); the
    lossy ``_0`` variant takes a single int8-requantized table.

Both paths have ``*_grouped`` variants for per-group weight scales
(DESIGN.md §2): the K walk splits at scale-group boundaries (``group_bytes``
packed bytes per group), each group's int32 partial finishes exactly, and
one fp32 multiply per (group, output) folds it into the fp32 accumulator —
the per-tensor kernels above are untouched, so ``group_scale_cols=None``
stays bit-identical.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


# ---------------------------------------------------------------------------
# Arithmetic-decode MAD path (GEMM regime)
# ---------------------------------------------------------------------------


def _elut_mad_kernel(*refs, b: int, g: int, field_bits: int):
    *x_refs, p_ref, out_ref = refs
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    fpb = 8 // field_bits
    mask = (1 << field_bits) - 1
    offset = b // 2
    p = p_ref[...].astype(jnp.int16)  # uint8 [bm, bkc] -> int16 for div/mod
    acc = out_ref[...]
    plane = 0
    for f in range(fpb):
        code = (p >> (f * field_bits)) & mask
        for i in range(g):
            d16 = (code // (b ** (g - 1 - i))) % b
            d = d16.astype(jnp.int8) - offset
            acc = acc + jax.lax.dot_general(
                x_refs[plane][...], d,
                (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.int32,
            )
            plane += 1
    out_ref[...] = acc


@functools.partial(jax.jit, static_argnames=(
    "b", "g", "field_bits", "bn", "bm", "bkc", "interpret"))
def elut_matmul(
    x_planes: tuple,
    packed: jax.Array,
    *,
    b: int,
    g: int,
    field_bits: int,
    bn: int = 128,
    bm: int = 128,
    bkc: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """x_planes: wpb × int8 [N, K/wpb] (deinterleaved, wpb = g·8/field_bits);
    packed: uint8 [M, K/wpb] ELUT code bytes.  Returns int32 [N, M].

    Requires N % bn == M % bm == (K/wpb) % bkc == 0 (the ops.py wrapper
    pads N; K alignment is the format's k_align).  Same tiling contract as
    the retired i2s/tl1 kernels: grid (N/bn, M/bm, Kbytes/bkc) with the
    contraction axis innermost and the int32 accumulator tile living in the
    output VMEM block across the k steps.
    """
    n, kb = x_planes[0].shape
    m = packed.shape[0]
    grid = (n // bn, m // bm, kb // bkc)

    x_spec = pl.BlockSpec((bn, bkc), lambda i, j, k: (i, k))
    p_spec = pl.BlockSpec((bm, bkc), lambda i, j, k: (j, k))
    o_spec = pl.BlockSpec((bn, bm), lambda i, j, k: (i, j))

    return pl.pallas_call(
        functools.partial(_elut_mad_kernel, b=b, g=g, field_bits=field_bits),
        grid=grid,
        in_specs=[x_spec] * len(x_planes) + [p_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.int32),
        interpret=interpret,
    )(*x_planes, packed)


# ---------------------------------------------------------------------------
# Arithmetic-decode MAD path with per-group weight scales
#
# The K reduction splits at scale-group boundaries (``group_bytes`` packed
# byte columns per group): each group's digit-plane dots accumulate into an
# exact int32 partial, which ONE fp32 multiply by the group's scale row then
# folds into the fp32 output tile — scale application at accumulator
# granularity, so the integer part of the computation stays as exact as the
# per-tensor kernel's.  The per-tensor kernels above are untouched
# (group_scale_cols=None stays bit-identical by construction).
# ---------------------------------------------------------------------------


def _elut_mad_grouped_kernel(*refs, b: int, g: int, field_bits: int,
                             group_bytes: int):
    *x_refs, p_ref, s_ref, out_ref = refs
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    fpb = 8 // field_bits
    mask = (1 << field_bits) - 1
    offset = b // 2
    p = p_ref[...].astype(jnp.int16)  # uint8 [bm, bkc] -> int16 for div/mod
    acc = out_ref[...]
    for s in range(p.shape[1] // group_bytes):
        sl = slice(s * group_bytes, (s + 1) * group_bytes)
        ps = p[:, sl]
        acc32 = None
        plane = 0
        for f in range(fpb):
            code = (ps >> (f * field_bits)) & mask
            for i in range(g):
                d16 = (code // (b ** (g - 1 - i))) % b
                d = d16.astype(jnp.int8) - offset
                part = jax.lax.dot_general(
                    x_refs[plane][:, sl], d,
                    (((1,), (1,)), ((), ())),
                    preferred_element_type=jnp.int32,
                )
                acc32 = part if acc32 is None else acc32 + part
                plane += 1
        acc = acc + acc32.astype(jnp.float32) * s_ref[s, :][None, :]
    out_ref[...] = acc


@functools.partial(jax.jit, static_argnames=(
    "b", "g", "field_bits", "group_bytes", "bn", "bm", "bkc", "interpret"))
def elut_matmul_grouped(
    x_planes: tuple,
    packed: jax.Array,
    scales: jax.Array,
    *,
    b: int,
    g: int,
    field_bits: int,
    group_bytes: int,
    bn: int = 128,
    bm: int = 128,
    bkc: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Grouped-scale variant of :func:`elut_matmul`.  scales: fp32
    [K/G, M] group-major scale plane (G = group_bytes · wpb weight columns
    per group).  Returns fp32 [N, M] with the weight scales applied (the
    wrapper multiplies the activation scale).

    Requires bkc % group_bytes == 0 (K blocks cover whole scale groups) on
    top of the :func:`elut_matmul` tiling contract.
    """
    if bkc % group_bytes != 0:
        raise ValueError(
            f"bkc={bkc} must cover whole scale groups of {group_bytes} bytes")
    n, kb = x_planes[0].shape
    m = packed.shape[0]
    grid = (n // bn, m // bm, kb // bkc)
    gpb = bkc // group_bytes  # scale groups per K block

    x_spec = pl.BlockSpec((bn, bkc), lambda i, j, k: (i, k))
    p_spec = pl.BlockSpec((bm, bkc), lambda i, j, k: (j, k))
    s_spec = pl.BlockSpec((gpb, bm), lambda i, j, k: (k, j))
    o_spec = pl.BlockSpec((bn, bm), lambda i, j, k: (i, j))

    return pl.pallas_call(
        functools.partial(_elut_mad_grouped_kernel, b=b, g=g,
                          field_bits=field_bits, group_bytes=group_bytes),
        grid=grid,
        in_specs=[x_spec] * len(x_planes) + [p_spec, s_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.float32),
        interpret=interpret,
    )(*x_planes, packed, scales.astype(jnp.float32))


# ---------------------------------------------------------------------------
# True-LUT GEMV path (batch-1 decode regime)
# ---------------------------------------------------------------------------


def _elut_gemv_kernel(*refs, n_entries: int, field_bits: int, lossless: bool):
    *lut_refs, p_ref, out_ref = refs
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    fpb = 8 // field_bits
    mask = (1 << field_bits) - 1
    p = p_ref[...].astype(jnp.int16)  # [bm, gb/fpb] packed code bytes
    acc = out_ref[...]
    for f, lut_ref in enumerate(lut_refs):
        codes = (p >> (f * field_bits)) & mask   # codes of field-f groups
        lut = lut_ref[...]                       # [gb/fpb, C] int32 (int16 range)
        for c in range(n_entries):
            m01 = (codes == c).astype(jnp.int8)              # [bm, gb/fpb]
            col = lut[:, c]                                   # [gb/fpb]
            if lossless:
                # pack-and-unpack: two int8-range lookups, recombined exactly.
                col_lo = (col & 0xFF).astype(jnp.int32)       # unsigned low byte
                col_hi = (col >> 8).astype(jnp.int32)         # arithmetic high
                acc_lo = jnp.dot(m01.astype(jnp.int32), col_lo,
                                 preferred_element_type=jnp.int32)
                acc_hi = jnp.dot(m01.astype(jnp.int32), col_hi,
                                 preferred_element_type=jnp.int32)
                acc = acc + (acc_hi * 256 + acc_lo)[:, None]
            else:
                acc = acc + jnp.dot(
                    m01.astype(jnp.int32), col,
                    preferred_element_type=jnp.int32,
                )[:, None]
    out_ref[...] = acc


@functools.partial(jax.jit, static_argnames=(
    "n_entries", "field_bits", "bm", "byte_blk", "lossless", "interpret"))
def elut_lut_gemv(
    lut_planes: tuple,
    packed: jax.Array,
    *,
    n_entries: int,
    field_bits: int,
    bm: int = 128,
    byte_blk: int = 128,
    lossless: bool = True,
    interpret: bool = False,
) -> jax.Array:
    """lut_planes: fpb × int32 [G/fpb, C] — the eLUT deinterleaved by packed
    field position (for tl1's 2-per-byte nibbles these are the even/odd group
    tables; a byte-wide code has a single table); packed: uint8 [M, G/fpb]
    code bytes (G = K/g groups).  Returns int32 [M, 1].

    Requires M % bm == 0 and (G/fpb) % byte_blk == 0.
    """
    m = packed.shape[0]
    n_bytes = packed.shape[1]
    grid = (m // bm, n_bytes // byte_blk)

    lut_spec = pl.BlockSpec((byte_blk, n_entries), lambda i, k: (k, 0))
    p_spec = pl.BlockSpec((bm, byte_blk), lambda i, k: (i, k))
    o_spec = pl.BlockSpec((bm, 1), lambda i, k: (i, 0))

    return pl.pallas_call(
        functools.partial(_elut_gemv_kernel, n_entries=n_entries,
                          field_bits=field_bits, lossless=lossless),
        grid=grid,
        in_specs=[lut_spec] * len(lut_planes) + [p_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((m, 1), jnp.int32),
        interpret=interpret,
    )(*lut_planes, packed)


# ---------------------------------------------------------------------------
# True-LUT GEMV path with per-group weight scales
#
# Same compare-and-accumulate lookup, but the byte walk splits at scale-group
# boundaries: the int16 pack-and-unpack accumulation (acc_hi·256 + acc_lo)
# completes EXACTLY within each group before its single fp32 scale multiply —
# the lossless contract survives grouping because no scale ever touches a
# partial table entry, only a finished per-group int32 accumulator.
# ---------------------------------------------------------------------------


def _elut_gemv_grouped_kernel(*refs, n_entries: int, field_bits: int,
                              lossless: bool, group_bytes: int):
    *lut_refs, p_ref, s_ref, out_ref = refs
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    fpb = 8 // field_bits
    mask = (1 << field_bits) - 1
    p = p_ref[...].astype(jnp.int16)  # [bm, byte_blk] packed code bytes
    acc = out_ref[...]
    for s in range(p.shape[1] // group_bytes):
        sl = slice(s * group_bytes, (s + 1) * group_bytes)
        ps = p[:, sl]
        acc_lo = None   # int32 per-group accumulators (exact)
        acc_hi = None
        for f, lut_ref in enumerate(lut_refs):
            codes = (ps >> (f * field_bits)) & mask
            lut = lut_ref[...][sl, :]           # [group_bytes, C] int32
            for c in range(n_entries):
                m01 = (codes == c).astype(jnp.int8)      # [bm, group_bytes]
                col = lut[:, c]                           # [group_bytes]
                if lossless:
                    # pack-and-unpack: two int8-range lookups, exact recombine
                    col_lo = (col & 0xFF).astype(jnp.int32)
                    col_hi = (col >> 8).astype(jnp.int32)
                    part_lo = jnp.dot(m01.astype(jnp.int32), col_lo,
                                      preferred_element_type=jnp.int32)
                    part_hi = jnp.dot(m01.astype(jnp.int32), col_hi,
                                      preferred_element_type=jnp.int32)
                    acc_lo = part_lo if acc_lo is None else acc_lo + part_lo
                    acc_hi = part_hi if acc_hi is None else acc_hi + part_hi
                else:
                    part = jnp.dot(m01.astype(jnp.int32), col,
                                   preferred_element_type=jnp.int32)
                    acc_lo = part if acc_lo is None else acc_lo + part
        y32 = (acc_hi * 256 + acc_lo) if lossless else acc_lo  # [bm] int32
        acc = acc + y32.astype(jnp.float32)[:, None] * s_ref[s, :][:, None]
    out_ref[...] = acc


@functools.partial(jax.jit, static_argnames=(
    "n_entries", "field_bits", "group_bytes", "bm", "byte_blk", "lossless",
    "interpret"))
def elut_lut_gemv_grouped(
    lut_planes: tuple,
    packed: jax.Array,
    scales: jax.Array,
    *,
    n_entries: int,
    field_bits: int,
    group_bytes: int,
    bm: int = 128,
    byte_blk: int = 128,
    lossless: bool = True,
    interpret: bool = False,
) -> jax.Array:
    """Grouped-scale variant of :func:`elut_lut_gemv`.  scales: fp32
    [K/G, M] group-major scale plane (G = group_bytes · wpb weight columns).
    Returns fp32 [M, 1] with the weight scales applied; the wrapper
    multiplies the activation scale (and the lossy table scale, which is
    global and therefore commutes out of the group sum).

    Requires byte_blk % group_bytes == 0 on top of the
    :func:`elut_lut_gemv` tiling contract.
    """
    if byte_blk % group_bytes != 0:
        raise ValueError(
            f"byte_blk={byte_blk} must cover whole scale groups of "
            f"{group_bytes} bytes")
    m = packed.shape[0]
    n_bytes = packed.shape[1]
    grid = (m // bm, n_bytes // byte_blk)
    gpb = byte_blk // group_bytes  # scale groups per byte block

    lut_spec = pl.BlockSpec((byte_blk, n_entries), lambda i, k: (k, 0))
    p_spec = pl.BlockSpec((bm, byte_blk), lambda i, k: (i, k))
    s_spec = pl.BlockSpec((gpb, bm), lambda i, k: (k, i))
    o_spec = pl.BlockSpec((bm, 1), lambda i, k: (i, 0))

    return pl.pallas_call(
        functools.partial(_elut_gemv_grouped_kernel, n_entries=n_entries,
                          field_bits=field_bits, lossless=lossless,
                          group_bytes=group_bytes),
        grid=grid,
        in_specs=[lut_spec] * len(lut_planes) + [p_spec, s_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((m, 1), jnp.float32),
        interpret=interpret,
    )(*lut_planes, packed, scales.astype(jnp.float32))
