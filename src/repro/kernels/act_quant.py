"""Fused per-tensor absmax int8 activation quantization (paper Phase 1).

Two Pallas passes (a global reduction cannot be one pass):
  1. tile-wise |x| max reduction -> partial maxima grid,
  2. quantize x with the combined scalar scale.

The scalar combine between passes is a trivial jnp.max on the tiny partial
array.  Matches ``repro.core.quant.absmax_int8`` bit-for-bit (same rounding).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ACT_QMAX = 127.0
EPS = 1e-6


def _absmax_kernel(x_ref, out_ref):
    out_ref[0, 0] = jnp.max(jnp.abs(x_ref[...].astype(jnp.float32)))


def _quant_kernel(x_ref, s_ref, out_ref):
    s = s_ref[0, 0]
    q = jnp.clip(jnp.round(x_ref[...].astype(jnp.float32) / s), -ACT_QMAX, ACT_QMAX)
    out_ref[...] = q.astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("bn", "bk", "interpret"))
def act_quant(
    x: jax.Array, *, bn: int = 256, bk: int = 512, interpret: bool = False
) -> tuple[jax.Array, jax.Array]:
    """fp [N, K] -> (int8 [N, K], fp32 scalar scale). N % bn == K % bk == 0."""
    n, k = x.shape
    grid = (n // bn, k // bk)
    partial_max = pl.pallas_call(
        _absmax_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bn, bk), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(grid, jnp.float32),
        interpret=interpret,
    )(x)
    scale = (jnp.maximum(jnp.max(partial_max), EPS) / ACT_QMAX).reshape(1, 1)
    x_q = pl.pallas_call(
        _quant_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bk), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bn, bk), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, k), jnp.int8),
        interpret=interpret,
    )(x, scale)
    return x_q, scale[0, 0]
