"""TL1 fused decode+matmul Pallas TPU kernel (paper §3.1, Algorithm 3, TPU-adapted).

Contract: y_int32[N, M] = x_q[N, K] (int8) · W_t[M, K]^T,
with W stored as 4-bit base-3 pair codes, 2 codes / byte (2 bpw in HBM).

Each byte packs codes (lo, hi) for weight pairs (w[4k], w[4k+1]) and
(w[4k+2], w[4k+3]); code = (w0+1)·3 + (w1+1) ∈ 0..8.  The split-plane
decode (DESIGN.md §2) extracts four digit planes with only shift / mask /
div-mod-by-3 VPU ops (div/mod by the constant 3 lowers to multiply-shift):

    lo = p & 0xF, hi = p >> 4
    D_0 = lo // 3 - 1   (w[4k])      D_1 = lo % 3 - 1   (w[4k+1])
    D_2 = hi // 3 - 1   (w[4k+2])    D_3 = hi % 3 - 1   (w[4k+3])
    y = Σ_i  X_i · D_i^T                    (four int8 MXU dots)

On CPU the paper realizes Algorithm 3 with a `vpshufb` 9-entry table; the
TPU has no lane table-lookup, so the enumerated-LUT step is replaced by
arithmetic base-3 decode — same element-wise format in HBM, same result.
The true-LUT formulation (one-hot × eLUT on the MXU) is kept in
``lut_gemv.py`` for the extreme memory-bound GEMV regime.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _tl1_kernel(x0, x1, x2, x3, p_ref, out_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    p = p_ref[...].astype(jnp.int16)  # uint8 [bm, bk4] -> int16 for div/mod
    lo = p & 0xF
    hi = (p >> 4) & 0xF
    planes = (lo // 3, lo % 3, hi // 3, hi % 3)
    acc = out_ref[...]
    for x_ref, d16 in zip((x0, x1, x2, x3), planes):
        d = d16.astype(jnp.int8) - 1
        acc = acc + jax.lax.dot_general(
            x_ref[...], d,
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.int32,
        )
    out_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("bn", "bm", "bk4", "interpret"))
def tl1_matmul(
    x_planes: tuple[jax.Array, jax.Array, jax.Array, jax.Array],
    packed: jax.Array,
    *,
    bn: int = 128,
    bm: int = 128,
    bk4: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """x_planes: 4 × int8 [N, K/4]; packed: uint8 [M, K/4] TL1 bytes.

    Returns int32 [N, M].  Same tiling contract as i2s_matmul.
    """
    n, k4 = x_planes[0].shape
    m = packed.shape[0]
    grid = (n // bn, m // bm, k4 // bk4)

    x_spec = pl.BlockSpec((bn, bk4), lambda i, j, k: (i, k))
    p_spec = pl.BlockSpec((bm, bk4), lambda i, j, k: (j, k))
    o_spec = pl.BlockSpec((bn, bm), lambda i, j, k: (i, j))

    return pl.pallas_call(
        _tl1_kernel,
        grid=grid,
        in_specs=[x_spec, x_spec, x_spec, x_spec, p_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.int32),
        interpret=interpret,
    )(*x_planes, packed)
