"""Jit'd public wrappers around the Pallas kernels.

Responsibilities: flatten batch dims, pad N to the tile size (zero activation
rows are exact no-ops), deinterleave activations into digit planes, dispatch
on the PackedWeight format, and apply the (s_x · s_w) rescale.  The kernels
themselves only ever see aligned tiles.

``interpret`` defaults to True off-TPU (the kernel body runs in Python on
CPU for validation); on a real TPU backend it compiles to Mosaic.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.qtensor import PackedWeight
from repro.kernels.act_quant import act_quant as _act_quant
from repro.kernels.i2s_matmul import i2s_matmul
from repro.kernels.lut_gemv import tl1_lut_gemv
from repro.kernels.ssd_scan import ssd_scan as _ssd_scan
from repro.kernels.tl1_matmul import tl1_matmul
from repro.kernels.tl2_matmul import tl2_matmul


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_rows(x: jax.Array, bn: int) -> tuple[jax.Array, int]:
    n = x.shape[0]
    pad = (-n) % bn
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    return x, n


def _pick(block: int, extent: int) -> int:
    """Largest tile ≤ block that divides extent (extents here are ≥ 8-aligned)."""
    b = min(block, extent)
    while extent % b:
        b //= 2
    return max(b, 1)


def _quad_planes(x: jax.Array) -> tuple[jax.Array, ...]:
    """[N, K] -> 4 × [N, K/4] with plane i holding x[:, i::4]."""
    n, k = x.shape
    r = x.reshape(n, k // 4, 4)
    return tuple(r[:, :, i] for i in range(4))


def _tri_planes(x: jax.Array) -> tuple[jax.Array, ...]:
    n, k = x.shape
    r = x.reshape(n, k // 3, 3)
    return tuple(r[:, :, i] for i in range(3))


def mpgemm_pallas(
    x_q: jax.Array,
    s_x: jax.Array,
    pw: PackedWeight,
    *,
    interpret: bool | None = None,
) -> jax.Array:
    """int8 [..., K] × PackedWeight [M, K] -> fp32 [..., M] (fused decode kernels)."""
    if interpret is None:
        interpret = _default_interpret()
    lead = x_q.shape[:-1]
    k = x_q.shape[-1]
    x2 = x_q.reshape(-1, k)
    m = pw.m

    if pw.fmt == "i2s":
        y32 = _i2s_like(x2, pw.planes["p"], m, i2s_matmul, interpret)
    elif pw.fmt == "tl1":
        y32 = _i2s_like(x2, pw.planes["p"], m, tl1_matmul, interpret)
    elif pw.fmt == "tl2k":
        y32 = _tl2k(x2, pw, interpret)
    else:
        raise ValueError(f"no pallas kernel for format {pw.fmt!r}")

    y = y32.astype(jnp.float32) * (jnp.asarray(s_x, jnp.float32) * pw.scale)
    return y.reshape(*lead, m)


def _i2s_like(x2, packed, m, kernel, interpret):
    bn = _pick(128, ((x2.shape[0] + 127) // 128) * 128)
    x2p, n = _pad_rows(x2, bn)
    planes = _quad_planes(x2p)
    k4 = planes[0].shape[1]
    y = kernel(
        planes, packed,
        bn=bn, bm=_pick(128, m), bk4=_pick(128, k4),
        interpret=interpret,
    )
    return y[:n]


def _tl2k(x2, pw, interpret):
    from repro.core import packing

    gt = packing.TL2K_GTILE
    y = None
    if pw.three_k:
        bn = _pick(128, ((x2.shape[0] + 127) // 128) * 128)
        x3, n = _pad_rows(x2[:, : pw.three_k], bn)
        planes = _tri_planes(x3)
        y = tl2_matmul(
            planes, pw.planes["idx"], pw.planes["sign"],
            bn=bn, bm=_pick(128, pw.m), g_tile=gt,
            interpret=interpret,
        )[:n]
    if pw.three_k < pw.k:
        tail = _i2s_like(x2[:, pw.three_k:], pw.planes["tail"], pw.m, tl1_matmul, interpret)
        y = tail if y is None else y + tail
    return y


def act_quant(x: jax.Array, *, interpret: bool | None = None):
    """fp [..., K] -> (int8 [..., K], fp32 scalar) via the fused Pallas pass."""
    if interpret is None:
        interpret = _default_interpret()
    lead = x.shape[:-1]
    k = x.shape[-1]
    x2 = x.reshape(-1, k)
    bn = _pick(256, ((x2.shape[0] + 255) // 256) * 256)
    x2p, n = _pad_rows(x2, bn)
    x_q, s = _act_quant(x2p, bn=bn, bk=_pick(512, k), interpret=interpret)
    return x_q[:n].reshape(*lead, k), s


def lut_gemv(
    x_q: jax.Array,
    s_x: jax.Array,
    pw: PackedWeight,
    *,
    lossless: bool = True,
    interpret: bool | None = None,
) -> jax.Array:
    """True-LUT decode GEMV (TL1_0/TL1_1): int8 [..., K] × tl1 [M, K] -> fp32 [..., M].

    The kernel itself is strictly single-row (the paper's batch-1 decode
    regime): any leading dims must flatten to N == 1.  Multi-row inputs are
    routed through the registry's batched LUT fallback (``tl*_lut``) instead
    of silently mis-tiling.
    """
    if interpret is None:
        interpret = _default_interpret()
    if pw.fmt != "tl1":
        raise ValueError(f"lut_gemv needs tl1 weights, got {pw.fmt!r}")
    k = x_q.shape[-1]
    if k != pw.k:
        raise ValueError(
            f"lut_gemv: activation K={k} does not match weight K={pw.k}")
    if k % 4 != 0:
        raise ValueError(f"lut_gemv needs K % 4 == 0, got K={k}")
    lead = x_q.shape[:-1]
    n = 1
    for d in lead:
        n *= int(d)
    if n != 1:
        # batched fallback via the registry: same LUT semantics, GEMM regime.
        from repro.core import dispatch

        name = "tl1_lut" if lossless else "tl1_lut_lossy"
        return dispatch.mpgemm(
            x_q, s_x, pw,
            dispatch.KernelPlan(gemv=name, gemm=name, interpret=interpret),
            _source="lut_gemv_fallback")
    s_x = jnp.asarray(s_x, jnp.float32)
    if s_x.size != 1:
        raise ValueError(
            f"lut_gemv needs a scalar activation scale, got shape {s_x.shape}")
    from repro.core import packing

    x1 = x_q.reshape(k)
    lut = packing.tl1_build_lut(x1[None, :])[0]  # [G, 9] int32
    s_lut = jnp.float32(1.0)
    if not lossless:
        s_lut = jnp.maximum(jnp.max(jnp.abs(lut)).astype(jnp.float32), 1.0) / 127.0
        lut = jnp.clip(jnp.round(lut / s_lut), -127, 127).astype(jnp.int32)
    lut_even, lut_odd = lut[0::2], lut[1::2]
    m = pw.m
    ghb = _pick(128, k // 4)  # bytes per k-step tile
    y32 = tl1_lut_gemv(
        lut_even, lut_odd, pw.planes["p"],
        bm=_pick(128, m), g_blk=2 * ghb,
        lossless=lossless, interpret=interpret,
    )[:, 0]
    y = y32.astype(jnp.float32) * (s_lut * s_x.reshape(()) * pw.scale)
    return y.reshape(*lead, m)


def ssd_scan(a_log, xbar, b, c, *, chunk: int = 64, interpret: bool | None = None):
    if interpret is None:
        interpret = _default_interpret()
    return _ssd_scan(a_log, xbar, b, c, chunk=chunk, interpret=interpret)
