"""Jit'd public wrappers around the Pallas kernels.

Responsibilities: flatten batch dims, pad N to the tile size (zero activation
rows are exact no-ops), deinterleave activations into digit planes, dispatch
on the PackedWeight's :class:`repro.core.formats.FormatSpec`, and apply the
(s_x · s_w) rescale.  The kernels themselves only ever see aligned tiles.

Every plain code-plane format (``spec.elut``: i2s, tl1, int2, int3, the
bit-contiguous ``_bc`` and zero-occupancy ``_z`` variants, …) runs the
parametric :mod:`repro.kernels.elut_matmul` family — its kernel bodies are
generated from the spec's ``(base, group, code width)``; there are no
per-format kernel files.  tl2k's mirror-consolidated sign+index kernel is
the ``tl2_mirror_matmul`` member of the same family, with the block-fitting
TwoK tail routed through the ternary ELUT instance.

Formats with an occupancy plane (``spec.occ_block``) route to the
``*_skip`` kernels, which consult the plane to skip all-zero K-blocks —
bit-identical to the dense walk (DESIGN.md §11); pass ``zero_skip=False``
to force the dense walk (the bench uses this for skip-vs-dense A/B).

``interpret`` defaults to True off-TPU (the kernel body runs in Python on
CPU for validation); on a real TPU backend it compiles to Mosaic.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import formats
from repro.core.qtensor import PackedWeight
from repro.kernels.act_quant import act_quant as _act_quant
from repro.kernels.elut_matmul import (elut_lut_gemv, elut_lut_gemv_grouped,
                                       elut_lut_gemv_skip, elut_matmul,
                                       elut_matmul_grouped, elut_matmul_skip,
                                       tl2_mirror_matmul)
from repro.kernels.ssd_scan import ssd_scan as _ssd_scan


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_rows(x: jax.Array, bn: int) -> tuple[jax.Array, int]:
    n = x.shape[0]
    pad = (-n) % bn
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    return x, n


def _pick(block: int, extent: int) -> int:
    """Largest tile ≤ block that divides extent (extents here are ≥ 8-aligned)."""
    b = min(block, extent)
    while extent % b:
        b //= 2
    return max(b, 1)


def _deinterleave(x: jax.Array, w: int) -> tuple[jax.Array, ...]:
    """[N, K] -> w × [N, K/w] with plane j holding x[:, j::w]."""
    n, k = x.shape
    r = x.reshape(n, k // w, w)
    return tuple(r[:, :, j] for j in range(w))


def _tri_planes(x: jax.Array) -> tuple[jax.Array, ...]:
    return _deinterleave(x, 3)


def mpgemm_pallas(
    x_q: jax.Array,
    s_x: jax.Array,
    pw: PackedWeight,
    *,
    interpret: bool | None = None,
    zero_skip: bool = True,
) -> jax.Array:
    """int8 [..., K] × PackedWeight [M, K] -> fp32 [..., M] (fused decode kernels).

    ``zero_skip=False`` forces the dense K walk for occupancy (``_z``)
    formats — the outputs are bit-identical either way; the flag only
    exists so the bench can time skip vs dense on the same operands.
    """
    if interpret is None:
        interpret = _default_interpret()
    lead = x_q.shape[:-1]
    k = x_q.shape[-1]
    x2 = x_q.reshape(-1, k)
    m = pw.m
    spec = formats.get(pw.fmt)

    if spec.elut and spec.group_scale_cols:
        # grouped kernel applies the [K//G, M] weight scales in-kernel
        yf = _elut_mad_grouped(x2, pw.planes["p"], pw.scale, m, spec, interpret)
        y = yf * jnp.asarray(s_x, jnp.float32)
        return y.reshape(*lead, m)
    if spec.elut and spec.occ_block and zero_skip:
        y32 = _elut_mad_skip(x2, pw.planes["p"], pw.planes["occ"], m, spec,
                             interpret)
    elif spec.elut:
        y32 = _elut_mad(x2, pw.planes["p"], m, spec, interpret)
    elif pw.fmt == "tl2k":
        y32 = _tl2k(x2, pw, interpret)
    else:
        raise ValueError(f"no pallas kernel for format {pw.fmt!r}")

    y = y32.astype(jnp.float32) * (jnp.asarray(s_x, jnp.float32) * pw.scale)
    return y.reshape(*lead, m)


def _unit_blk(block: int, ub: int, kb: int) -> int:
    """Largest K byte-block ≤ ~``block`` covering whole ``ub``-byte units."""
    return ub * _pick(max(1, block // ub), kb // ub)


def _block_bytes(spec) -> int:
    """Packed bytes per occupancy block (occ_block weight columns)."""
    return spec.occ_block // spec.weights_per_unit * spec.unit_bytes


def _elut_mad(x2, packed, m, spec, interpret):
    wpu = spec.weights_per_unit
    bn = _pick(128, ((x2.shape[0] + 127) // 128) * 128)
    x2p, n = _pad_rows(x2, bn)
    planes = _deinterleave(x2p, wpu)
    kb = packed.shape[1]
    y = elut_matmul(
        planes, packed,
        b=spec.base, g=spec.group, field_bits=spec.field_bits,
        code_bits=spec.code_bits,
        bn=bn, bm=_pick(128, m), bkc=_unit_blk(128, spec.unit_bytes, kb),
        interpret=interpret,
    )
    return y[:n]


def _elut_mad_skip(x2, packed, occ, m, spec, interpret):
    wpu = spec.weights_per_unit
    bb = _block_bytes(spec)
    bn = _pick(128, ((x2.shape[0] + 127) // 128) * 128)
    x2p, n = _pad_rows(x2, bn)
    planes = _deinterleave(x2p, wpu)
    kb = packed.shape[1]
    y = elut_matmul_skip(
        planes, packed, occ,
        b=spec.base, g=spec.group, field_bits=spec.field_bits,
        code_bits=spec.code_bits, block_bytes=bb,
        bn=bn, bm=_pick(128, m), bkc=_unit_blk(128, bb, kb),
        interpret=interpret,
    )
    return y[:n]


def _group_blk(block: int, group_bytes: int, n_groups: int) -> int:
    """Largest K-block ≤ ~``block`` bytes covering whole scale groups."""
    return group_bytes * _pick(max(1, block // group_bytes), n_groups)


def _elut_mad_grouped(x2, packed, scales, m, spec, interpret):
    wpu = spec.weights_per_unit
    group_bytes = spec.group_scale_cols // wpu * spec.unit_bytes
    bn = _pick(128, ((x2.shape[0] + 127) // 128) * 128)
    x2p, n = _pad_rows(x2, bn)
    planes = _deinterleave(x2p, wpu)
    kb = packed.shape[1]
    y = elut_matmul_grouped(
        planes, packed, scales,
        b=spec.base, g=spec.group, field_bits=spec.field_bits,
        code_bits=spec.code_bits, group_bytes=group_bytes,
        bn=bn, bm=_pick(128, m),
        bkc=_group_blk(128, group_bytes, kb // group_bytes),
        interpret=interpret,
    )
    return y[:n]


def _tl1_tail(x2, packed, m, interpret):
    return _elut_mad(x2, packed, m, formats.get("tl1"), interpret)


def _tl2k(x2, pw, interpret):
    from repro.core import packing

    gt = packing.TL2K_GTILE
    y = None
    if pw.three_k:
        bn = _pick(128, ((x2.shape[0] + 127) // 128) * 128)
        x3, n = _pad_rows(x2[:, : pw.three_k], bn)
        planes = _tri_planes(x3)
        y = tl2_mirror_matmul(
            planes, pw.planes["idx"], pw.planes["sign"],
            bn=bn, bm=_pick(128, pw.m), g_tile=gt,
            interpret=interpret,
        )[:n]
    if pw.three_k < pw.k:
        tail = _tl1_tail(x2[:, pw.three_k:], pw.planes["tail"], pw.m, interpret)
        y = tail if y is None else y + tail
    return y


def act_quant(x: jax.Array, *, interpret: bool | None = None):
    """fp [..., K] -> (int8 [..., K], fp32 scalar) via the fused Pallas pass."""
    if interpret is None:
        interpret = _default_interpret()
    lead = x.shape[:-1]
    k = x.shape[-1]
    x2 = x.reshape(-1, k)
    bn = _pick(256, ((x2.shape[0] + 255) // 256) * 256)
    x2p, n = _pad_rows(x2, bn)
    x_q, s = _act_quant(x2p, bn=bn, bk=_pick(512, k), interpret=interpret)
    return x_q[:n].reshape(*lead, k), s


def lut_gemv(
    x_q: jax.Array,
    s_x: jax.Array,
    pw: PackedWeight,
    *,
    lossless: bool = True,
    interpret: bool | None = None,
    zero_skip: bool = True,
) -> jax.Array:
    """True-LUT decode GEMV: int8 [..., K] × ELUT-format [M, K] -> fp32 [..., M].

    Parametric over any grouped ELUT format (tl1 = (3,2), int2 = (4,2),
    int3 = (8,2)); ``lossless`` selects the int16 pack-and-unpack (``_1``)
    vs int8-requantized-table (``_0``) variants.  The kernel itself is
    strictly single-row (the paper's batch-1 decode regime): any leading
    dims must flatten to N == 1.  Multi-row inputs are routed through the
    registry's batched LUT fallback (the XLA one-hot contraction) instead
    of silently mis-tiling.
    """
    if interpret is None:
        interpret = _default_interpret()
    spec = formats.REGISTRY.get(pw.fmt)
    if spec is None or not spec.supports_lut_gemv():
        raise ValueError(
            f"lut_gemv needs a grouped ELUT format "
            f"{formats.lut_gemv_formats()}, got {pw.fmt!r} weights")
    k = x_q.shape[-1]
    if k != pw.k:
        raise ValueError(
            f"lut_gemv: activation K={k} does not match weight K={pw.k}")
    if k % spec.k_align != 0:
        raise ValueError(
            f"lut_gemv needs K % {spec.k_align} == 0 for {pw.fmt}, got K={k}")
    lead = x_q.shape[:-1]
    n = 1
    for d in lead:
        n *= int(d)
    if n != 1:
        # batched fallback via the registry: same LUT semantics, GEMM regime.
        from repro.core import dispatch

        name = f"{pw.fmt}_lut" + ("" if lossless else "_lossy")
        return dispatch.mpgemm(
            x_q, s_x, pw,
            dispatch.KernelPlan(gemv=name, gemm=name, interpret=interpret),
            _source="lut_gemv_fallback")
    s_x = jnp.asarray(s_x, jnp.float32)
    if s_x.size != 1:
        raise ValueError(
            f"lut_gemv needs a scalar activation scale, got shape {s_x.shape}")
    from repro.core import elut

    x1 = x_q.reshape(k)
    lut = elut.build_lut(x1[None, :], spec.base, spec.group)[0]  # [G, C] int32
    s_lut = jnp.float32(1.0)
    if not lossless:
        lut, s_lut = elut.quantize_lut(lut)
    cpu = spec.codes_per_unit
    lut_planes = tuple(lut[c::cpu] for c in range(cpu))
    m = pw.m
    n_bytes = pw.planes["p"].shape[1]
    if spec.group_scale_cols:
        group_bytes = spec.group_scale_cols // spec.weights_per_unit * spec.unit_bytes
        yf = elut_lut_gemv_grouped(
            lut_planes, pw.planes["p"], pw.scale,
            n_entries=spec.lut_size, field_bits=spec.field_bits,
            code_bits=spec.code_bits, group_bytes=group_bytes,
            bm=_pick(128, m),
            byte_blk=_group_blk(128, group_bytes, n_bytes // group_bytes),
            lossless=lossless, interpret=interpret,
        )[:, 0]
        # the lossy table scale is global, so it commutes out of the group sum
        y = yf * (s_lut * s_x.reshape(()))
        return y.reshape(*lead, m)
    if spec.occ_block and zero_skip:
        bb = _block_bytes(spec)
        y32 = elut_lut_gemv_skip(
            lut_planes, pw.planes["p"], pw.planes["occ"],
            n_entries=spec.lut_size, field_bits=spec.field_bits,
            code_bits=spec.code_bits, block_bytes=bb,
            bm=_pick(128, m), byte_blk=_unit_blk(128, bb, n_bytes),
            lossless=lossless, interpret=interpret,
        )[:, 0]
    else:
        y32 = elut_lut_gemv(
            lut_planes, pw.planes["p"],
            n_entries=spec.lut_size, field_bits=spec.field_bits,
            code_bits=spec.code_bits,
            bm=_pick(128, m), byte_blk=_unit_blk(128, spec.unit_bytes, n_bytes),
            lossless=lossless, interpret=interpret,
        )[:, 0]
    y = y32.astype(jnp.float32) * (s_lut * s_x.reshape(()) * pw.scale)
    return y.reshape(*lead, m)


def ssd_scan(a_log, xbar, b, c, *, chunk: int = 64, interpret: bool | None = None):
    if interpret is None:
        interpret = _default_interpret()
    return _ssd_scan(a_log, xbar, b, c, chunk=chunk, interpret=interpret)
