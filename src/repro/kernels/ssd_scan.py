"""Mamba2 SSD chunked-scan Pallas TPU kernel (for the mamba2-1.3b arch).

State-space duality (arXiv:2405.21060): within a chunk of Q steps the
recurrence  h_t = a_t·h_{t-1} + B_t ⊗ x̄_t,  y_t = C_t·h_t  is computed as a
decay-masked attention (MXU-friendly), and a [P, S] state carries between
chunks.  The per-(batch·head) state lives in a VMEM scratch buffer that
persists across the sequential chunk grid steps.

This is activation math — the ternary technique applies to the surrounding
in/out projections (DESIGN.md §Arch-applicability), so the kernel is fp32.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(alog_ref, xbar_ref, b_ref, c_ref, y_ref, h_ref):
    nc = pl.program_id(1)

    @pl.when(nc == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = alog_ref[0]                     # [Q] log decay (dt·A, ≤ 0)
    la = jnp.cumsum(a)                  # inclusive cumulative log decay
    xb = xbar_ref[0]                    # [Q, P]
    bm = b_ref[0]                       # [Q, S]
    cm = c_ref[0]                       # [Q, S]
    q = a.shape[0]

    # Intra-chunk: decay-masked attention on the MXU.
    scores = jnp.dot(cm, bm.T, preferred_element_type=jnp.float32)  # [Q, Q]
    decay = jnp.exp(la[:, None] - la[None, :])
    row = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    att = jnp.where(row >= col, scores * decay, 0.0)
    y_intra = jnp.dot(att, xb, preferred_element_type=jnp.float32)  # [Q, P]

    # Inter-chunk: contribution of the carried state.
    h = h_ref[...]                                                  # [P, S]
    y_inter = jnp.exp(la)[:, None] * jnp.dot(cm, h.T)               # [Q, P]
    y_ref[0] = y_intra + y_inter

    # State update: h' = a_chunk·h + Σ_j (Π_{k>j} a_k) x̄_j ⊗ B_j.
    w = jnp.exp(la[-1] - la)                                        # [Q]
    h_ref[...] = jnp.exp(la[-1]) * h + jnp.dot((xb * w[:, None]).T, bm)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(
    a_log: jax.Array,   # [BH, L]    log decay per step
    xbar: jax.Array,    # [BH, L, P] dt-scaled inputs
    b: jax.Array,       # [BH, L, S]
    c: jax.Array,       # [BH, L, S]
    *,
    chunk: int = 64,
    interpret: bool = False,
) -> jax.Array:
    """Returns y [BH, L, P].  Requires L % chunk == 0."""
    bh, L = a_log.shape
    p = xbar.shape[-1]
    s = b.shape[-1]
    grid = (bh, L // chunk)

    return pl.pallas_call(
        _ssd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk), lambda i, k: (i, k)),
            pl.BlockSpec((1, chunk, p), lambda i, k: (i, k, 0)),
            pl.BlockSpec((1, chunk, s), lambda i, k: (i, k, 0)),
            pl.BlockSpec((1, chunk, s), lambda i, k: (i, k, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, p), lambda i, k: (i, k, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, L, p), jnp.float32),
        scratch_shapes=[pltpu.VMEM((p, s), jnp.float32)],
        interpret=interpret,
    )(a_log, xbar, b, c)
