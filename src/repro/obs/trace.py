"""Span-based tracer (DESIGN.md §9): nested spans, structured events,
Chrome-trace / Perfetto JSON export.

One :class:`Tracer` collects *complete* spans (``ph: "X"``) and *instant*
structured events (``ph: "i"``) in the Chrome trace-event format that
Perfetto (https://ui.perfetto.dev) and ``chrome://tracing`` open directly.
Spans nest per thread via a thread-local stack; the tracer is thread-safe
(one lock guards the shared record lists) and takes an injectable clock so
the serving engine's virtual-clock tests can assert exact span trees with
exact timestamps.

Disabled tracing is ZERO-overhead by construction: :data:`NULL_TRACER`
returns one shared no-op span object from every :meth:`Tracer.span` call —
no allocation, no clock read, no lock — so the engine hot path can be
instrumented unconditionally.
"""

from __future__ import annotations

import json
import threading
import time


class Span:
    """One open span.  Use as a context manager; ``set()`` attaches args,
    ``event()`` records an instant event nested under this span."""

    __slots__ = ("_tracer", "name", "args", "t0", "t1", "tid",
                 "children", "events")

    def __init__(self, tracer: "Tracer", name: str, args: dict):
        self._tracer = tracer
        self.name = name
        self.args = args
        self.t0 = self.t1 = 0.0
        self.tid = 0
        self.children: list = []   # closed child Spans, in open order
        self.events: list = []     # (ts, name, args) instants under this span

    def set(self, **args) -> "Span":
        self.args.update(args)
        return self

    def event(self, name: str, **args) -> None:
        self._tracer.event(name, **args)

    def __enter__(self) -> "Span":
        self._tracer._open(self)
        return self

    def __exit__(self, *exc) -> bool:
        self._tracer._close(self)
        return False


class _NullSpan:
    """The shared do-nothing span (see module docstring)."""

    __slots__ = ()

    def set(self, **args) -> "_NullSpan":
        return self

    def event(self, name: str, **args) -> None:
        pass

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False


NULL_SPAN = _NullSpan()


class NullTracer:
    """API-compatible disabled tracer."""

    enabled = False

    def span(self, name: str, **args):
        return NULL_SPAN

    def event(self, name: str, **args) -> None:
        pass


NULL_TRACER = NullTracer()


class Tracer:
    """Collecting tracer.  ``clock`` returns seconds (monotone); the engine
    passes its own (possibly virtual) clock so trace timestamps share the
    timeline of the serving telemetry."""

    enabled = True

    def __init__(self, clock=time.perf_counter):
        self._clock = clock
        self._lock = threading.Lock()
        self._local = threading.local()
        self._roots: list[Span] = []      # closed top-level spans, open order
        self._orphans: list = []          # events emitted outside any span

    # -- recording ----------------------------------------------------------

    def _stack(self) -> list:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    def span(self, name: str, **args) -> Span:
        return Span(self, name, args)

    def _open(self, span: Span) -> None:
        span.t0 = self._clock()
        span.tid = threading.get_ident()
        self._stack().append(span)

    def _close(self, span: Span) -> None:
        span.t1 = self._clock()
        st = self._stack()
        # tolerate mis-nested exits instead of corrupting the stack
        if st and st[-1] is span:
            st.pop()
        elif span in st:
            st.remove(span)
        with self._lock:
            if st:
                st[-1].children.append(span)
            else:
                self._roots.append(span)

    def event(self, name: str, **args) -> None:
        ts = self._clock()
        st = self._stack()
        with self._lock:
            if st:
                st[-1].events.append((ts, name, args))
            else:
                self._orphans.append((ts, name, args, threading.get_ident()))

    # -- export -------------------------------------------------------------

    def span_tree(self) -> list:
        """Closed spans as nested dicts — what the tests assert against:
        ``{"name", "args", "events": [names], "children": [...]}``."""
        def node(s: Span) -> dict:
            return {"name": s.name, "args": dict(s.args),
                    "t0": s.t0, "t1": s.t1,
                    "events": [n for _, n, _ in s.events],
                    "children": [node(c) for c in s.children]}

        with self._lock:
            return [node(s) for s in self._roots]

    def chrome_events(self) -> list:
        """Flatten to Chrome trace-event dicts (ts/dur in µs)."""
        out: list = []

        def emit(s: Span) -> None:
            out.append({"name": s.name, "ph": "X", "ts": s.t0 * 1e6,
                        "dur": max(s.t1 - s.t0, 0.0) * 1e6,
                        "pid": 0, "tid": s.tid, "args": s.args})
            for ts, name, args in s.events:
                out.append({"name": name, "ph": "i", "ts": ts * 1e6,
                            "pid": 0, "tid": s.tid, "s": "t", "args": args})
            for c in s.children:
                emit(c)

        with self._lock:
            for s in self._roots:
                emit(s)
            for ts, name, args, tid in self._orphans:
                out.append({"name": name, "ph": "i", "ts": ts * 1e6,
                            "pid": 0, "tid": tid, "s": "t", "args": args})
        return out

    def save(self, path: str) -> str:
        """Write ``{"traceEvents": [...]}`` — load in Perfetto as-is."""
        with open(path, "w") as f:
            json.dump({"traceEvents": self.chrome_events(),
                       "displayTimeUnit": "ms"}, f, indent=1, default=str)
        return path
