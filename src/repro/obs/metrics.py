"""Counters / gauges / histograms registry (DESIGN.md §9).

A tiny in-process metrics registry in the Prometheus data model: named
series with optional labels, three instrument kinds, a structured
``snapshot()`` (what ``--metrics-json`` persists) and a Prometheus
text-exposition ``to_prometheus()`` snapshot.  No numpy / jax imports —
importable from anywhere, like ``repro.serve.metrics``.

:data:`NULL_METRICS` is the disabled registry: every instrument it hands
out is a shared no-op, so unconditional instrumentation costs nothing
(mirrors ``trace.NULL_TRACER``).
"""

from __future__ import annotations

import threading

# histogram default: log2-spaced second buckets, µs-ish to minutes
DEFAULT_BUCKETS = (1e-6, 1e-5, 1e-4, 1e-3, 1e-2, 0.1, 1.0, 10.0, 60.0)


class Counter:
    """Monotone counter."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        if v < 0:
            raise ValueError(f"counter increment must be >= 0, got {v}")
        self.value += v


class Gauge:
    """Point-in-time value."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def inc(self, v: float = 1.0) -> None:
        self.value += v


class Histogram:
    """Cumulative-bucket histogram (Prometheus semantics: each bucket
    counts observations ≤ its upper bound; +Inf is implicit via count)."""

    __slots__ = ("bounds", "bucket_counts", "count", "sum")

    def __init__(self, bounds=DEFAULT_BUCKETS):
        self.bounds = tuple(bounds)
        self.bucket_counts = [0] * len(self.bounds)
        self.count = 0
        self.sum = 0.0

    def observe(self, v: float) -> None:
        self.count += 1
        self.sum += v
        for i, b in enumerate(self.bounds):
            if v <= b:
                self.bucket_counts[i] += 1

    def cumulative(self) -> list:
        """[(upper_bound, cumulative_count)] incl. the +Inf bucket."""
        return [*zip(self.bounds, self.bucket_counts), ("+Inf", self.count)]


class _NullInstrument:
    __slots__ = ()
    value = 0.0

    def inc(self, v: float = 1.0) -> None:
        pass

    def set(self, v: float) -> None:
        pass

    def observe(self, v: float) -> None:
        pass


_NULL_INSTRUMENT = _NullInstrument()


class NullMetrics:
    enabled = False

    def counter(self, name: str, **labels):
        return _NULL_INSTRUMENT

    def gauge(self, name: str, **labels):
        return _NULL_INSTRUMENT

    def histogram(self, name: str, buckets=None, **labels):
        return _NULL_INSTRUMENT


NULL_METRICS = NullMetrics()


def series_key(name: str, labels: dict) -> str:
    """Prometheus-style series identity: ``name{a="1",b="x"}``."""
    if not labels:
        return name
    inner = ",".join(f'{k}="{labels[k]}"' for k in sorted(labels))
    return f"{name}{{{inner}}}"


class MetricsRegistry:
    """Get-or-create instrument registry; thread-safe."""

    enabled = True

    def __init__(self):
        self._lock = threading.Lock()
        # kind -> series key -> instrument; name kinds are exclusive
        self._series: dict[str, dict] = {"counter": {}, "gauge": {},
                                         "histogram": {}}
        self._kinds: dict[str, str] = {}  # metric name -> kind

    def _get(self, kind: str, name: str, labels: dict, make):
        key = series_key(name, labels)
        with self._lock:
            prior = self._kinds.setdefault(name, kind)
            if prior != kind:
                raise ValueError(
                    f"metric {name!r} already registered as a {prior}")
            table = self._series[kind]
            inst = table.get(key)
            if inst is None:
                inst = table[key] = make()
            return inst

    def counter(self, name: str, **labels) -> Counter:
        return self._get("counter", name, labels, Counter)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get("gauge", name, labels, Gauge)

    def histogram(self, name: str, buckets=None, **labels) -> Histogram:
        return self._get("histogram", name, labels,
                         lambda: Histogram(buckets or DEFAULT_BUCKETS))

    # -- export -------------------------------------------------------------

    def snapshot(self) -> dict:
        """Structured dump: the ``--metrics-json`` payload."""
        with self._lock:
            return {
                "counters": {k: c.value
                             for k, c in self._series["counter"].items()},
                "gauges": {k: g.value
                           for k, g in self._series["gauge"].items()},
                "histograms": {
                    k: {"count": h.count, "sum": h.sum,
                        "buckets": [[str(b), n] for b, n in h.cumulative()]}
                    for k, h in self._series["histogram"].items()},
            }

    def to_prometheus(self) -> str:
        """Prometheus text exposition format, one TYPE line per metric."""
        lines: list = []
        with self._lock:
            for kind in ("counter", "gauge"):
                typed: set = set()
                for key, inst in sorted(self._series[kind].items()):
                    name = key.split("{", 1)[0]
                    if name not in typed:
                        typed.add(name)
                        lines.append(f"# TYPE {name} {kind}")
                    lines.append(f"{key} {inst.value:g}")
            for key, h in sorted(self._series["histogram"].items()):
                name, _, rest = key.partition("{")
                labels = rest[:-1] if rest else ""
                lines.append(f"# TYPE {name} histogram")
                for b, n in h.cumulative():
                    le = f'le="{b}"'
                    inner = f"{labels},{le}" if labels else le
                    lines.append(f"{name}_bucket{{{inner}}} {n}")
                sfx = f"{{{labels}}}" if labels else ""
                lines.append(f"{name}_sum{sfx} {h.sum:g}")
                lines.append(f"{name}_count{sfx} {h.count}")
        return "\n".join(lines) + "\n"
