"""Jit-aware kernel timing + measured-vs-predicted attribution (DESIGN.md §9).

mpGEMM dispatch happens at TRACE time: inside a jitted engine step there is
no per-call host clock to read, and a fence inside the trace would change
the program.  So attribution works at the jit boundary instead:

* every jitted engine callable is wrapped in an :class:`InstrumentedFn`.
  The wrapper detects a jit trace by the dispatch decision-log delta around
  the call (decisions are recorded at trace time only) and captures the
  traced call's *keyset* — a multiset of
  ``(kernel, fmt, M, K, N-bucket)`` dispatch keys — into a module-level
  registry keyed by (underlying callable, argument shape signature).  This
  capture runs even with profiling OFF (two integer reads per call) so a
  later profiled engine can attribute executions of executables compiled
  before profiling was enabled;
* with a :class:`KernelProfiler` attached, the wrapper fences the call
  (``jax.block_until_ready``) and books the wall time: a call that traced
  is a COMPILE call (compile+first-execute wall, attributed separately);
  a warm call is an EXECUTE call whose wall time is split across the
  keyset's keys proportionally to the dispatch cost model's per-call hint
  — measured time per key is therefore a *cost-share attribution of the
  fenced step wall*, not an isolated kernel timer (the honest best
  available under jit; see the DESIGN.md §9 caveats);
* :meth:`KernelProfiler.report` emits the ``measured_vs_predicted`` table:
  per key — calls, compile vs execute seconds, measured µs/call and GB/s
  next to the cost model's predicted µs, HBM bytes and MXU inflation.
  This is the seed data for the ROADMAP's measured-autotune item.
"""

from __future__ import annotations

import collections
import dataclasses
import time

import jax

from repro.core import dispatch

# (underlying callable, arg shape signature) -> Counter of dispatch keys
# captured from that call's jit trace.  Module-level on purpose: jitted
# callables are shared per (cfg, paged) across engines, so their keysets
# must be too.
_KEYSETS: dict = {}


def _sig(args) -> tuple:
    """Shape/dtype signature of a call's array leaves — what jit keys on."""
    out = []
    for leaf in jax.tree_util.tree_leaves(args):
        shape = getattr(leaf, "shape", None)
        if shape is not None:
            out.append((tuple(shape), str(getattr(leaf, "dtype", ""))))
        else:
            out.append(repr(leaf))
    return tuple(out)


def decision_key(d) -> tuple:
    """A dispatch Decision folded to its attribution key."""
    return (d.kernel, d.fmt, d.m, d.k, dispatch.n_bucket(d.n))


def _keyset(decisions) -> collections.Counter:
    return collections.Counter(decision_key(d) for d in decisions)


def predicted_us(key: tuple, occupancy: float = 1.0) -> float:
    kernel, fmt, m, k, nb = key
    return dispatch.REGISTRY[kernel].cost(fmt, nb, k, m, occupancy)


def predicted_hbm_bytes(key: tuple, occupancy: float = 1.0) -> float:
    """``occupancy`` = the weight's nonzero-block fraction
    (``PackedWeight.occupancy()``): zero-skip kernels on ``_z`` formats
    stream proportionally fewer code-plane bytes (DESIGN.md §11)."""
    kernel, fmt, m, k, nb = key
    return dispatch.REGISTRY[kernel].hbm_bytes(fmt, nb, k, m, occupancy)


@dataclasses.dataclass
class KernelStat:
    """Accumulated attribution for one (kernel, fmt, M, K, N-bucket) key."""

    calls: int = 0            # executed mpGEMM call sites × warm executions
    compile_calls: int = 0    # call sites seen in compile (tracing) calls
    compile_s: float = 0.0    # attributed compile+first-execute wall
    execute_s: float = 0.0    # attributed steady-state wall


class KernelProfiler:
    """Accumulates per-key attribution; injectable clock for determinism."""

    def __init__(self, clock=time.perf_counter):
        self.clock = clock
        self.stats: dict[tuple, KernelStat] = {}
        self.unattributed_s = 0.0  # fenced wall with no known keyset

    def record(self, keys: collections.Counter | None, dt: float,
               *, compiled: bool) -> None:
        if not keys:
            self.unattributed_s += dt
            return
        total = sum(predicted_us(k) * c for k, c in keys.items()) or 1.0
        for key, cnt in keys.items():
            share = dt * (predicted_us(key) * cnt / total)
            st = self.stats.setdefault(key, KernelStat())
            if compiled:
                st.compile_calls += cnt
                st.compile_s += share
            else:
                st.calls += cnt
                st.execute_s += share

    def report(self) -> dict:
        """The ``measured_vs_predicted`` table (sorted by attributed wall)."""
        rows = []
        for key, st in self.stats.items():
            kernel, fmt, m, k, nb = key
            spec = dispatch.REGISTRY[kernel]
            pred_us = predicted_us(key)
            pred_bytes = predicted_hbm_bytes(key)
            meas_us = (st.execute_s / st.calls * 1e6) if st.calls else None
            infl = spec.mxu_inflation
            if infl is None:
                from repro.core import formats as fmtreg
                infl = fmtreg.get(fmt).mxu_inflation
            rows.append({
                "kernel": kernel, "fmt": fmt, "M": m, "K": k, "N_bucket": nb,
                "calls": st.calls, "compile_calls": st.compile_calls,
                "compile_s": round(st.compile_s, 6),
                "execute_s": round(st.execute_s, 6),
                "measured_us_per_call":
                    round(meas_us, 3) if meas_us is not None else None,
                "predicted_us_per_call": round(pred_us, 3),
                "measured_over_predicted":
                    round(meas_us / pred_us, 3) if meas_us else None,
                "predicted_hbm_bytes_per_call": round(pred_bytes, 1),
                "measured_gb_s":
                    round(pred_bytes * st.calls / st.execute_s / 1e9, 3)
                    if st.execute_s else None,
                "predicted_mxu_inflation": round(float(infl), 3),
            })
        rows.sort(key=lambda r: -(r["execute_s"] + r["compile_s"]))
        return {
            "rows": rows,
            "unattributed_s": round(self.unattributed_s, 6),
            "note": ("execute time per key is a cost-share attribution of "
                     "the fenced jitted-step wall (DESIGN.md §9); compile "
                     "rows book the trace+first-execute wall separately"),
        }


class InstrumentedFn:
    """The jit-boundary wrapper (see module docstring).  ``profiler=None``
    is the capture-only mode the engine uses when observability is off."""

    __slots__ = ("fn", "label", "profiler")

    def __init__(self, fn, label: str, profiler: KernelProfiler | None = None):
        self.fn = fn
        self.label = label
        self.profiler = profiler

    def __call__(self, *args):
        prof = self.profiler
        mark = dispatch.decision_count()
        if prof is None:
            out = self.fn(*args)
            if dispatch.decision_count() != mark:  # this call jit-traced
                _KEYSETS[(self.fn, _sig(args))] = _keyset(
                    dispatch.decisions_since(mark))
            return out
        t0 = prof.clock()
        out = self.fn(*args)
        jax.block_until_ready(out)
        dt = prof.clock() - t0
        sig = _sig(args)
        if dispatch.decision_count() != mark:
            keys = _keyset(dispatch.decisions_since(mark))
            _KEYSETS[(self.fn, sig)] = keys
            prof.record(keys, dt, compiled=True)
        else:
            prof.record(_KEYSETS.get((self.fn, sig)), dt, compiled=False)
        return out


def instrument(fn, label: str,
               profiler: KernelProfiler | None = None) -> InstrumentedFn:
    if isinstance(fn, InstrumentedFn):  # re-wrap: keep the shared keyset id
        fn = fn.fn
    return InstrumentedFn(fn, label, profiler)
