"""Structured-event payloads and their canonical text renderings.

The engine emits *structured* diagnostics (dicts on the tracer event
stream); the strings humans read are rendered from those payloads by the
formatters here — the ONE home of the wording, so the printed output stays
identical whether it comes from the launcher, a test, or a log scraper
replaying a trace file.  No heavy imports: this module must stay loadable
from docs tooling, like ``repro.serve.metrics``.
"""

from __future__ import annotations


def format_stall(diag: dict) -> str:
    """Render the engine's stall diagnosis (the RuntimeError text).

    ``diag`` is the structured payload from
    ``ServeEngine._stall_diagnosis()`` — also emitted as a ``stall`` event
    on the tracer before the engine raises."""
    lines = []
    for s in diag["slots"]:
        if "blocks_needed" in s:
            line = (
                f"slot {s['slot']} (rid {s['rid']}, prio {s['priority']}, "
                f"{s['phase']} at pos {s['cursor']}/{s['n_base']}) needs "
                f"{s['blocks_needed']} more KV block(s)")
        else:
            line = (f"slot {s['slot']} (rid {s['rid']}, {s['phase']} at "
                    f"pos {s['cursor']}/{s['n_base']})")
        if s.get("draft_blocks_needed"):
            line += f" + {s['draft_blocks_needed']} draft block(s)"
        lines.append(line)
    p = diag["pool"]
    if p["kind"] == "paged":
        pool = (f"{p['free']} of {p['total']} KV blocks free"
                f", {p['shared']} refcounted/shared")
        if "prefix_cached" in p:
            pool += (f", {p['prefix_cached']} prefix-cached "
                     f"({p['prefix_evictable']} evictable)")
        if "draft_free" in p:
            pool += (f"; draft pool {p['draft_free']} of "
                     f"{p['draft_total']} free")
    else:
        pool = "dense KV cache"
    blocked = "; ".join(lines) if lines else "no occupied slots"
    return (f"serving stalled for {diag['stall_ticks']} ticks: no slot can "
            f"make progress and nothing is evictable "
            f"(preemption={diag['preemption']}). Blocked: {blocked}. "
            f"Pool: {pool}; queued requests: {diag['queued']}. "
            "Raise --kv-blocks, lower concurrency, or enable preemption.")


def format_prefix_summary(s: dict) -> str:
    """Render the launcher's prefix-cache telemetry line from a
    ``metrics_summary()`` dict (leading indent included, as printed)."""
    return (f"  prefix hits = {s['prefix_hit_requests']}/{s['requests']} "
            f"requests, hit rate = {s['prefix_hit_rate']:.2f}, "
            f"prefill tokens skipped = {s['prefill_tokens_skipped']}, "
            f"blocks reused = {s['blocks_reused']}"
            + (f", cached = {s['prefix_cached_blocks']} "
               f"({s['prefix_evictable_blocks']} evictable)"
               if "prefix_cached_blocks" in s else ""))
