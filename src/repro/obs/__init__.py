"""Observability (DESIGN.md §9): tracing, metrics, kernel attribution.

Three composable pieces behind one :class:`Obs` bundle:

    trace.Tracer           nested spans + structured events, Chrome/Perfetto
                           JSON export, injectable clock
    metrics.MetricsRegistry  counters / gauges / histograms, JSON +
                           Prometheus text snapshots
    kernels.KernelProfiler jit-aware mpGEMM timing: per
                           (kernel, fmt, M, K, N-bucket) wall/compile/call
                           accounting and the measured_vs_predicted report

Everything is OFF by default and zero-overhead when off: :data:`NULL_OBS`
hands the engine no-op spans and instruments, so the hot path carries its
instrumentation unconditionally.  Build a live bundle with :func:`make`
(``clock`` is injectable — the engine's virtual-clock tests assert exact
span trees and deterministic attribution).
"""

from __future__ import annotations

import dataclasses
import time

from repro.core import dispatch
from repro.distributed import sharding
from repro.obs import events, kernels, metrics, trace  # noqa: F401
from repro.obs.events import format_prefix_summary, format_stall  # noqa: F401
from repro.obs.kernels import InstrumentedFn, KernelProfiler, instrument  # noqa: F401
from repro.obs.metrics import NULL_METRICS, MetricsRegistry  # noqa: F401
from repro.obs.trace import NULL_TRACER, Tracer  # noqa: F401


@dataclasses.dataclass
class Obs:
    """The bundle an engine carries.  ``kernels=None`` → no kernel timing
    (and no per-call fences)."""

    tracer: object = NULL_TRACER
    metrics: object = NULL_METRICS
    kernels: KernelProfiler | None = None

    @property
    def active(self) -> bool:
        return (self.tracer.enabled or self.metrics.enabled
                or self.kernels is not None)


NULL_OBS = Obs()


def make(clock=time.perf_counter, *, tracing: bool = True,
         metrics_on: bool = True, kernel_timing: bool = True) -> Obs:
    """A live bundle; all three pieces share ``clock``."""
    return Obs(
        tracer=Tracer(clock=clock) if tracing else NULL_TRACER,
        metrics=MetricsRegistry() if metrics_on else NULL_METRICS,
        kernels=KernelProfiler(clock=clock) if kernel_timing else None,
    )


def metrics_blob(obs: Obs) -> dict:
    """The ``--metrics-json`` payload: registry snapshot + the dispatch
    decision log (retained entries AND the trim-loss counter — the log
    drops its oldest half at capacity, see ``dispatch.decisions_dropped``)
    + the measured_vs_predicted kernel attribution table."""
    reg = obs.metrics
    if reg.enabled:
        c = reg.counter("dispatch_decisions_dropped")
        c.inc(dispatch.decisions_dropped() - c.value)
        reg.gauge("dispatch_decisions_retained").set(len(dispatch.decisions()))
        s = reg.counter("sharding_axes_dropped")
        s.inc(sharding.axes_dropped() - s.value)
    return {
        "metrics": reg.snapshot() if reg.enabled else
            {"counters": {}, "gauges": {}, "histograms": {}},
        "dispatch": {
            "decisions_dropped": dispatch.decisions_dropped(),
            "decisions": [dataclasses.asdict(d) for d in dispatch.decisions()],
        },
        "sharding": {"axes_dropped": sharding.axes_dropped()},
        "measured_vs_predicted": obs.kernels.report() if obs.kernels else
            {"rows": [], "unattributed_s": 0.0,
             "note": "kernel profiling disabled"},
    }
