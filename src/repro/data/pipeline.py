"""Deterministic synthetic LM data pipeline.

Design goals for the production setting:
  * host-sharded: each host generates only its slice of the global batch;
  * checkpointable: the iterator state is a single step counter — batch(t) is
    a pure function of (seed, step, host_slice), so restore is exact and
    elastic (a different host count replays the same global stream);
  * preemption-safe: no hidden buffer state to lose.

The token stream is a seeded Markov-ish mixture so models can actually learn
(loss decreases) rather than uniform noise.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0


@dataclasses.dataclass
class DataState:
    step: int = 0


def _host_slice(cfg: DataConfig) -> tuple[int, int]:
    per = cfg.global_batch // cfg.n_hosts
    return cfg.host_id * per, per


def make_batch(cfg: DataConfig, step: int) -> dict:
    """Pure function (seed, step) -> host-local batch dict.

    Learnable structure: tokens live in a small active sub-vocabulary and
    follow the deterministic successor t[i+1] = (t[i] + 7) mod V_active with
    10% uniform noise — a model that learns the bigram drops well below the
    uniform-entropy floor within tens of steps.
    """
    start, per = _host_slice(cfg)
    rng = np.random.default_rng((cfg.seed, step))
    va = min(cfg.vocab, 64)
    t0 = rng.integers(0, va, size=(cfg.global_batch, 1))
    toks = [t0]
    for _ in range(cfg.seq_len):
        nxt = (toks[-1] + 7) % va
        noise = rng.integers(0, va, size=(cfg.global_batch, 1))
        use_noise = rng.random((cfg.global_batch, 1)) < 0.1
        toks.append(np.where(use_noise, noise, nxt))
    seq = np.concatenate(toks, axis=1)
    seq = seq[start : start + per]
    return {
        "tokens": jnp.asarray(seq[:, :-1], jnp.int32),
        "labels": jnp.asarray(seq[:, 1:], jnp.int32),
    }


class DataIterator:
    """Checkpointable iterator: state == step counter."""

    def __init__(self, cfg: DataConfig, state: DataState | None = None):
        self.cfg = cfg
        self.state = state or DataState()

    def __next__(self) -> dict:
        b = make_batch(self.cfg, self.state.step)
        self.state.step += 1
        return b

    def checkpoint(self) -> dict:
        return {"step": self.state.step}

    @classmethod
    def restore(cls, cfg: DataConfig, ckpt: dict) -> "DataIterator":
        return cls(cfg, DataState(step=int(ckpt["step"])))
