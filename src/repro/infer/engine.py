"""Batched serving engine: slot-based continuous batching over decode steps.

The engine owns B decode slots.  New requests are admitted into free slots
and consume their prompt token-by-token (prefill phase) while other slots
keep generating — all through ONE jitted step with per-slot positions
(paused slots pass position −1; their cache writes land in the trash slot).
Finished sequences retire and free their slot immediately.

Weights are packed (the paper's convert step) before serving; with per-tensor
int8 activation quant + i2s/tl*_1 formats, decode is lossless w.r.t. the
b1.58 training scheme (paper Figure 2).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dispatch
from repro.core.dispatch import KernelPlan
from repro.models import lm
from repro.models.config import ModelConfig


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list                  # token ids
    max_new_tokens: int = 16
    temperature: float = 0.0      # 0 → greedy
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class _Slot:
    req: Request
    cursor: int = 0               # tokens of the prompt already consumed


class Engine:
    def __init__(self, params, cfg: ModelConfig, *, batch_slots: int = 4,
                 max_seq: int = 256, pack: bool = True, seed: int = 0,
                 plan: KernelPlan | None = None):
        if plan is not None:
            cfg = cfg.with_plan(plan)
        self.cfg = cfg
        self.params = lm.pack(params, cfg) if pack and cfg.quant.mode == "quant" else params
        self.slots: list[_Slot | None] = [None] * batch_slots
        self.max_seq = max_seq
        self.state = lm.init_state(cfg, batch_slots, max_seq)
        self.key = jax.random.PRNGKey(seed)
        self.queue: list[Request] = []
        self._decision_mark = dispatch.decision_count()
        self._step_fn = jax.jit(partial(_decode, cfg=cfg))

    def kernel_decisions(self) -> tuple:
        """mpGEMM dispatch decisions recorded since this engine was built.

        Decisions are logged at trace time, so a single-shape serving run
        yields one decision per BitLinear per traced step shape.  The regime
        follows the engine's SLOT COUNT, not the number of busy slots: the
        jitted step always batches all ``batch_slots`` (idle slots pad at
        pos −1), so only a ``batch_slots=1`` engine takes the N=1 GEMV
        regime (``lut_gemv`` for tl1); larger engines always dispatch GEMM.
        """
        return dispatch.decisions_since(self._decision_mark)

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def step(self) -> list[Request]:
        """One decode tick for every busy slot; returns finished requests."""
        b = len(self.slots)
        for i in range(b):
            if self.slots[i] is None and self.queue:
                self.slots[i] = _Slot(self.queue.pop(0))

        toks = np.zeros((b, 1), np.int32)
        pos = np.full((b,), -1, np.int32)
        for i, sl in enumerate(self.slots):
            if sl is None:
                continue
            r = sl.req
            if sl.cursor < len(r.prompt):
                toks[i, 0] = r.prompt[sl.cursor]
            else:
                toks[i, 0] = r.out_tokens[-1]
            pos[i] = sl.cursor

        logits, self.state = self._step_fn(
            self.params, jnp.asarray(toks), jnp.asarray(pos), self.state
        )
        finished = []
        for i, sl in enumerate(self.slots):
            if sl is None:
                continue
            r = sl.req
            sl.cursor += 1
            if sl.cursor < len(r.prompt):
                continue  # still prefilling
            if r.temperature > 0:
                self.key, sub = jax.random.split(self.key)
                nxt = int(jax.random.categorical(sub, logits[i, 0] / r.temperature))
            else:
                nxt = int(jnp.argmax(logits[i, 0]))
            r.out_tokens.append(nxt)
            if len(r.out_tokens) >= r.max_new_tokens or sl.cursor >= self.max_seq - 1:
                r.done = True
                finished.append(r)
                self.slots[i] = None
        return finished

    def run(self) -> list[Request]:
        done: list[Request] = []
        while self.queue or any(s is not None for s in self.slots):
            done.extend(self.step())
        return done


def _decode(params, toks, pos, state, *, cfg: ModelConfig):
    return lm.decode_step(params, toks, pos, cfg, state)


def generate(params, cfg: ModelConfig, prompts: list, *, max_new_tokens: int = 16,
             batch_slots: int = 4, max_seq: int = 256, pack: bool = True) -> list:
    """Convenience: run a batch of prompts to completion, return token lists."""
    eng = Engine(params, cfg, batch_slots=batch_slots, max_seq=max_seq, pack=pack)
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=list(p), max_new_tokens=max_new_tokens))
    done = eng.run()
    return [r.out_tokens for r in sorted(done, key=lambda r: r.rid)]
