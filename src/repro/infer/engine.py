"""Batched serving engine — legacy facade over ``repro.serve`` (DESIGN.md §7).

:class:`Engine` keeps the original slot-based continuous-batching contract
(dense ``[slots, max_seq]`` KV caches, prompts consumed token-by-token inside
the one jitted decode tick, FIFO admission) by instantiating
:class:`repro.serve.engine.ServeEngine` with ``paged=False,
prefill_chunk=1``.  New code should use ServeEngine directly — it adds the
paged block-pool KV cache, chunked prefill, priority/deadline admission with
preemption, and per-request telemetry.

Weights are packed (the paper's convert step) before serving; with per-tensor
int8 activation quant + i2s/tl*_1 formats, decode is lossless w.r.t. the
b1.58 training scheme (paper Figure 2).
"""

from __future__ import annotations

from repro.core.dispatch import KernelPlan
from repro.models.config import ModelConfig
from repro.serve.engine import ServeConfig, ServeEngine
from repro.serve.scheduler import Request  # noqa: F401  (legacy import site)


class Engine(ServeEngine):
    """Dense token-by-token continuous batching (the pre-serve behaviour).

    The jitted step always batches all ``batch_slots`` (idle slots pad at
    pos −1), so only a ``batch_slots=1`` engine takes the N=1 GEMV regime
    (``lut_gemv`` for tl1); larger engines always dispatch GEMM — see
    :meth:`kernel_decisions`.
    """

    def __init__(self, params, cfg: ModelConfig, *, batch_slots: int = 4,
                 max_seq: int = 256, pack: bool = True, seed: int = 0,
                 plan: KernelPlan | None = None, obs=None):
        super().__init__(
            params, cfg,
            ServeConfig(batch_slots=batch_slots, max_seq=max_seq,
                        paged=False, prefill_chunk=1),
            pack=pack, seed=seed, plan=plan, obs=obs)


def generate(params, cfg: ModelConfig, prompts: list, *, max_new_tokens: int = 16,
             batch_slots: int = 4, max_seq: int = 256, pack: bool = True) -> list:
    """Convenience: run a batch of prompts to completion, return token lists."""
    eng = Engine(params, cfg, batch_slots=batch_slots, max_seq=max_seq, pack=pack)
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=list(p), max_new_tokens=max_new_tokens))
    done = eng.run()
    return [r.out_tokens for r in sorted(done, key=lambda r: r.rid)]
