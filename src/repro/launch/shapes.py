"""Input-shape cells and ShapeDtypeStruct input specs for the dry-run.

Four shapes per the brief (LM shapes are seq_len × global_batch):
    train_4k     4,096 × 256     → train_step
    prefill_32k  32,768 × 32     → prefill (serve)
    decode_32k   one token, KV cache of 32,768, batch 128 → serve_step
    long_500k    one token, KV cache of 524,288, batch 1  → serve_step
                 (sub-quadratic archs only; skips recorded in DESIGN.md)

Specs are ShapeDtypeStructs throughout — weak-type-correct, shardable, no
device allocation: the full configs only ever exist abstractly on this host.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.bitlinear import QuantConfig
from repro.models import lm
from repro.models.config import ModelConfig
from repro.train import loop as train_loop
from repro.train import optimizer as opt


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}

# long_500k eligibility (DESIGN.md §Shape-cell skips)
LONG_OK = {"mamba2-1.3b", "recurrentgemma-2b", "gemma3-4b"}


def applicable(arch: str, shape: str) -> bool:
    if shape == "long_500k":
        return arch in LONG_OK
    return True


def dryrun_config(cfg: ModelConfig, kind: str, *, fmt: str = "i2s",
                  plan=None) -> ModelConfig:
    """Numerics for the production lowering: bf16 activations; QAT for train,
    packed ternary inference otherwise; remat for the train graph.

    The inference plan defaults to XLA-only kernels — the dry-runs prove the
    pure-XLA lowering and must stay pallas-import-free."""
    if kind == "train":
        # w_gather left off: GSPMD's own FSDP propagation keeps the stacked
        # weights and their scan-backward cotangents 256-way sharded (an
        # explicit in-body TP constraint was measured to force TP-only f32
        # cotangent carriers — +13 GB/device; see EXPERIMENTS.md §Dry-run)
        return cfg.replace(dtype="bfloat16", remat=True,
                           quant=QuantConfig(mode="qat"))
    from repro.core.dispatch import KernelPlan

    return cfg.replace(dtype="bfloat16",
                       quant=QuantConfig(mode="quant", fmt=fmt,
                                         plan=plan or KernelPlan(backend="xla")))


def abstract_params(cfg: ModelConfig, kind: str):
    """ShapeDtypeStruct tree of the params this cell's step consumes."""
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    if kind == "train":
        p = jax.eval_shape(lambda k: lm.init(k, cfg), key)
        return p
    return jax.eval_shape(lambda k: lm.pack(lm.init(k, cfg), cfg), key)


def abstract_train_state(cfg: ModelConfig, tcfg: train_loop.TrainConfig):
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    return jax.eval_shape(lambda k: train_loop.init_train_state(k, cfg, tcfg), key)


def batch_specs(cfg: ModelConfig, cell: ShapeCell) -> dict:
    b, s = cell.global_batch, cell.seq_len
    specs = {
        "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
        "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
    }
    if cfg.frontend:
        specs["frontend_emb"] = jax.ShapeDtypeStruct(
            (b, cfg.frontend_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.is_encdec():
        specs["enc_emb"] = jax.ShapeDtypeStruct((b, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
    return specs


def decode_specs(cfg: ModelConfig, cell: ShapeCell) -> dict:
    b = cell.global_batch
    state = jax.eval_shape(lambda: lm.init_state(cfg, b, cell.seq_len))
    return {
        "tok": jax.ShapeDtypeStruct((b, 1), jnp.int32),
        "pos": jax.ShapeDtypeStruct((b,), jnp.int32),
        "state": state,
    }


def input_specs(cfg: ModelConfig, cell: ShapeCell, tcfg=None) -> dict:
    """All abstract inputs for the cell's step function."""
    kind = cell.kind
    if kind == "train":
        tcfg = tcfg or train_loop.TrainConfig()
        return {
            "state": abstract_train_state(cfg, tcfg),
            "batch": batch_specs(cfg, cell),
        }
    params = abstract_params(cfg, kind)
    if kind == "prefill":
        out = {"params": params, "batch": batch_specs(cfg, cell),
               "state": jax.eval_shape(lambda: lm.init_state(cfg, cell.global_batch, cell.seq_len))}
        out["batch"].pop("labels")
        return out
    return {"params": params, **decode_specs(cfg, cell)}
