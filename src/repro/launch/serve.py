"""Serving launcher: pack a ternary model and run the batched engine.

CPU smoke:  python -m repro.launch.serve --arch qwen1.5-0.5b --smoke
Kernel routing is shape-aware (DESIGN.md §5): an engine sized to one slot
(--slots 1) decodes in the GEMV regime (true-LUT kernel for tl1); any larger
slot count always batches all slots — idle ones pad — so it dispatches the
GEMM regime.  Inspect with --explain, override with --gemv/--gemm, measure with
--autotune (winners persist to the cache JSON and steer future runs).

A real deployment would restore packed params from the checkpoint store and
pjit decode_step over the serving mesh (the dry-run proves that lowering).
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.core import dispatch
from repro.core.bitlinear import QuantConfig
from repro.core.dispatch import KernelPlan
from repro.infer.engine import Engine, Request
from repro.models import lm


def build_plan(args) -> KernelPlan:
    if args.lut:  # deprecated alias, kept so existing invocations still work
        if args.fmt in ("tl1", "tl2"):
            print(f"[serve] --lut is deprecated; use --gemv/--gemm "
                  f"(mapping to the {args.lut} LUT kernels)")
            return dispatch.lut_plan(args.fmt, lossless=(args.lut == "lossless"))
        # historical behavior: lut was silently ignored for non-LUT formats
        print(f"[serve] --lut has no effect for fmt={args.fmt!r} (ignored)")
    return KernelPlan(gemv=args.gemv, gemm=args.gemm, backend=args.backend)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--fmt", default="i2s",
                    choices=["i2s", "tl1", "tl2", "tl2k", "int4", "fp"])
    ap.add_argument("--gemv", default="auto",
                    help="kernel name for the N=1 decode regime (default: auto)")
    ap.add_argument("--gemm", default="auto",
                    help="kernel name for the batched regime (default: auto)")
    ap.add_argument("--backend", default="auto", choices=["auto", "xla", "pallas"])
    ap.add_argument("--lut", default="", choices=["", "lossless", "lossy"],
                    help="DEPRECATED: use --gemv/--gemm")
    ap.add_argument("--autotune-cache", default="",
                    help="autotune cache JSON: loaded if it exists; "
                         "written after --autotune")
    ap.add_argument("--autotune", action="store_true",
                    help="measure registry candidates at this model's decode "
                         "shapes before serving")
    ap.add_argument("--explain", action="store_true",
                    help="print the dispatch decision per regime and exit")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--ckpt", default="", help="restore packed params from here")
    args = ap.parse_args()

    plan = build_plan(args)
    cfg = configs.smoke(args.arch) if args.smoke else configs.get(args.arch)
    cfg = cfg.replace(dtype="float32",
                      quant=QuantConfig(mode="quant", fmt=args.fmt, plan=plan))

    if args.autotune_cache:
        import os
        if os.path.exists(args.autotune_cache):
            dispatch.load_cache(args.autotune_cache)
            print(f"[serve] loaded autotune cache {args.autotune_cache} "
                  f"({len(dispatch.active_cache().entries)} entries)")

    d, f = cfg.d_model, cfg.d_ff or cfg.d_model
    layer_shapes = [(n, k, m) for n in (1, args.slots)
                    for (k, m) in ((d, d), (d, f), (f, d))]
    if args.explain:
        for n, k, m in layer_shapes:
            print(dispatch.explain(args.fmt, n, k, m, plan))
        return
    if args.autotune:
        dispatch.autotune(args.fmt, layer_shapes)
        if args.autotune_cache:
            dispatch.active_cache().save(args.autotune_cache)
            print(f"[serve] autotune winners saved to {args.autotune_cache}")

    params = lm.init(jax.random.PRNGKey(0), cfg)
    if args.ckpt:
        from repro.ckpt import store
        params, _ = store.restore(params, args.ckpt)

    eng = Engine(params, cfg, batch_slots=args.slots, max_seq=args.max_seq)
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        prompt = rng.integers(0, cfg.vocab, size=rng.integers(3, 9)).tolist()
        eng.submit(Request(rid=i, prompt=prompt, max_new_tokens=args.max_new))

    t0 = time.perf_counter()
    done = eng.run()
    dt = time.perf_counter() - t0
    toks = sum(len(r.out_tokens) for r in done)
    print(f"[serve] {args.arch} fmt={args.fmt}: "
          f"{len(done)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s on CPU; see benchmarks for TPU projections)")
    routed = sorted({(dc.regime, dc.n, dc.kernel, dc.source)
                     for dc in eng.kernel_decisions()})
    for regime, n, kernel, source in routed:
        print(f"  routed {regime} (N={n}) -> {kernel} [{source}]")
    for r in sorted(done, key=lambda r: r.rid)[:3]:
        print(f"  req{r.rid}: prompt={r.prompt} -> {r.out_tokens}")


if __name__ == "__main__":
    main()
