"""Serving launcher: pack a ternary model and run the batched engine.

CPU smoke:  python -m repro.launch.serve --arch qwen1.5-0.5b --smoke
A real deployment would restore packed params from the checkpoint store and
pjit decode_step over the serving mesh (the dry-run proves that lowering).
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.core.bitlinear import QuantConfig
from repro.infer.engine import Engine, Request
from repro.models import lm


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--fmt", default="i2s",
                    choices=["i2s", "tl1", "tl2", "tl2k", "int4", "fp"])
    ap.add_argument("--lut", default="", choices=["", "lossless", "lossy"])
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--ckpt", default="", help="restore packed params from here")
    args = ap.parse_args()

    cfg = configs.smoke(args.arch) if args.smoke else configs.get(args.arch)
    cfg = cfg.replace(dtype="float32",
                      quant=QuantConfig(mode="quant", fmt=args.fmt,
                                        lut=args.lut or None))
    params = lm.init(jax.random.PRNGKey(0), cfg)
    if args.ckpt:
        from repro.ckpt import store
        params, _ = store.restore(params, args.ckpt)

    eng = Engine(params, cfg, batch_slots=args.slots, max_seq=args.max_seq)
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        prompt = rng.integers(0, cfg.vocab, size=rng.integers(3, 9)).tolist()
        eng.submit(Request(rid=i, prompt=prompt, max_new_tokens=args.max_new))

    t0 = time.perf_counter()
    done = eng.run()
    dt = time.perf_counter() - t0
    toks = sum(len(r.out_tokens) for r in done)
    print(f"[serve] {args.arch} fmt={args.fmt}{('_'+args.lut) if args.lut else ''}: "
          f"{len(done)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s on CPU; see benchmarks for TPU projections)")
    for r in sorted(done, key=lambda r: r.rid)[:3]:
        print(f"  req{r.rid}: prompt={r.prompt} -> {r.out_tokens}")


if __name__ == "__main__":
    main()
