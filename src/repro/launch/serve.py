"""Serving launcher: pack a ternary model and run the serving engine.

CPU smoke:  python -m repro.launch.serve --arch qwen1.5-0.5b --smoke
Paged path: python -m repro.launch.serve --smoke --paged --prefill-chunk 16

Kernel routing is shape-aware (DESIGN.md §5): an engine sized to one slot
(--slots 1) decodes in the GEMV regime (true-LUT kernel for tl1); any larger
slot count always batches all slots — idle ones pad — so it dispatches the
GEMM regime.  Prefill CHUNKS (--prefill-chunk > 1) flatten to N=chunk and
always take the GEMM/MAD kernels.  Inspect with --explain, override with
--gemv/--gemm, measure with --autotune (winners persist to the cache JSON).

Serving subsystem flags (DESIGN.md §7): --paged switches the KV cache to the
block-pool layout (--block-size / --kv-blocks size it), --prefill-chunk
enables chunked prefill, and --bursty N replays N request bursts against the
admission scheduler and prints per-request telemetry (TTFT, queue wait,
throughput, preemptions).

Speculative decoding (DESIGN.md §10): --speculate K drafts K tokens per
decode tick and verifies all K+1 positions in one batched call that rides
the GEMM regime; --draft picks the drafter ('self' reuses the target's
weights — add --draft-fmt int2_g128 to re-pack them cheaper — 'ngram' /
'ngram:N' proposes from each request's own token history at zero model
cost, or name a small arch); greedy output is bit-identical to
non-speculative serving.

Observability (DESIGN.md §9): ``--trace-out trace.json`` writes a
Chrome/Perfetto span trace of the run (one span per engine tick with
admission / prefill / decode / sampling children), ``--metrics-json``
dumps the metrics registry plus the dispatch decision log and the
measured-vs-predicted kernel attribution table, and ``--metrics-prom``
writes a Prometheus text snapshot.  All off by default and zero-overhead
when off.

A real deployment would restore packed params from the checkpoint store and
pjit decode_step over the serving mesh (the dry-run proves that lowering).
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro import obs as obs_mod
from repro.core import dispatch, formats
from repro.core.bitlinear import QuantConfig
from repro.core.dispatch import KernelPlan
from repro.infer.engine import Engine
from repro.models import lm
from repro.serve import Request, ServeConfig, ServeEngine
from repro.serve import qos as qos_mod
from repro.serve import spec as spec_mod


def build_plan(args) -> KernelPlan:
    return KernelPlan(gemv=args.gemv, gemm=args.gemm, backend=args.backend)


def make_obs(args) -> obs_mod.Obs | None:
    """A live Obs bundle iff any observability flag asked for one —
    otherwise None, so the engine carries the zero-overhead NULL bundle."""
    if not (args.trace_out or args.metrics_json or args.metrics_prom):
        return None
    return obs_mod.make(tracing=bool(args.trace_out))


def make_draft(args, params, cfg):
    """Resolve --draft / --draft-fmt to a DraftModel (or None for the
    zero-copy self-speculation default: the engine wraps its own packed
    params).  ``params`` are the target's RAW weights — a re-packed
    self-draft quantises them at the cheaper format itself."""
    if args.speculate <= 0:
        return None
    if args.draft == "self":
        if not args.draft_fmt or args.draft_fmt == args.fmt:
            return None
        return spec_mod.self_draft(params, cfg, fmt=args.draft_fmt)
    if args.draft == "ngram" or args.draft.startswith("ngram:"):
        _, _, n = args.draft.partition(":")
        return spec_mod.LookupDraft(n=int(n) if n else 2)
    dcfg = configs.smoke(args.draft) if args.smoke else configs.get(args.draft)
    dcfg = dcfg.replace(dtype="float32", quant=QuantConfig(
        mode="quant", fmt=args.draft_fmt or args.fmt,
        plan=build_plan(args), act=args.act))
    dparams = lm.init(jax.random.PRNGKey(1), dcfg)
    return spec_mod.make_draft(dparams, dcfg, label=args.draft)


def make_tp_mesh(args):
    """The (data=1, model=tp) serving mesh for --tp N (None when tp == 1).

    On a dev box force the device count first:
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (set BEFORE jax
    first initialises — DESIGN.md §12 quickstart)."""
    if args.tp <= 1:
        return None
    ndev = len(jax.devices())
    if ndev < args.tp:
        raise SystemExit(
            f"[serve] --tp {args.tp} needs {args.tp} devices, found {ndev}; "
            "on CPU set XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{args.tp}")
    from repro.launch.mesh import make_mesh
    return make_mesh((1, args.tp), ("data", "model"))


def make_engine(args, params, cfg, obs=None, mesh=None):
    if mesh is None and not (args.paged or args.prefill_chunk > 1 or args.bursty
                             or args.prefix_cache or args.speculate > 0):
        return Engine(params, cfg, batch_slots=args.slots,
                      max_seq=args.max_seq, obs=obs)
    return ServeEngine(params, cfg, ServeConfig(
        batch_slots=args.slots, max_seq=args.max_seq, paged=args.paged,
        block_size=args.block_size,
        kv_blocks=args.kv_blocks or None,
        prefill_chunk=args.prefill_chunk,
        prefill_budget=args.prefill_budget,
        prefix_cache=args.prefix_cache,
        speculate_k=args.speculate), obs=obs,
        draft=make_draft(args, params, cfg), mesh=mesh)


def _request_qos(args, rng) -> str | None:
    if args.qos == "mixed":
        return str(rng.choice(sorted(qos_mod.CLASSES)))
    return args.qos or None


def submit_burst(eng, cfg, args, rng, rids, max_new, templates=None):
    """Queue one burst.  With a prefix cache, prompts draw a shared template
    prefix (2 blocks long — what a system prompt looks like at this scale)
    plus a private suffix; otherwise they are fully random, as before."""
    for rid in rids:
        prompt = []
        if templates:
            prompt = list(templates[int(rng.integers(0, len(templates)))])
        prompt += rng.integers(0, cfg.vocab, size=rng.integers(3, 9)).tolist()
        req = Request(rid=rid, prompt=prompt, max_new_tokens=max_new)
        if isinstance(eng, ServeEngine) and not isinstance(eng, Engine):
            eng.submit(req, priority=int(rng.integers(0, 3)),
                       qos=_request_qos(args, rng))
        else:
            eng.submit(req)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--fmt", default=None, choices=list(formats.names()),
                    help="weight format (any registry entry, incl. the "
                         "non-ternary ELUT formats int2/int3); default: "
                         "picked by --qos objective, else i2s")
    ap.add_argument("--act", default="token", choices=["token", "tensor"],
                    help="activation quant granularity (default: token — "
                         "composition-invariant under batching; 'tensor' is "
                         "the bit-exact b1.58 scheme but ties logits to the "
                         "step batch composition)")
    ap.add_argument("--gemv", default="auto",
                    help="kernel name for the N=1 decode regime (default: auto)")
    ap.add_argument("--gemm", default="auto",
                    help="kernel name for the batched regime (default: auto)")
    ap.add_argument("--backend", default="auto", choices=["auto", "xla", "pallas"])
    ap.add_argument("--autotune-cache", default="",
                    help="autotune cache JSON: loaded if it exists; "
                         "written after --autotune")
    ap.add_argument("--autotune", action="store_true",
                    help="measure registry candidates at this model's decode "
                         "shapes before serving")
    ap.add_argument("--explain", action="store_true",
                    help="print the dispatch decision per regime and exit")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=128)
    # serving subsystem (DESIGN.md §7)
    ap.add_argument("--paged", action="store_true",
                    help="paged block-pool KV cache instead of dense slots")
    ap.add_argument("--block-size", type=int, default=16,
                    help="KV block size in tokens (paged)")
    ap.add_argument("--kv-blocks", type=int, default=0,
                    help="total KV pool blocks (0 → slots·ceil(max_seq/bs))")
    ap.add_argument("--prefill-chunk", type=int, default=1,
                    help="prompt tokens per prefill chunk (1 → token-by-token)")
    ap.add_argument("--prefill-budget", type=int, default=0,
                    help="prefill tokens per tick, packed as ONE batched "
                         "[budget//chunk, chunk] call (mpGEMM N = S*C); "
                         "0 → sequential per-slot chunks")
    ap.add_argument("--bursty", type=int, default=0,
                    help="bursty-arrival simulation: N bursts of --requests "
                         "requests with decode ticks between bursts")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="share prompt-prefix KV blocks across requests "
                         "(paged, attention archs; inert otherwise)")
    ap.add_argument("--speculate", type=int, default=0,
                    help="speculative decoding: draft K tokens per decode "
                         "tick and verify all K+1 positions in ONE batched "
                         "call (N = slots*(K+1), the GEMM regime); greedy "
                         "output stays bit-identical (0 → off)")
    ap.add_argument("--draft", default="self",
                    help="draft source: 'self' (target weights; zero extra "
                         "memory unless --draft-fmt re-packs them), "
                         "'ngram' / 'ngram:N' (model-free prompt-lookup: "
                         "proposals from each request's own history), or an "
                         "arch name for a separate small draft")
    ap.add_argument("--draft-fmt", default=None, choices=list(formats.names()),
                    help="registry format for the draft's weights (e.g. a "
                         "cheaper int2_g128); default: the target's --fmt")
    ap.add_argument("--qos", default=None,
                    choices=sorted(qos_mod.CLASSES) + ["mixed"],
                    help="QoS class applied to every request ('mixed': "
                         "random per request); also picks the default --fmt "
                         "via the registry objective")
    ap.add_argument("--tp", type=int, default=1,
                    help="tensor-parallel shards: serve on a (data=1, "
                         "model=N) mesh with packed planes M-sharded "
                         "(DESIGN.md §12); tokens stay bit-identical to "
                         "--tp 1.  Host smoke: XLA_FLAGS=--xla_force_host_"
                         "platform_device_count=N")
    ap.add_argument("--seed", type=int, default=0,
                    help="workload RNG seed (prompts, priorities, QoS mix)")
    ap.add_argument("--ckpt", default="", help="restore packed params from here")
    # observability (DESIGN.md §9) — off by default, zero overhead when off
    ap.add_argument("--trace-out", default="",
                    help="write a Chrome/Perfetto trace-event JSON of the "
                         "serve run here (open at https://ui.perfetto.dev)")
    ap.add_argument("--metrics-json", default="",
                    help="write the metrics snapshot here: registry dump, "
                         "dispatch decision log + drop counter, and the "
                         "measured_vs_predicted kernel attribution table")
    ap.add_argument("--metrics-prom", default="",
                    help="write a Prometheus text-format metrics snapshot")
    args = ap.parse_args()

    plan = build_plan(args)
    cfg = configs.smoke(args.arch) if args.smoke else configs.get(args.arch)
    if args.fmt is None:
        # QoS objective → registry format (replica-level contract: weights
        # are packed once at load, so the class picks THIS engine's format),
        # restricted to formats whose K alignment divides this model's
        # layer dims (grouped _g128 variants need K % 128 == 0)
        dims = {cfg.d_model, cfg.d_ff or cfg.d_model}
        compat = [n for n in formats.names()
                  if all(k % formats.get(n).k_align == 0 for k in dims)]
        args.fmt = (qos_mod.select_format(
            "standard" if args.qos in (None, "mixed") else args.qos,
            candidates=compat))
        if args.qos:
            print(f"[serve] qos={args.qos} -> fmt={args.fmt}")
    if args.act == "tensor" and (args.slots > 1 or args.prefill_chunk > 1):
        # the composition-dependent-logits caveat (DESIGN.md §7): one absmax
        # per step means a request's logits depend on what it is batched with
        print("[serve] WARNING: per-TENSOR activation quant with batched "
              f"serving (slots={args.slots}, chunk={args.prefill_chunk}) ties "
              "each request's logits to the step's batch composition; use the "
              "default --act token for composition-invariant serving")
    if args.speculate > 0 and args.act == "tensor":
        # for speculation this is a refusal, not a warning: the [B, K+1]
        # verify would score different logits than the [B, 1] decode it
        # replaces, so greedy acceptance could not be bit-identical
        print("[serve] ERROR: --speculate needs composition-invariant "
              "logits; per-TENSOR activation quant ties them to the step "
              "batch, so drafted tokens could not be verified exactly. "
              "Use the default --act token (the supported mode) or drop "
              "--speculate.")
        raise SystemExit(2)
    cfg = cfg.replace(dtype="float32",
                      quant=QuantConfig(mode="quant", fmt=args.fmt, plan=plan,
                                        act=args.act))

    if args.autotune_cache:
        import os
        if os.path.exists(args.autotune_cache):
            dispatch.load_cache(args.autotune_cache)
            print(f"[serve] loaded autotune cache {args.autotune_cache} "
                  f"({len(dispatch.active_cache().entries)} entries)")

    d, f = cfg.d_model, cfg.d_ff or cfg.d_model
    batch_ns = [1, args.slots]
    if args.prefill_chunk > 1:
        if args.prefill_budget > 0:
            # the batched concurrent prefill tick always runs at N = S·C
            # (S capped by the slot count exactly as the engine caps it);
            # the per-slot N = chunk shape never dispatches in this mode
            from repro.serve.scheduler import max_prefill_rows
            batch_ns.append(max_prefill_rows(args.prefill_budget,
                                             args.prefill_chunk, args.slots)
                            * args.prefill_chunk)
        else:
            batch_ns.append(args.prefill_chunk)
    if args.speculate > 0:
        # the verify batch (B·(K+1)) and the draft-ingest width — the exact
        # shapes the engine pins via register_chunk_bucket, so --explain and
        # --autotune see the regime the verify call will actually ride
        batch_ns.append(args.slots * (args.speculate + 1))
        batch_ns.append(args.slots * max(args.speculate + 1,
                                         args.prefill_chunk))
    batch_ns = sorted(set(batch_ns))
    layer_shapes = [(n, k, m) for n in batch_ns
                    for (k, m) in ((d, d), (d, f), (f, d))]
    if args.tp > 1:
        # TP dispatches the SHARD-LOCAL contraction (M/tp under the engine's
        # column-parallel layout) — explain/autotune the shapes that run
        layer_shapes = dispatch.shard_shapes(layer_shapes, tp=args.tp)
    if args.explain:
        for n, k, m in layer_shapes:
            print(dispatch.explain(args.fmt, n, k, m, plan))
        return
    if args.autotune:
        dispatch.autotune(args.fmt, layer_shapes)
        if args.autotune_cache:
            dispatch.active_cache().save(args.autotune_cache)
            print(f"[serve] autotune winners saved to {args.autotune_cache}")

    params = lm.init(jax.random.PRNGKey(0), cfg)
    if args.ckpt:
        from repro.ckpt import store
        params, _ = store.restore(params, args.ckpt)

    obs = make_obs(args)
    mesh = make_tp_mesh(args)
    eng = make_engine(args, params, cfg, obs, mesh)
    rng = np.random.default_rng(args.seed)
    templates = None
    if args.prefix_cache:
        if getattr(eng, "prefix_inert_reason", None):
            print(f"[serve] prefix cache inert: {eng.prefix_inert_reason}")
        templates = [rng.integers(0, cfg.vocab,
                                  size=2 * args.block_size).tolist()
                     for _ in range(max(1, args.requests // 3))]

    t0 = time.perf_counter()
    if args.bursty:
        done = []
        for b in range(args.bursty):
            submit_burst(eng, cfg, args, rng,
                         range(b * args.requests, (b + 1) * args.requests),
                         args.max_new, templates)
            for _ in range(args.max_new // 2 + 1):  # partial drain per burst
                done.extend(eng.step())
        while eng.sched.pending or any(s is not None for s in eng.slots):
            done.extend(eng.step())
    else:
        submit_burst(eng, cfg, args, rng, range(args.requests), args.max_new,
                     templates)
        done = eng.run()
    dt = time.perf_counter() - t0

    toks = sum(len(r.out_tokens) for r in done)
    mode = (f"paged(bs={args.block_size})" if args.paged else "dense") + \
           (f"+chunk{args.prefill_chunk}" if args.prefill_chunk > 1 else "+token") + \
           (f"+budget{args.prefill_budget}" if args.prefill_budget > 0 else "") + \
           (f"+spec{args.speculate}" if args.speculate > 0 else "")
    if args.tp > 1:
        mode += f"+tp{args.tp}"
    print(f"[serve] {args.arch} fmt={args.fmt} {mode}: "
          f"{len(done)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s on CPU; see benchmarks for TPU projections)")
    if isinstance(eng, ServeEngine) and not isinstance(eng, Engine):
        s = eng.metrics_summary()
        print(f"  ttft p50/p95 = {s['ttft_p50']:.3f}/{s['ttft_p95']:.3f}s  "
              f"queue p95 = {s['queue_wait_p95']:.3f}s  "
              f"preemptions = {s['preemptions']}"
              + (f"  kv free/shared/total = {s['kv_blocks_free']}"
                 f"/{s['kv_blocks_shared']}/{s['kv_blocks']}"
                 if args.paged else ""))
        if args.prefix_cache:
            # structured prefix-hit telemetry: per-admission events live on
            # the tracer (--trace-out); the printed line renders the same
            # structured summary through the one canonical formatter
            print(obs_mod.format_prefix_summary(s))
        if args.speculate > 0 and s.get("spec_steps"):
            print(f"  spec[{s['spec_draft']}] k={s['speculate_k']}: "
                  f"accepted/step = {s['spec_accepted_per_step']:.2f} "
                  f"(1.0 = plain decode), acceptance = "
                  f"{s['spec_acceptance_rate'] or 0.0:.2f} over "
                  f"{s['spec_tokens_drafted']} drafted "
                  f"({s['spec_tokens_rejected']} rejected)")
    routed = sorted({(dc.regime, dc.n, dc.kernel, dc.source)
                     for dc in eng.kernel_decisions()})
    for regime, n, kernel, source in routed:
        print(f"  routed {regime} (N={n}) -> {kernel} [{source}]")
    for r in sorted(done, key=lambda r: r.rid)[:3]:
        print(f"  req{r.rid}: prompt={r.prompt} -> {r.out_tokens}")

    if obs is not None:
        import json
        if args.trace_out:
            obs.tracer.save(args.trace_out)
            print(f"[serve] trace -> {args.trace_out} "
                  f"({len(obs.tracer.chrome_events())} events; open at "
                  "https://ui.perfetto.dev)")
        if args.metrics_json or args.metrics_prom:
            blob = obs_mod.metrics_blob(obs)
            if isinstance(eng, ServeEngine):
                blob["serve"] = eng.metrics_summary()
            if args.metrics_json:
                with open(args.metrics_json, "w") as f:
                    json.dump(blob, f, indent=1, default=str)
                nrows = len(blob["measured_vs_predicted"]["rows"])
                print(f"[serve] metrics -> {args.metrics_json} "
                      f"({nrows} kernel-attribution rows, "
                      f"{blob['dispatch']['decisions_dropped']} decisions "
                      "dropped)")
            if args.metrics_prom:
                with open(args.metrics_prom, "w") as f:
                    f.write(obs.metrics.to_prometheus())
                print(f"[serve] prometheus snapshot -> {args.metrics_prom}")


if __name__ == "__main__":
    main()
