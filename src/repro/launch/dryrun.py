import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST run before any jax import — jax locks the device
count at first init.  512 host devices back both the 16×16 single-pod mesh
(first 256) and the 2×16×16 multi-pod mesh.

Per cell this script:
  1. builds the production mesh and the cell's ShapeDtypeStruct input specs,
  2. pjit-lowers the real step function (train_step / prefill / serve_step)
     with explicit in/out shardings from repro.distributed.sharding,
  3. compiles (proving the distribution config is coherent: no sharding
     mismatches, no unsupported collectives, memory fits),
  4. records memory_analysis, cost_analysis and the collective schedule
     parsed from the optimized per-device HLO into a JSON artifact that
     benchmarks/roofline.py and EXPERIMENTS.md consume.

Usage:
    python -m repro.launch.dryrun --arch gemma3-4b --shape train_4k [--multi-pod]
    python -m repro.launch.dryrun --all [--multi-pod] [--out results/dryrun]
"""

import argparse
import json
import subprocess
import sys
import time
import traceback

import jax

from repro import configs
from repro.distributed import sharding
from repro.launch import hlocost, roofline, shapes
from repro.launch.mesh import make_production_mesh
from repro.models import lm
from repro.train import loop as train_loop


def _out_unspecified(tree):
    return None


def lower_cell(arch: str, shape: str, *, multi_pod: bool, fmt: str = "i2s",
               extra_cfg: dict | None = None, microbatches: int = 16):
    """Build mesh + specs and return (lowered, cfg, cell, mesh)."""
    mesh = make_production_mesh(multi_pod=multi_pod)
    sharding.set_mesh(mesh)  # enables P-spec sharding constraints in the model body
    cell = shapes.SHAPES[shape]
    cfg = shapes.dryrun_config(configs.get(arch), cell.kind, fmt=fmt)
    dp = ("pod", "data") if multi_pod else ("data",)
    dp_size = 32 if multi_pod else 16
    if cell.global_batch % dp_size == 0:
        # d_model slice on "model" too: remat-saved residuals shard 16× (the
        # per-layer all-gather this costs stays far under the compute term)
        mdl = "model" if configs.get(arch).d_model % 16 == 0 else None
        cfg = cfg.replace(act_shard=(dp, None, mdl))
    elif cell.kind != "decode" and cell.seq_len % dp_size == 0:
        cfg = cfg.replace(act_shard=(None, "data", None))  # SP fallback
    if extra_cfg:
        extra = dict(extra_cfg)
        if "act_shard" in extra and extra["act_shard"] is not None:
            extra["act_shard"] = tuple(
                tuple(a) if isinstance(a, list) else a for a in extra["act_shard"]
            )
        cfg = cfg.replace(**extra)

    if cell.kind == "train":
        # grad accumulation bounds the live activation set; fsdp grad spec
        # keeps the accumulator reduce-scattered (ZeRO gradient sharding)
        tcfg = train_loop.TrainConfig(microbatches=microbatches, grad_spec="fsdp")
        specs = shapes.input_specs(cfg, cell, tcfg)
        step = train_loop.make_train_step(cfg, tcfg)
        in_sh = (
            sharding.shard_params(specs["state"], mesh, "train"),
            sharding.shard_batch(specs["batch"], mesh),
        )
        out_sh = (sharding.shard_params(specs["state"], mesh, "train"), None)
        lowered = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                          donate_argnums=(0,)).lower(
            specs["state"], specs["batch"]
        )
    elif cell.kind == "prefill":
        specs = shapes.input_specs(cfg, cell)

        def prefill_fn(params, batch, state):
            return lm.prefill(params, batch, cfg, state)

        in_sh = (
            sharding.shard_params(specs["params"], mesh, "infer"),
            sharding.shard_batch(specs["batch"], mesh),
            sharding.shard_state(specs["state"], mesh, batch=cell.global_batch),
        )
        out_sh = (None, sharding.shard_state(specs["state"], mesh, batch=cell.global_batch))
        lowered = jax.jit(prefill_fn, in_shardings=in_sh, out_shardings=out_sh,
                          donate_argnums=(2,)).lower(
            specs["params"], specs["batch"], specs["state"]
        )
    else:  # decode / serve_step
        specs = shapes.input_specs(cfg, cell)

        def serve_step(params, tok, pos, state):
            return lm.decode_step(params, tok, pos, cfg, state)

        st_sh = sharding.shard_state(specs["state"], mesh, batch=cell.global_batch)
        in_sh = (
            sharding.shard_params(specs["params"], mesh, "infer"),
            sharding.shard_batch(specs["tok"], mesh),
            None,
            st_sh,
        )
        out_sh = (None, st_sh)
        lowered = jax.jit(serve_step, in_shardings=in_sh, out_shardings=out_sh,
                          donate_argnums=(3,)).lower(
            specs["params"], specs["tok"], specs["pos"], specs["state"]
        )
    return lowered, cfg, cell, mesh


def run_cell(arch: str, shape: str, *, multi_pod: bool, fmt: str = "i2s",
             out_dir: str = "results/dryrun", extra_cfg: dict | None = None,
             tag: str = "", microbatches: int = 16) -> dict:
    t0 = time.time()
    lowered, cfg, cell, mesh = lower_cell(arch, shape, multi_pod=multi_pod,
                                          fmt=fmt, extra_cfg=extra_cfg,
                                          microbatches=microbatches)
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    mem_d = {}
    if mem is not None:
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            mem_d[k] = int(getattr(mem, k, 0) or 0)
    print(compiled.memory_analysis())

    cost = compiled.cost_analysis() or {}
    print({k: v for k, v in cost.items() if k in ("flops", "bytes accessed")})
    xla_flops = float(cost.get("flops", 0.0))
    xla_bytes = float(cost.get("bytes accessed", 0.0))

    hlo = compiled.as_text()
    # Primary accounting: trip-count-aware walker (XLA's cost_analysis counts
    # while/scan bodies once — useless for scan-structured models).
    hc = hlocost.analyze(hlo)
    flops = hc["flops"]
    bytes_acc = hc["bytes"]
    coll = hc["collectives"]
    coll.update({f"once_{k}": v for k, v in roofline.collective_bytes(hlo).items()
                 if k.startswith("n_")})
    terms = roofline.terms(flops, bytes_acc, coll["total"])

    nums = roofline.model_numbers(cfg)
    mflops = roofline.model_flops(cfg, cell, nums["n_active"])
    chips = mesh.size

    rec = {
        "arch": arch, "shape": shape, "mesh": list(mesh.shape.values()),
        "axes": list(mesh.axis_names), "chips": chips, "fmt": fmt, "tag": tag,
        "kind": cell.kind,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "flops_per_device": flops, "bytes_per_device": bytes_acc,
        "xla_once_flops": xla_flops, "xla_once_bytes": xla_bytes,
        "collectives": coll, "memory_analysis": mem_d,
        "terms": terms,
        "model": {**nums, "model_flops_global": mflops,
                  "model_flops_per_device": mflops / chips,
                  "useful_flop_frac": (mflops / chips) / flops if flops else 0.0},
    }
    os.makedirs(out_dir, exist_ok=True)
    mesh_tag = "pod2" if multi_pod else "pod1"
    suffix = f"_{tag}" if tag else ""
    fname = f"{arch}_{shape}_{mesh_tag}{suffix}.json"
    with open(os.path.join(out_dir, fname), "w") as f:
        json.dump(rec, f, indent=1)
    print(f"[dryrun] {arch} × {shape} × {mesh_tag}: compile ok in "
          f"{t_compile:.1f}s; bound={terms['bound']} step={terms['step_s']*1e3:.2f}ms")
    return rec


def run_all(multi_pod: bool, out_dir: str, fmt: str, skip_existing: bool = True):
    """Drive every applicable cell in an isolated subprocess."""
    mesh_tag = "pod2" if multi_pod else "pod1"
    failures = []
    for arch in configs.ASSIGNED:
        for shape in shapes.SHAPES:
            if not shapes.applicable(arch, shape):
                continue
            fname = os.path.join(out_dir, f"{arch}_{shape}_{mesh_tag}.json")
            if skip_existing and os.path.exists(fname):
                print(f"[skip] {fname}")
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
                   "--shape", shape, "--out", out_dir, "--fmt", fmt]
            if multi_pod:
                cmd.append("--multi-pod")
            print("[run]", " ".join(cmd), flush=True)
            r = subprocess.run(cmd)
            if r.returncode != 0:
                failures.append((arch, shape))
    if failures:
        print("FAILURES:", failures)
        sys.exit(1)
    print("all cells compiled OK")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(shapes.SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--fmt", default="i2s")
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--cfg-json", default=None,
                    help="JSON dict of ModelConfig overrides (perf iteration)")
    ap.add_argument("--mb", type=int, default=16, help="train microbatches")
    args = ap.parse_args()

    if args.all:
        run_all(args.multi_pod, args.out, args.fmt)
        return
    extra = json.loads(args.cfg_json) if args.cfg_json else None
    try:
        run_cell(args.arch, args.shape, multi_pod=args.multi_pod, fmt=args.fmt,
                 out_dir=args.out, extra_cfg=extra, tag=args.tag,
                 microbatches=args.mb)
    except Exception:
        traceback.print_exc()
        sys.exit(1)


if __name__ == "__main__":
    main()
