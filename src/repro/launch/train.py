"""Production training launcher.

Builds the mesh, applies the sharding rules, wires the checkpoint store +
fault-tolerant runner, and trains.  The same entry point drives:
  * CPU smoke:   python -m repro.launch.train --arch qwen1.5-0.5b --smoke --steps 20
  * production:  launched per-host under a jax.distributed world, with
                 --mesh data,model (single pod) or pod,data,model.

On a real cluster `jax.distributed.initialize()` runs first (env-driven);
on this container the mesh falls back to the available devices.
"""

from __future__ import annotations

import argparse
import os

import jax
import numpy as np

from repro import configs
from repro.core.bitlinear import QuantConfig
from repro.data.pipeline import DataConfig, DataIterator
from repro.distributed import fault, sharding
from repro.launch.mesh import make_mesh
from repro.models import lm
from repro.train import loop as train_loop
from repro.train import optimizer as opt


def build(args):
    cfg = configs.smoke(args.arch) if args.smoke else configs.get(args.arch)
    cfg = cfg.replace(dtype=args.dtype, remat=not args.smoke,
                      quant=QuantConfig(mode="qat"))
    tcfg = train_loop.TrainConfig(
        opt=opt.OptConfig(lr=args.lr, warmup_steps=args.warmup,
                          total_steps=args.steps),
        microbatches=args.microbatches,
        grad_compress=args.grad_compress,
        grad_spec="fsdp" if args.mesh else "",
    )
    return cfg, tcfg


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="bitnet-b1.58-700m")
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--warmup", type=int, default=10)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-compress", default="none",
                    choices=["none", "bf16", "bf16_ef"])
    ap.add_argument("--dtype", default="float32")
    ap.add_argument("--mesh", default="", help="e.g. '2x4' -> (data,model)")
    ap.add_argument("--ckpt-dir", default="results/ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    if os.environ.get("JAX_COORDINATOR"):  # multi-host bring-up
        jax.distributed.initialize()

    cfg, tcfg = build(args)
    dc = DataConfig(vocab=cfg.vocab, seq_len=args.seq_len,
                    global_batch=args.global_batch,
                    n_hosts=jax.process_count(), host_id=jax.process_index())
    it = DataIterator(dc)

    state = train_loop.init_train_state(jax.random.PRNGKey(0), cfg, tcfg)
    shardings = None
    if args.mesh:
        shape = tuple(int(x) for x in args.mesh.split("x"))
        mesh = make_mesh(shape, ("data", "model")[: len(shape)] if len(shape) == 2
                         else ("pod", "data", "model"))
        sharding.set_mesh(mesh)
        shardings = sharding.shard_params(state, mesh, "train")
        state = jax.device_put(state, shardings)
        step_fn = jax.jit(train_loop.make_train_step(cfg, tcfg),
                          in_shardings=(shardings, sharding.shard_batch(next(DataIterator(dc)), mesh)),
                          out_shardings=(shardings, None), donate_argnums=(0,))
    else:
        step_fn = jax.jit(train_loop.make_train_step(cfg, tcfg))

    if args.resume:
        from repro.ckpt import store
        last = store.latest_step(args.ckpt_dir)
        if last is not None:
            state, extra = store.restore(state, args.ckpt_dir, last, shardings=shardings)
            it.state.step = int(extra.get("data_step", 0))
            print(f"[train] resumed from step {last}")

    runner = fault.ResilientRunner(step_fn, args.ckpt_dir,
                                   ckpt_every=args.ckpt_every)
    state, history = runner.run(state, it, args.steps, shardings=shardings)
    losses = [float(m["loss"]) for m in history]
    print(f"[train] {args.arch}: loss {losses[0]:.4f} -> {losses[-1]:.4f} "
          f"over {len(losses)} steps; stragglers={len(runner.straggler.events)}")


if __name__ == "__main__":
    main()
