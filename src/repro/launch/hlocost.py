"""Trip-count-aware cost model over optimized HLO text.

XLA's built-in cost_analysis() visits each instruction ONCE — `while` bodies
(every lax.scan: the layer stack, the attention KV-block loop) are counted a
single time regardless of trip count, which silently undercounts flops,
bytes and collective payloads by orders of magnitude on scan-structured
models.  This walker re-derives the costs with loops multiplied out:

  * flops: dot = 2 · |result| · |contracted dims|; elementwise ≈ |result|;
    fusion = Σ inner instruction flops.
  * bytes (roofline HBM model): operands + results for compute ops, but
    slice-shaped access for dynamic-slice / gather (2·|slice|) and
    dynamic-update-slice (2·|update|) — an in-place cache update touches the
    update bytes, not the whole buffer (this matches what a TPU actually
    streams, unlike the naive operand sum).
  * collectives: result-shape bytes per op kind, trip-multiplied.
  * while: trip count parsed from the loop condition's s32 constant
    (lax.scan always lowers to `lt(i, const)`).

Costs are per-device: the walker runs on the post-SPMD per-device module.
"""

from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "pred": 1, "s2": 0.25, "u2": 0.25, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f16": 2,
    "bf16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1,
    "f8e5m2": 1, "token": 0, "opaque": 0,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_ARR = re.compile(r"(\w+)\[([\d,]*)\]")
_FREE = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
         "after-all", "partition-id", "replica-id", "iota", "custom-call",
         "rng-bit-generator", "opt-barrier"}

# data movement: contributes bytes, never flops
_NONARITH = _FREE | {"broadcast", "copy", "transpose", "reshape", "convert",
                     "select", "compare", "slice", "concatenate", "pad",
                     "reverse", "dynamic-slice", "dynamic-update-slice",
                     "gather", "scatter", "clamp", "shift-right-logical",
                     "shift-left", "shift-right-arithmetic", "and", "or",
                     "xor", "not"}


def _arr_bytes(dt: str, dims: str) -> float:
    if dt not in _DTYPE_BYTES:
        return 0.0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


def _type_bytes(s: str) -> float:
    return sum(_arr_bytes(dt, dims) for dt, dims in _ARR.findall(s))


def _type_elems(s: str) -> float:
    total = 0
    for _, dims in _ARR.findall(s):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n
    return total


@dataclasses.dataclass
class Inst:
    name: str
    result: str
    op: str
    operands: str
    attrs: str


def _split_balanced(s: str, start: int) -> int:
    """Index just past the matching ')' for the '(' at s[start]."""
    depth = 0
    for i in range(start, len(s)):
        if s[i] == "(":
            depth += 1
        elif s[i] == ")":
            depth -= 1
            if depth == 0:
                return i + 1
    return len(s)


_OPERAND_NAME = re.compile(r"%([\w.\-]+)")


def operand_types(inst: Inst, symtab: dict) -> list:
    """Resolve operand names to result types (HLO may omit inline types)."""
    inline = _ARR.findall(inst.operands)
    if inline:
        return [f"{dt}[{dims}]" for dt, dims in inline]
    return [symtab.get(n, "") for n in _OPERAND_NAME.findall(inst.operands)]


def parse_module(text: str) -> dict:
    """computation name -> [Inst]; key '__entry__' aliases the ENTRY comp."""
    comps: dict = {}
    cur = None
    entry = None
    for raw in text.splitlines():
        line = raw.rstrip()
        m = re.match(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.*\{$", line)
        if m and not line.startswith(" "):
            cur = m.group(2)
            comps[cur] = []
            if m.group(1):
                entry = cur
            continue
        if line == "}":
            cur = None
            continue
        if cur is None:
            continue
        s = line.strip()
        if s.startswith("ROOT "):
            s = s[5:]
        eq = s.find(" = ")
        if eq < 0:
            continue
        name = s[:eq].lstrip("%")
        rest = s[eq + 3:]
        # result type: balanced tuple or single token
        if rest.startswith("("):
            end = _split_balanced(rest, 0)
            result = rest[:end]
            rest = rest[end:].lstrip()
        else:
            sp = rest.find(" ")
            result = rest[:sp]
            rest = rest[sp + 1:]
        par = rest.find("(")
        if par < 0:
            continue
        op = rest[:par].strip()
        end = _split_balanced(rest, par)
        operands = rest[par + 1 : end - 1]
        attrs = rest[end:].lstrip(", ")
        comps[cur].append(Inst(name, result, op, operands, attrs))
    if entry:
        comps["__entry__"] = comps[entry]
    return comps


def _trip_count(comps: dict, cond_name: str) -> int:
    """lax.scan condition: compare(i, constant(N)) — take the s32 constant."""
    best = None
    for inst in comps.get(cond_name, []):
        if inst.op == "constant" and inst.result.startswith("s32[]"):
            m = re.search(r"constant\((\-?\d+)\)", f"{inst.op}({inst.operands})")
            if m:
                v = int(m.group(1))
                best = v if best is None else max(best, v)
        if inst.op == "fusion":
            called = re.search(r"calls=%?([\w.\-]+)", inst.attrs)
            if called:
                t = _trip_count(comps, called.group(1))
                if t > 1:
                    best = t if best is None else max(best, t)
    return best if best and best > 0 else 1


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: dict = dataclasses.field(default_factory=dict)
    coll_total: float = 0.0

    def __iadd__(self, o: "Cost"):
        self.flops += o.flops
        self.bytes += o.bytes
        self.coll_total += o.coll_total
        for k, v in o.coll.items():
            self.coll[k] = self.coll.get(k, 0.0) + v
        return self

    def scaled(self, f: float) -> "Cost":
        return Cost(self.flops * f, self.bytes * f,
                    {k: v * f for k, v in self.coll.items()}, self.coll_total * f)


def _build_symtabs(comps: dict) -> dict:
    return {cname: {i.name: i.result for i in insts}
            for cname, insts in comps.items()}


def _dot_flops(inst: Inst, symtab: dict) -> float:
    res = _type_elems(inst.result)
    otypes = operand_types(inst, symtab)
    if not otypes or not otypes[0]:
        return 2.0 * res  # unknown lhs: degrade to elementwise estimate
    lhs = _ARR.search(otypes[0])
    if not lhs:
        return 2.0 * res
    dims = [int(d) for d in lhs.group(2).split(",") if d]
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", inst.attrs)
    contract = 1
    if m and m.group(1):
        for i in m.group(1).split(","):
            contract *= dims[int(i)]
    return 2.0 * res * contract


def _operand_bytes(inst: Inst, symtab: dict) -> float:
    return sum(_type_bytes(t) for t in operand_types(inst, symtab))


def _fusion_operand_bytes(inst: Inst, inner_insts: list, symtab: dict) -> float:
    """Operand traffic of a fusion, at *consumed* granularity.

    A fusion whose parameter is touched only through dynamic-slice / gather
    (e.g. selecting one layer's weights from a scan-stacked array) streams
    the slice, not the whole operand — billing the full stacked array would
    overcount a 62-layer stack 62×.
    """
    otypes = operand_types(inst, symtab)
    # parameter index -> (sliced_bytes_so_far, touched_wholesale)
    sliced: dict = {}
    whole: set = set()
    pname_to_idx: dict = {}
    for fi in inner_insts:
        if fi.op == "parameter":
            m = re.match(r"parameter", fi.op)
            pm = re.search(r"parameter\((\d+)\)", f"{fi.op}({fi.operands})")
            if pm:
                pname_to_idx[fi.name] = int(pm.group(1))
    for fi in inner_insts:
        if fi.op == "parameter":
            continue
        names = _OPERAND_NAME.findall(fi.operands)
        for pos, n in enumerate(names):
            if n not in pname_to_idx:
                continue
            idx = pname_to_idx[n]
            if fi.op in ("dynamic-slice", "gather") and pos == 0:
                sliced[idx] = sliced.get(idx, 0.0) + _type_bytes(fi.result)
            else:
                whole.add(idx)
    total = 0.0
    for idx, t in enumerate(otypes):
        full = _type_bytes(t)
        if idx in whole or idx not in sliced:
            total += full
        else:
            total += min(full, sliced[idx])
    return total


def _inst_cost(comps: dict, symtabs: dict, cname: str, inst: Inst, memo: dict) -> Cost:
    op = inst.op
    symtab = symtabs.get(cname, {})
    c = Cost()
    if op in _FREE:
        return c
    if op == "while":
        cond = re.search(r"condition=%?([\w.\-]+)", inst.attrs)
        body = re.search(r"body=%?([\w.\-]+)", inst.attrs)
        trips = _trip_count(comps, cond.group(1)) if cond else 1
        inner = Cost()
        if body:
            inner += _comp_cost(comps, symtabs, body.group(1), memo)
        if cond:
            inner += _comp_cost(comps, symtabs, cond.group(1), memo)
        return inner.scaled(trips)
    if op == "conditional":
        branches = re.findall(r"(?:branch_computations=\{|true_computation=|false_computation=)%?([\w.\-]+)", inst.attrs)
        for b in re.findall(r"%([\w.\-]+)", inst.attrs) if not branches else branches:
            c += _comp_cost(comps, symtabs, b, memo)
        return c
    if op == "call":
        m = re.search(r"to_apply=%?([\w.\-]+)", inst.attrs)
        if m:
            c += _comp_cost(comps, symtabs, m.group(1), memo)
        return c
    if op == "fusion":
        m = re.search(r"calls=%?([\w.\-]+)", inst.attrs)
        fname = m.group(1) if m else ""
        inner_insts = comps.get(fname, [])
        ftab = symtabs.get(fname, {})
        for fi in inner_insts:
            if fi.op == "dot":
                c.flops += _dot_flops(fi, ftab)
            elif fi.op not in _NONARITH:
                c.flops += _type_elems(fi.result)
        # in-place stacked-buffer update: any inner DUS producing the full
        # fusion result element count (scan stashes, cache writes, grad
        # accumulators) — compared in elements: a convert may change dtype
        res_b = _type_bytes(inst.result)
        res_e = _type_elems(inst.result)
        root_dus = any(
            fi.op == "dynamic-update-slice" and _type_elems(fi.result) == res_e
            for fi in inner_insts
        )
        if root_dus:
            # in-place cache update: touch the update, not the buffer
            ops_b = _operand_bytes(inst, symtab)
            c.bytes += 2.0 * max(ops_b - res_b, 0.0) + 1024
        else:
            c.bytes += _fusion_operand_bytes(inst, inner_insts, symtab) + res_b
        return c
    if op in COLLECTIVES or any(op == k + "-start" for k in COLLECTIVES):
        base = next(k for k in COLLECTIVES if op.startswith(k))
        b = _type_bytes(inst.result)
        c.coll[base] = c.coll.get(base, 0.0) + b
        c.coll_total += b
        c.bytes += b  # payload also moves through HBM
        return c
    if op in ("dynamic-slice", "gather"):
        c.bytes += 2.0 * _type_bytes(inst.result)
        return c
    if op == "dynamic-update-slice":
        ops_b = _operand_bytes(inst, symtab)
        c.bytes += 2.0 * max(ops_b - _type_bytes(inst.result), 0.0) + 1024
        return c
    if op == "scatter":
        c.bytes += 2.0 * _operand_bytes(inst, symtab) - _type_bytes(inst.result)
        c.flops += _type_elems(inst.result)
        return c
    if op == "dot":
        c.flops += _dot_flops(inst, symtab)
        c.bytes += _operand_bytes(inst, symtab) + _type_bytes(inst.result)
        return c
    # generic op: arithmetic counts flops; movement counts bytes only
    if op not in _NONARITH:
        c.flops += _type_elems(inst.result)
    c.bytes += _operand_bytes(inst, symtab) + _type_bytes(inst.result)
    return c


def _comp_cost(comps: dict, symtabs: dict, name: str, memo: dict) -> Cost:
    if name in memo:
        return memo[name]
    total = Cost()
    for inst in comps.get(name, []):
        total += _inst_cost(comps, symtabs, name, inst, memo)
    memo[name] = total
    return total


def analyze(hlo_text: str) -> dict:
    comps = parse_module(hlo_text)
    symtabs = _build_symtabs(comps)
    memo: dict = {}
    c = _comp_cost(comps, symtabs, "__entry__", memo)
    coll = {k: c.coll.get(k, 0.0) for k in COLLECTIVES}
    return {
        "flops": c.flops,
        "bytes": c.bytes,
        "collectives": {**coll, "total": c.coll_total},
    }
