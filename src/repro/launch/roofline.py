"""Roofline term extraction from compiled dry-run artifacts.

    compute    = HLO_FLOPs_per_device / peak_FLOP/s
    memory     = HLO_bytes_per_device / HBM_bw
    collective = collective_bytes_per_device / link_bw

cost_analysis() runs on the post-SPMD per-device module, so its numbers are
already per-chip.  Collective bytes are not in cost_analysis: we parse the
optimized HLO text and sum the result-shape bytes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute (per-device
payload, by the same per-device-module argument).

Hardware model (TPU v5e, per the brief):
    197 TFLOP/s bf16 · 819 GB/s HBM · ~50 GB/s/link ICI.
"""

from __future__ import annotations

import re

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_ARRAY_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> float:
    """Sum bytes over every array in a (possibly tuple) HLO type string."""
    total = 0.0
    for dt, dims in _ARRAY_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-device payload bytes by collective kind, from optimized HLO."""
    out = {k: 0.0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.match(r"^(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.+?)\s+([\w\-]+)\(", stripped)
        if not m:
            continue
        type_str, op = m.groups()
        # normalize fused variants like all-gather-start / all-reduce-done
        base = None
        for c in _COLLECTIVES:
            if op == c or op.startswith(c + "-start"):
                base = c
                break
        if base is None:
            continue
        out[base] += _shape_bytes(type_str)
        counts[base] += 1
    out_counts = {f"n_{k}": v for k, v in counts.items()}
    return {**out, **out_counts, "total": sum(out[k] for k in _COLLECTIVES)}


def terms(flops: float, bytes_accessed: float, coll_bytes: float) -> dict:
    """Three roofline terms in seconds + the dominant one."""
    t = {
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": bytes_accessed / HBM_BW,
        "collective_s": coll_bytes / LINK_BW,
    }
    t["bound"] = max(("compute_s", "memory_s", "collective_s"), key=lambda k: t[k])
    t["step_s"] = max(t["compute_s"], t["memory_s"], t["collective_s"])
    return t


def model_numbers(cfg) -> dict:
    """Analytic parameter counts: total and active (MoE top-k)."""
    d, f, v = cfg.d_model, cfg.d_ff, cfg.vocab
    h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    kinds = cfg.layer_kinds()
    per_attn = d * (h * dh) + 2 * d * (kv * dh) + (h * dh) * d
    per_ffn_dense = 3 * d * f
    total = active = v * d  # embedding (tied head)
    for k in kinds + (["enc"] * cfg.enc_layers):
        if k in ("attn", "local", "enc"):
            total += per_attn
            active += per_attn
        elif k == "xattn":
            total += 2 * per_attn
            active += 2 * per_attn
        elif k == "rec":
            total += 2 * d * cfg.d_inner + cfg.d_inner * d
            active += 2 * d * cfg.d_inner + cfg.d_inner * d
        elif k == "ssd":
            di, s, hh = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
            n = d * (2 * di + 2 * s + hh) + di * d
            total += n
            active += n
        if cfg.d_ff > 0 and k != "ssd":
            if cfg.ffn_kind == "moe":
                total += cfg.n_experts * per_ffn_dense
                active += cfg.top_k * per_ffn_dense
            else:
                total += per_ffn_dense
                active += per_ffn_dense
    return {"n_total": total, "n_active": active}


def model_flops(cfg, cell, n_active: int) -> float:
    """6·N·D train / 2·N·D inference (+ decode attention over the cache)."""
    if cell.kind == "train":
        return 6.0 * n_active * cell.global_batch * cell.seq_len
    if cell.kind == "prefill":
        return 2.0 * n_active * cell.global_batch * cell.seq_len
    flops = 2.0 * n_active * cell.global_batch
    # decode attention reads the KV cache: 4·S_eff per layer-head-dim
    for k in cfg.layer_kinds():
        if k in ("attn", "xattn"):
            s_eff = cell.seq_len
        elif k == "local":
            s_eff = min(cfg.window, cell.seq_len)
        else:
            continue
        flops += 4.0 * cell.global_batch * s_eff * cfg.n_heads * cfg.d_head
    return flops
