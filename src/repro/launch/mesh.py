"""Production mesh builders.

Functions (never module-level constants) so importing this module never
touches jax device state — the dry-run must set XLA_FLAGS before first init.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single pod (256 chips) or 2×16×16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple, axes: tuple):
    return jax.make_mesh(shape, axes)


def data_axes(mesh) -> tuple:
    """Mesh axes carrying the batch (DP): everything except 'model'."""
    return tuple(a for a in mesh.axis_names if a != "model")
