"""Sharded checkpointing: atomic, integrity-checked, async, elastic.

Layout of one checkpoint:
    <dir>/step_<N>/
        manifest.msgpack     # step, leaf paths, shapes, dtypes, crc32s, extra
        leaf_<i>.npy         # one array per pytree leaf (host-gathered)
    <dir>/step_<N>.tmp/      # staging; atomic os.replace on completion

Properties required at scale:
  * atomic: a checkpoint is visible only when complete (rename of the dir);
  * integrity: per-leaf crc32 verified on restore;
  * async: save() can run in a background thread (training continues);
  * elastic: restore() re-shards every leaf onto the CURRENT mesh via
    device_put with the target sharding — a checkpoint written on 2×16×16
    restores onto 16×16 (or 1 CPU device) unchanged;
  * GC: keep_last_k prunes old steps;
  * iterator state and train config travel in the manifest's `extra` dict.

PackedWeight / BitLinearParams are registered pytrees, so packed inference
checkpoints round-trip exactly (int4 planes are widened to int8 on disk).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import zlib

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _leaves_with_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(p), l) for p, l in flat]


def save(tree, directory: str, step: int, *, extra: dict | None = None,
         keep_last_k: int | None = None) -> str:
    """Blocking save.  Returns the final checkpoint path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    entries = []
    for i, (path, leaf) in enumerate(_leaves_with_paths(tree)):
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype == jnp.int4:  # no stable npy encoding for sub-byte
            arr = arr.astype(np.int8)
            stored_dtype = "int4"
        else:
            stored_dtype = arr.dtype.str
        fname = f"leaf_{i}.npy"
        np.save(os.path.join(tmp, fname), arr)
        entries.append({
            "path": path,
            "file": fname,
            "shape": list(arr.shape),
            "dtype": stored_dtype,
            "crc": zlib.crc32(arr.tobytes()),
        })
    manifest = {"step": step, "leaves": entries, "extra": extra or {}}
    with open(os.path.join(tmp, "manifest.msgpack"), "wb") as f:
        f.write(msgpack.packb(manifest))
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)  # atomic visibility
    if keep_last_k:
        gc(directory, keep_last_k)
    return final


class AsyncSaver:
    """One background writer; at most one save in flight (latest wins)."""

    def __init__(self):
        self._thread: threading.Thread | None = None
        self.last_path: str | None = None
        self.error: BaseException | None = None

    def save(self, tree, directory: str, step: int, **kw) -> None:
        self.wait()
        # snapshot to host before returning control to the train loop
        host_tree = jax.tree_util.tree_map(lambda l: np.asarray(jax.device_get(l)), tree)

        def run():
            try:
                self.last_path = save(host_tree, directory, step, **kw)
            except BaseException as e:  # surfaced on next wait()
                self.error = e

        self._thread = threading.Thread(target=run, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.error is not None:
            err, self.error = self.error, None
            raise err


def available_steps(directory: str) -> list:
    if not os.path.isdir(directory):
        return []
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            try:
                steps.append(int(name.split("_", 1)[1]))
            except ValueError:
                pass
    return sorted(steps)


def latest_step(directory: str) -> int | None:
    steps = available_steps(directory)
    return steps[-1] if steps else None


def restore(template, directory: str, step: int | None = None, *,
            shardings=None, mesh=None, mode: str = "infer") -> tuple:
    """Restore into the structure of `template`; returns (tree, extra).

    shardings: optional matching tree of NamedSharding — leaves are
    device_put onto it (elastic re-sharding onto the current mesh).
    mesh: convenience alternative — derive the sharding tree from the
    standard param rules (``sharding.shard_params(template, mesh, mode)``),
    so a checkpoint written unsharded restores straight onto a TP mesh with
    packed planes M-sharded and grouped scale columns travelling with their
    code rows (DESIGN.md §12).  The checkpoint bytes are mesh-agnostic
    (leaves are host-gathered at save), so save→restore round-trips exactly
    across any mesh change.
    """
    if mesh is not None:
        if shardings is not None:
            raise ValueError("pass shardings= or mesh=, not both")
        from repro.distributed import sharding as sharding_mod

        shardings = sharding_mod.shard_params(template, mesh, mode)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    cdir = os.path.join(directory, f"step_{step}")
    with open(os.path.join(cdir, "manifest.msgpack"), "rb") as f:
        manifest = msgpack.unpackb(f.read())

    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    by_path = {e["path"]: e for e in manifest["leaves"]}
    shard_flat = None
    if shardings is not None:
        shard_flat = [s for _, s in jax.tree_util.tree_flatten_with_path(shardings)[0]]

    leaves = []
    for i, (p, tmpl_leaf) in enumerate(flat):
        key = jax.tree_util.keystr(p)
        e = by_path.get(key)
        if e is None:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = np.load(os.path.join(cdir, e["file"]))
        if zlib.crc32(arr.tobytes()) != e["crc"]:
            raise IOError(f"crc mismatch for {key} in {cdir}")
        if e["dtype"] == "int4":
            arr = arr  # widened on disk; cast below via template dtype
        if tuple(arr.shape) != tuple(tmpl_leaf.shape):
            raise ValueError(f"shape mismatch for {key}: {arr.shape} vs {tmpl_leaf.shape}")
        out = jnp.asarray(arr, dtype=tmpl_leaf.dtype)
        if shard_flat is not None:
            out = jax.device_put(out, shard_flat[i])
        leaves.append(out)
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest.get("extra", {})


def gc(directory: str, keep_last_k: int) -> None:
    steps = available_steps(directory)
    for s in steps[:-keep_last_k]:
        shutil.rmtree(os.path.join(directory, f"step_{s}"), ignore_errors=True)
