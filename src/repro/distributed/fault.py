"""Fault tolerance: retries, preemption handling, straggler mitigation.

On a real cluster these hooks sit between the coordinator and the pjit step;
here they are exercised with injected failures (tests/test_distributed.py):

  * ResilientRunner — wraps the train step: on failure it restores the last
    checkpoint (params+opt+data-iterator step) and replays.  Because the data
    pipeline is a pure function of the step counter, replay is bit-exact.
  * FaultInjector — deterministic failure schedule for drills.
  * StragglerPolicy — bounded-staleness step watchdog: a step exceeding
    `timeout_factor` × the trailing-median step time is reported (and, on a
    real deployment, re-dispatched to a hot spare); here it records events.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

from repro.ckpt import store


class InjectedFault(RuntimeError):
    pass


class FaultInjector:
    """Raises InjectedFault on the scheduled (0-based) call indices."""

    def __init__(self, fail_at: set):
        self.fail_at = set(fail_at)
        self.calls = 0

    def __call__(self) -> None:
        i = self.calls
        self.calls += 1
        if i in self.fail_at:
            raise InjectedFault(f"injected failure at call {i}")


@dataclasses.dataclass
class StragglerPolicy:
    timeout_factor: float = 3.0
    window: int = 16
    times: list = dataclasses.field(default_factory=list)
    events: list = dataclasses.field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        """Returns True if this step is a straggler."""
        hist = sorted(self.times[-self.window:])
        median = hist[len(hist) // 2] if hist else None
        self.times.append(dt)
        if median is not None and dt > self.timeout_factor * max(median, 1e-9):
            self.events.append((step, dt, median))
            return True
        return False


class ResilientRunner:
    """Checkpoint/restart training driver with replay-exact recovery."""

    def __init__(self, step_fn: Callable, ckpt_dir: str, *, ckpt_every: int = 10,
                 max_restarts: int = 5, keep_last_k: int = 3,
                 fault_hook: Callable | None = None, async_save: bool = True):
        self.step_fn = step_fn
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.max_restarts = max_restarts
        self.keep_last_k = keep_last_k
        self.fault_hook = fault_hook
        self.saver = store.AsyncSaver() if async_save else None
        self.straggler = StragglerPolicy()
        self.restarts = 0

    def _save(self, state: Any, step: int, data_step: int) -> None:
        extra = {"data_step": data_step}
        if self.saver is not None:
            self.saver.save(state, self.ckpt_dir, step, extra=extra,
                            keep_last_k=self.keep_last_k)
        else:
            store.save(state, self.ckpt_dir, step, extra=extra,
                       keep_last_k=self.keep_last_k)

    def run(self, state: Any, data_iter, n_steps: int, *, shardings=None) -> tuple:
        """Runs to completion, surviving injected/step failures via restore."""
        history = []
        step = 0
        self._save(state, step, data_iter.state.step)
        while step < n_steps:
            try:
                t0 = time.monotonic()
                if self.fault_hook is not None:
                    self.fault_hook()
                batch = next(data_iter)
                state, metrics = self.step_fn(state, batch)
                self.straggler.observe(step, time.monotonic() - t0)
                history.append(metrics)
                step += 1
                if step % self.ckpt_every == 0 or step == n_steps:
                    self._save(state, step, data_iter.state.step)
            except InjectedFault:
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    raise
                if self.saver is not None:
                    self.saver.wait()
                last = store.latest_step(self.ckpt_dir)
                state, extra = store.restore(state, self.ckpt_dir, last,
                                             shardings=shardings)
                # rewind data + history to the restored step (replay-exact)
                data_iter.state.step = int(extra["data_step"])
                del history[last:]
                step = last
        if self.saver is not None:
            self.saver.wait()
        return state, history
