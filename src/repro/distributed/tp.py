"""Tensor-parallel packed mpGEMM: mesh-sharded PackedWeight execution.

This is the execution half of the TP story (DESIGN.md §12; the slicing half
is ``repro.core.qtensor.shard_m`` / ``shard_k``).  Two parallelisms:

  * **column-parallel (M-shard)** — every device holds a self-contained
    PackedWeight over a row slice of the output features (code planes row-
    sliced, the grouped [K//G, M] scale plane COLUMN-sliced so scale columns
    travel with their code rows).  Each device runs the full-K contraction
    for its rows — the same arithmetic, element for element, as the
    unsharded kernel — and the outputs concatenate.  Bit-identical to
    unsharded BY CONSTRUCTION, for any scale, lossless or not.

  * **row-parallel (K-shard)** — devices hold disjoint K-column ranges and
    the partial results reduce with ONE ``psum`` at int32-accumulator
    granularity:

      - per-tensor-scale formats: each shard's kernel runs with UNIT scales,
        so its fp32 output is exactly its int32 partial accumulator (every
        value an integer < 2^24, the same representability bound the whole
        lossless contract rests on); the psum adds those integers exactly;
        the per-tensor scale multiplies ONCE, after the reduction.  The
        result is bit-identical to the unsharded kernel for ANY scale —
        scaling partials before the reduction (the wrong granularity) is
        exact only for dyadic scales, and the sharded test tier carries a
        witness proving it diverges.

      - grouped-scale formats: shard boundaries sit on scale-group
        boundaries (``FormatSpec.shard_k_quantum``), so every group's scale
        is applied inside exactly one shard at the accumulator granularity
        the grouped kernels already use; the psum then adds exactly-scaled
        group accumulators — the same set of fp32 addends as the unsharded
        group walk.  Exact (atol=0 vs the fp64 oracle) under the conformance
        harness's dyadic scales.

Both entry points run the existing kernels unmodified through
``dispatch.mpgemm`` inside ``shard_map``, so dispatch decisions and
autotune keys record the SHARD-LOCAL M and K — the shapes that actually
execute per device.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import dispatch
from repro.core.qtensor import PackedWeight, check_shard_k, check_shard_m

__all__ = ["packed_sharding", "mpgemm_mshard", "mpgemm_kshard"]


def _axis_size(mesh, axis: str) -> int:
    if axis not in mesh.axis_names:
        raise ValueError(
            f"mesh has no axis {axis!r}; axes: {mesh.axis_names}")
    return mesh.shape[axis]


def _specs(pw: PackedWeight, axis: str, dim: str):
    """(plane specs dict, scale spec) placing shard i's slice on device i.

    Packing is K-contiguous per row, so the NamedSharding slice of each
    GLOBAL plane is byte-for-byte the shard ``qtensor.shard_m/shard_k``
    would cut — sharded placement is a layout no-op, never a repack."""
    if dim == "m":
        planes = {name: P(axis, None) for name in pw.planes}
        scale = P() if pw.scale.ndim == 0 else P(None, axis)
    elif dim == "k":
        planes = {name: P(None, axis) for name in pw.planes}
        scale = P() if pw.scale.ndim == 0 else P(axis, None)
    else:
        raise ValueError(f"dim must be 'm' or 'k', got {dim!r}")
    return planes, scale


def packed_sharding(pw: PackedWeight, mesh, *, axis: str = "model",
                    dim: str = "m") -> PackedWeight:
    """A PackedWeight-shaped tree of NamedSharding for ``jax.device_put``.

    Validates the same alignment rules as the slicing API (misaligned
    requests raise, they do not silently replicate)."""
    n = _axis_size(mesh, axis)
    if dim == "m":
        check_shard_m(pw.m, n)
    else:
        check_shard_k(pw.spec, pw.k, n)
    plane_specs, scale_spec = _specs(pw, axis, dim)
    return PackedWeight(
        {name: NamedSharding(mesh, s) for name, s in plane_specs.items()},
        NamedSharding(mesh, scale_spec), pw.fmt, pw.shape,
        three_k=pw.three_k)


def mpgemm_mshard(x_q: jax.Array, s_x, pw: PackedWeight, mesh, *,
                  axis: str = "model",
                  plan: dispatch.KernelPlan = dispatch.AUTO) -> jax.Array:
    """Column-parallel mpGEMM: int8 [..., K] × PackedWeight → fp32 [..., M].

    x replicated, weight M-sharded; shard outputs concatenate along M.
    Bit-identical to the unsharded dispatch for any scale."""
    n = _axis_size(mesh, axis)
    m_local = check_shard_m(pw.m, n)
    plane_specs, scale_spec = _specs(pw, axis, "m")
    x_spec = P(*([None] * x_q.ndim))
    out_spec = P(*([None] * (x_q.ndim - 1) + [axis]))
    s_x = jnp.asarray(s_x, jnp.float32)

    def local_fn(x, planes, scale, sx):
        lpw = PackedWeight(planes, scale, pw.fmt, (m_local, pw.k),
                           three_k=pw.three_k)
        return dispatch.mpgemm(x, sx, lpw, plan)

    fn = shard_map(local_fn, mesh=mesh,
                   in_specs=(x_spec, plane_specs, scale_spec, P()),
                   out_specs=out_spec)
    return fn(x_q, pw.planes, pw.scale, s_x)


def mpgemm_kshard(x_q: jax.Array, s_x, pw: PackedWeight, mesh, *,
                  axis: str = "model",
                  plan: dispatch.KernelPlan = dispatch.AUTO) -> jax.Array:
    """Row-parallel mpGEMM with ONE psum at int32-accumulator granularity.

    x and weight K-sharded on group-aligned boundaries; the activation's
    per-tensor/per-token scale and a per-tensor weight scale are applied
    ONLY after the cross-shard reduction (module docstring holds the
    exactness argument).  Requires a lossless kernel plan: the lossy
    requantized-LUT kernels fold ``s_x`` into their table build, which the
    deferred-scale contract cannot express."""
    n = _axis_size(mesh, axis)
    k_local = check_shard_k(pw.spec, pw.k, n)
    grouped = pw.scale.ndim != 0
    plane_specs, scale_spec = _specs(pw, axis, "k")
    x_spec = P(*([None] * (x_q.ndim - 1) + [axis]))
    out_spec = P(*([None] * x_q.ndim))
    s_x = jnp.asarray(s_x, jnp.float32)
    one = jnp.float32(1.0)

    def local_fn(x, planes, scale, sx):
        if grouped:
            # group scales already apply at accumulator granularity inside
            # the kernel, and no group straddles a shard — psum adds
            # exactly-scaled group accumulators
            lpw = PackedWeight(planes, scale, pw.fmt, (pw.m, k_local))
            return jax.lax.psum(dispatch.mpgemm(x, sx, lpw, plan), axis)
        # per-tensor: unit scales make the shard output ITS int32 partial
        # accumulator (exactly representable fp32); reduce first, scale once
        lpw = PackedWeight(planes, one, pw.fmt, (pw.m, k_local))
        acc = jax.lax.psum(dispatch.mpgemm(x, one, lpw, plan), axis)
        return acc * (sx * scale)

    fn = shard_map(local_fn, mesh=mesh,
                   in_specs=(x_spec, plane_specs, scale_spec, P()),
                   out_specs=out_spec)
    return fn(x_q, pw.planes, pw.scale, s_x)
