"""Sharding rules: logical param/state/batch axes → mesh axes (DP/TP/EP/SP).

Strategy (DESIGN.md §5):
  * BitLinear weights (fp or packed planes): output-features on "model" (TP).
    Packed planes keep their kernel tile structure intact because only the
    M dim is ever split.
  * MoE expert stacks: leading expert dim on "model" (EP).
  * Embedding / tied LM head: vocab dim on "model".
  * Batch on ("pod", "data"); if the batch can't fill the data axes
    (long_500k, global_batch=1) the sequence/cache-length dim takes "data"
    (SP / context parallelism).
  * Pattern-scan stacks carry a leading n_repeats dim → specs shift right.
  * Any proposed axis that does not divide the dim is dropped (replicated) —
    rules degrade gracefully across all 10 architectures.

Only INPUT shardings are pinned; GSPMD propagates through the model body.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P


def set_mesh(mesh) -> None:
    """``jax.set_mesh`` compat across jax versions.

    jax ≥ 0.5 exposes ``jax.set_mesh``; on 0.4.x the equivalent is entering
    the ``Mesh`` context manager, which installs the thread-local resource
    env that lets bare ``PartitionSpec`` sharding constraints resolve inside
    jit.  We enter it for process lifetime (deliberately never exited — the
    launchers set one production mesh per process)."""
    if hasattr(jax, "set_mesh"):
        jax.set_mesh(mesh)
    else:
        mesh.__enter__()


def current_mesh():
    """Read back the mesh installed by :func:`set_mesh`.

    Gated on the SAME capability probe as :func:`set_mesh` — jax versions
    that have ``get_abstract_mesh`` but not ``jax.set_mesh`` would otherwise
    return the empty abstract mesh here while ``set_mesh`` populated the
    legacy thread-local env, silently dropping every sharding axis."""
    if hasattr(jax, "set_mesh"):
        return jax.sharding.get_abstract_mesh()
    from jax._src import mesh as mesh_lib  # old jax: no public accessor

    return mesh_lib.thread_resources.env.physical_mesh


def _axis_size(mesh, name) -> int:
    if isinstance(name, tuple):
        out = 1
        for n in name:
            out *= _axis_size(mesh, n)
        return out
    return mesh.shape[name]


_AXES_DROPPED = 0


def axes_dropped() -> int:
    """Process-wide count of sharding axes ``_fit`` dropped because a dim
    was not divisible by the proposed mesh axis.  Each drop replicates that
    dim — graceful, but a degradation: surfaced through the obs metrics
    registry (``sharding_axes_dropped``, mirroring
    ``dispatch.decisions_dropped``) so a model silently serving replicated
    is observable rather than silent."""
    return _AXES_DROPPED


def _fit(spec: tuple, shape: tuple, mesh) -> P:
    """Drop spec axes that don't divide the corresponding dim (counted)."""
    global _AXES_DROPPED
    out = []
    for dim, ax in zip(shape, spec):
        if ax is None:
            out.append(None)
        elif dim % _axis_size(mesh, ax) == 0:
            out.append(ax)
        else:
            _AXES_DROPPED += 1
            out.append(None)
    out += [None] * (len(shape) - len(out))
    return P(*out)


def data_axes(mesh) -> tuple:
    axes = tuple(a for a in mesh.axis_names if a != "model")
    return axes


def _path_keys(path) -> list:
    keys = []
    for p in path:
        if hasattr(p, "key"):
            keys.append(str(p.key))
        elif hasattr(p, "idx"):
            keys.append(str(p.idx))
        elif hasattr(p, "name"):
            keys.append(str(p.name))
    return keys


def param_spec(path_keys: list, leaf, mesh, mode: str = "infer") -> P:
    """mode="infer": TP only (out-features on "model"; packed planes keep
    their kernel tile structure intact).  mode="train": ZeRO-1 — the LIVE
    (bf16) params stay TP-sharded so the forward never re-gathers weights,
    while the f32 master copy, Adam moments, and accumulated gradients shard
    over ("data","model") jointly (FSDP).  Measured on deepseek-33b train_4k:
    full-FSDP live params cost ~4.8 TB/device/step of weight all-gathers
    (16 microbatches × 62 layers); ZeRO-1 replaces that with one
    reduce-scatter + one param all-gather per step."""
    nd = leaf.ndim
    scan = 1 if "scan" in path_keys else 0
    pre = (None,) * scan
    opt_leaf = {"mu", "nu", "master", "ef"} & set(path_keys)
    # optimizer state: FSDP over EVERY mesh axis (incl. "pod" on the
    # multi-pod mesh) — at llama4-400B scale the f32 master+moments are
    # 4.8 TB and only fit when sharded 512-way
    all_axes = tuple(a for a in mesh.axis_names if a != "model") + ("model",)
    wax = all_axes if (mode == "train" and opt_leaf) else "model"

    if "experts" in path_keys:
        # EP: expert dim on model.  In train mode the LIVE expert weights
        # also FSDP their out-features (a 400B expert stack cannot be
        # TP-only: 50 GB/device); inference keeps them EP-only (packed
        # ternary experts are 16× smaller — they fit).
        if mode == "train":
            sub = ("model", tuple(a for a in mesh.axis_names if a != "model"))
        else:
            sub = ("model",)
        return _fit(pre + sub, leaf.shape, mesh)
    if "emb" in path_keys:
        return _fit((wax,), leaf.shape, mesh)
    if "router" in path_keys:
        return P(*([None] * nd))
    # BitLinear master weights / packed planes / biases: out-features sharded
    bitlin_keys = {"q", "k", "v", "o", "gate", "up", "down", "in", "out"}
    if "scale" in path_keys and bitlin_keys & set(path_keys):
        # PackedWeight scale: the grouped plane is [K//G, M] — shard its
        # COLUMNS so scale columns travel with their (M-sharded) code rows;
        # the leading K//G dim must stay whole or K-group scales would be
        # torn apart from their accumulators.  Scalar scales replicate.
        if nd - scan == 2:
            return _fit(pre + (None, wax), leaf.shape, mesh)
        return P(*([None] * nd))
    if bitlin_keys & set(path_keys) and ("w" in path_keys or "planes" in path_keys
                                         or "b" in path_keys or "w4" in path_keys):
        if nd - scan >= 1:
            return _fit(pre + (wax,), leaf.shape, mesh)
    return P(*([None] * nd))


def shard_params(params: Any, mesh, mode: str = "infer") -> Any:
    """Tree of NamedSharding matching `params` (works for opt state too)."""

    def spec(path, leaf):
        return NamedSharding(mesh, param_spec(_path_keys(path), leaf, mesh, mode))

    return jax.tree_util.tree_map_with_path(spec, params)


def state_spec(path_keys: list, leaf, mesh, *, batch: int) -> P:
    """Decode-cache shardings.  Batch on data axes when it divides (else the
    cache length takes 'data' — SP).  KV heads shard on 'model' when they
    divide it; otherwise the cache length takes 'model' too (measured: a
    replicated-cache spec with internally-sharded attention made GSPMD
    all-gather the whole stacked cache — 19.3 GB/device/step)."""
    dp = data_axes(mesh)
    nd = leaf.ndim
    scan = 1 if "scan" in path_keys else 0
    pre = (None,) * scan
    batch_fits = batch % _axis_size(mesh, dp) == 0 if dp else False
    bax = dp if batch_fits else None

    def _cache_axes(shape_kv: int | None):
        kv_fits = shape_kv is not None and shape_kv % _axis_size(mesh, "model") == 0
        kv_ax = "model" if kv_fits else None
        seq = [] if batch_fits else ["data"]
        if not kv_fits:
            seq.append("model")
        sax = tuple(seq) if seq else None
        return sax, kv_ax

    if {"k", "v", "ck", "cv"} & set(path_keys) and nd - scan == 4:
        sax, kv_ax = _cache_axes(leaf.shape[scan + 2])
        return _fit(pre + (bax, sax, kv_ax, None), leaf.shape, mesh)
    if {"ks", "vs"} & set(path_keys) and nd - scan == 3:
        sax, kv_ax = _cache_axes(leaf.shape[scan + 2])
        return _fit(pre + (bax, sax, kv_ax), leaf.shape, mesh)
    if "pos" in path_keys:
        sax, _ = _cache_axes(None)
        return _fit(pre + (bax, sax), leaf.shape, mesh)
    if "h" in path_keys:  # rec [B, dr] / ssd [B, H, P, S]
        if nd - scan == 2:
            return _fit(pre + (bax, "model"), leaf.shape, mesh)
        return _fit(pre + (bax, "model", None, None), leaf.shape, mesh)
    if "conv" in path_keys:
        return _fit(pre + (bax, None, "model"), leaf.shape, mesh)
    return P(*([None] * nd))


def shard_state(state: Any, mesh, *, batch: int) -> Any:
    def spec(path, leaf):
        return NamedSharding(mesh, state_spec(_path_keys(path), leaf, mesh, batch=batch))

    return jax.tree_util.tree_map_with_path(spec, state)


def shard_batch(batch: Any, mesh) -> Any:
    """tokens/labels [B, S] (+ frontend/enc embeddings [B, T, D])."""
    dp = data_axes(mesh)

    def spec(leaf):
        shape = leaf.shape
        if shape[0] % _axis_size(mesh, dp) == 0:
            return NamedSharding(mesh, _fit((dp,), shape, mesh))
        if len(shape) >= 2:  # SP fallback: shard sequence
            return NamedSharding(mesh, _fit((None, "data"), shape, mesh))
        return NamedSharding(mesh, P(*([None] * len(shape))))

    return jax.tree_util.tree_map(spec, batch)


def replicated(tree: Any, mesh) -> Any:
    return jax.tree_util.tree_map(
        lambda l: NamedSharding(mesh, P(*([None] * l.ndim))), tree
    )
