"""AdamW + schedules, implemented directly over pytrees (no optax dependency).

Optimizer state shards like the parameters (ZeRO-1 falls out of pjit
out_shardings matching the param shardings).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    grad_clip: float = 1.0


def schedule(cfg: OptConfig, step: jax.Array) -> jax.Array:
    """Linear warmup → cosine decay to 10%."""
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0
    )
    cos = 0.1 + 0.45 * (1.0 + jnp.cos(math.pi * prog))
    return cfg.lr * warm * cos


def init(params: Any) -> dict:
    """Adam moments + f32 master copy.  The step function sees bf16 params;
    the f32 master lives here (FSDP-sharded) — mixed-precision at scale."""
    zeros = jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, F32), params)
    return {"mu": zeros, "nu": jax.tree_util.tree_map(jnp.zeros_like, zeros),
            "master": jax.tree_util.tree_map(lambda p: p.astype(F32), params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(F32) ** 2) for l in leaves))


def clip_by_global_norm(grads: Any, max_norm: float) -> tuple[Any, jax.Array]:
    g = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(g, 1e-9))
    # keep the leaf dtype: a f32 scalar must not promote bf16 grads (that
    # would double every gradient buffer at 33B scale)
    return jax.tree_util.tree_map(lambda x: x * scale.astype(x.dtype), grads), g


def _decay_mask(path) -> bool:
    """No weight decay on norms/biases/scalars (1-D params)."""
    return True  # resolved per-leaf by ndim below


def update(cfg: OptConfig, params: Any, grads: Any, state: dict) -> tuple[Any, dict, dict]:
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state["step"] + 1
    lr = schedule(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1.0 - b1 ** step.astype(F32)
    bc2 = 1.0 - b2 ** step.astype(F32)

    def upd(p, g, mu, nu, master):
        g = g.astype(F32)
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * g * g
        step_dir = (mu / bc1) / (jnp.sqrt(nu / bc2) + cfg.eps)
        wd = cfg.weight_decay if p.ndim >= 2 else 0.0
        new_master = master - lr * (step_dir + wd * master)
        return new_master.astype(p.dtype), mu, nu, new_master

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state["mu"])
    flat_nu = treedef.flatten_up_to(state["nu"])
    flat_ma = treedef.flatten_up_to(state["master"])
    out = [upd(p, g, m, n, ma) for p, g, m, n, ma
           in zip(flat_p, flat_g, flat_mu, flat_nu, flat_ma)]
    new_params = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_state = {
        "mu": jax.tree_util.tree_unflatten(treedef, [o[1] for o in out]),
        "nu": jax.tree_util.tree_unflatten(treedef, [o[2] for o in out]),
        "master": jax.tree_util.tree_unflatten(treedef, [o[3] for o in out]),
        "step": step,
    }
    return new_params, new_state, {"lr": lr, "grad_norm": gnorm}
