"""QAT training loop: the BitNet b1.58 training scheme end-to-end.

The train step is a pure function (params, opt_state, batch, rng) → (...) so
it jits/pjits unchanged from 1 CPU device to the 512-chip multi-pod mesh.
Features: microbatch gradient accumulation, gradient clipping, bf16 gradient
all-reduce compression with error feedback (optional), deterministic metrics.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import lm
from repro.models.config import ModelConfig
from repro.train import optimizer as opt

F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: opt.OptConfig = opt.OptConfig()
    microbatches: int = 1            # gradient accumulation
    grad_compress: str = "none"      # none | bf16 | bf16_ef (error feedback)
    grad_spec: str = ""              # "" | "fsdp": pin gradient-accumulator
    #   sharding to the train param layout (ZeRO gradient sharding — turns
    #   the per-microbatch all-reduce into a reduce-scatter and keeps the
    #   accumulator at 1/N size).  Needs jax.set_mesh at trace time.


def init_train_state(key, cfg: ModelConfig, tcfg: TrainConfig) -> dict:
    params = lm.init(key, cfg)
    dtype = jnp.dtype(cfg.dtype)
    # step-visible params in compute dtype; f32 master lives in the optimizer
    params = jax.tree_util.tree_map(
        lambda p: p.astype(dtype) if p.dtype == jnp.float32 else p, params)
    state = {"params": params, "opt": opt.init(params)}
    if tcfg.grad_compress == "bf16_ef":
        state["ef"] = jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, F32), params)
    return state


def _compress_grads(grads: Any, tcfg: TrainConfig, ef: Any | None):
    """Gradient wire-format compression (beyond-paper §Perf lever).

    bf16:    cast before the (GSPMD-inserted) data-parallel all-reduce —
             halves collective bytes; standard at scale.
    bf16_ef: same + error feedback: the rounding residual is carried to the
             next step, making the compression unbiased over time.
    """
    if tcfg.grad_compress == "none":
        return grads, ef
    if tcfg.grad_compress == "bf16":
        g = jax.tree_util.tree_map(lambda x: x.astype(jnp.bfloat16).astype(F32), grads)
        return g, ef
    if tcfg.grad_compress == "bf16_ef":
        def q_of(g, e):
            return (g.astype(F32) + e).astype(jnp.bfloat16).astype(F32)

        g = jax.tree_util.tree_map(q_of, grads, ef)
        e = jax.tree_util.tree_map(lambda gr, er, q: gr.astype(F32) + er - q, grads, ef, g)
        return g, e
    raise ValueError(tcfg.grad_compress)


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig):
    """Returns step(state, batch) -> (state, metrics); jit/pjit-ready."""

    # hoist weight fake-quant out of the microbatch loop (see
    # bitlinear.prequantize_weights); activations still quantize per use.
    # Under the STE, d loss/d w_fq == d loss/d w_master, so gradients taken
    # at the prequantized point apply to the masters unchanged.
    hoist = cfg.quant.mode == "qat"
    loss_cfg = cfg.replace(quant=dataclasses.replace(cfg.quant, mode="qat_acts")) if hoist else cfg

    def loss(params_fq, batch):
        return lm.loss_fn(params_fq, batch, loss_cfg)

    grad_fn = jax.value_and_grad(loss, has_aux=True)

    def constrain_grads(g):
        if tcfg.grad_spec != "fsdp":
            return g
        from repro.distributed import sharding as shd

        def pin(path, leaf):
            spec = shd.param_spec(shd._path_keys(path), leaf,
                                  shd.current_mesh(), "train")
            return jax.lax.with_sharding_constraint(leaf, spec)

        return jax.tree_util.tree_map_with_path(pin, g)

    def step(state: dict, batch: dict) -> tuple[dict, dict]:
        params = state["params"]
        if hoist:
            from repro.core import bitlinear

            params = bitlinear.prequantize_weights(params)  # once per step
        mb = tcfg.microbatches
        if mb > 1:
            def split(x):
                return x.reshape(mb, x.shape[0] // mb, *x.shape[1:])

            batches = jax.tree_util.tree_map(split, batch)

            def acc_body(carry, mbatch):
                gsum, lsum = carry
                (l, aux), g = grad_fn(params, mbatch)
                gsum = jax.tree_util.tree_map(jnp.add, gsum, constrain_grads(g))
                return (constrain_grads(gsum), lsum + l), aux["nll"]

            g0 = constrain_grads(
                jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, F32), params))
            (gsum, lsum), nlls = jax.lax.scan(acc_body, (g0, jnp.zeros((), F32)), batches)
            grads = jax.tree_util.tree_map(lambda g: g / mb, gsum)
            lval, nll = lsum / mb, nlls.mean()
        else:
            (lval, aux), grads = grad_fn(params, batch)
            grads = constrain_grads(grads)
            nll = aux["nll"]

        ef = state.get("ef")
        grads, ef = _compress_grads(grads, tcfg, ef)
        new_params, new_opt, om = opt.update(tcfg.opt, params, grads, state["opt"])
        new_state = {"params": new_params, "opt": new_opt}
        if ef is not None:
            new_state["ef"] = ef
        metrics = {"loss": lval, "nll": nll, **om}
        return new_state, metrics

    return step


def train(cfg: ModelConfig, tcfg: TrainConfig, data_iter, n_steps: int,
          state: dict | None = None, key=None, hooks=()) -> tuple[dict, list]:
    """Single-host driver (the multi-pod driver lives in launch/train.py)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    if state is None:
        state = init_train_state(key, cfg, tcfg)
    step_fn = jax.jit(make_train_step(cfg, tcfg))
    history = []
    for i in range(n_steps):
        batch = next(data_iter)
        state, metrics = step_fn(state, batch)
        history.append({k: float(v) for k, v in metrics.items()})
        for h in hooks:
            h(i, state, history[-1])
    return state, history
