"""Admission scheduler: priority/deadline queueing, KV-gated admission,
preemption victim selection (DESIGN.md §7).

The queue is a :class:`collections.deque` — FIFO admission (all-default
priorities) is O(1) via ``popleft``; with mixed priorities the scheduler
scans for the best candidate (serving queues are short; an O(log n) heap
would cost more in re-prioritisation churn than the scan does).

Ordering: higher ``priority`` first, then earlier ``deadline`` (None sorts
last), then arrival order.  Preempted submissions re-enter at the FRONT of
their priority class carrying ``resume_tokens`` (prompt + everything already
generated), so a re-admitted request re-prefills its full history and greedy
decoding continues losslessly.
"""

from __future__ import annotations

import collections
import dataclasses
import math

from repro.serve.metrics import RequestMetrics


@dataclasses.dataclass
class Request:
    """A generation request (re-exported as ``repro.infer.engine.Request``)."""

    rid: int
    prompt: list                  # token ids
    max_new_tokens: int = 16
    temperature: float = 0.0      # 0 → greedy
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class Submission:
    """A queued request plus its scheduling envelope."""

    req: Request
    priority: int = 0             # higher = more urgent
    deadline: float | None = None  # absolute clock time, None = best-effort
    arrival: int = 0              # monotone submit sequence (FIFO tiebreak)
    resume_tokens: list | None = None  # set on preemption re-enqueue
    metrics: RequestMetrics | None = None
    qos: str | None = None        # QoS class name (repro.serve.qos)

    def tokens(self) -> list:
        """What must be in the KV cache before decode continues."""
        return self.resume_tokens if self.resume_tokens is not None else self.req.prompt

    def blocks_needed(self, pcfg) -> int:
        """Admission footprint: the full history + the first generated
        token, clamped to the block-table width (a history ending exactly on
        a block boundary would otherwise ask for one block more than any
        sequence can ever address).  The ONE home of this rule."""
        return min(pcfg.blocks_for(len(self.tokens()) + 1),
                   pcfg.max_blocks_per_seq)

    def sort_key(self) -> tuple:
        return (-self.priority,
                self.deadline if self.deadline is not None else math.inf,
                self.arrival)


def max_prefill_rows(budget: int, chunk: int, slots: int | None = None) -> int:
    """Rows an [S, C] batched-prefill tick packs under a token budget.

    The ONE home of the token-budget policy: every row costs one full chunk
    of budget — short final chunks are right-padded to C on-device, so the
    device work per row is C tokens regardless of how many are real.  A
    budget below one chunk still packs a single row (the tick must be able
    to make progress); ``slots`` caps the rows at the engine's slot count
    (more rows than slots could never hold real chunks — callers sizing
    the [S, C] call or its autotune N-bucket must pass it)."""
    if budget <= 0 or chunk <= 0:
        return 0
    rows = max(1, budget // chunk)
    return rows if slots is None else min(rows, slots)


def plan_prefill_rows(prefilling: list) -> list:
    """Packing ORDER for batched prefill rows: best submissions first.

    ``prefilling`` is [(slot, submission)]; the order is the queue's own
    (:meth:`Submission.sort_key`: priority desc, deadline, arrival).  Slot
    order would starve high-index slots — admission always fills the lowest
    free slot, so under a tight budget every new arrival in a low slot
    would jump a half-prefilled request in a high one; arrival order is
    starvation-free (a waiting request only yields to strictly
    better-ranked work).  The engine stages the first
    :func:`max_prefill_rows` candidates that can actually make progress
    this tick — a block-stalled pick must not waste its row, the
    next-ranked slot backfills it."""
    return [s for s, _ in sorted(prefilling, key=lambda t: t[1].sort_key())]


class AdmissionScheduler:
    def __init__(self):
        self._q: collections.deque[Submission] = collections.deque()
        self._seq = 0
        self._plain = True  # every queued sub default-priority/no-deadline

    def __len__(self) -> int:
        return len(self._q)

    @property
    def pending(self) -> bool:
        return bool(self._q)

    def submit(self, sub: Submission) -> Submission:
        sub.arrival = self._seq
        self._seq += 1
        if sub.priority != 0 or sub.deadline is not None:
            self._plain = False
        self._q.append(sub)
        return sub

    def requeue(self, sub: Submission) -> None:
        """Preemption re-entry: front of the queue, original arrival kept."""
        self._q.appendleft(sub)

    def peek_best(self) -> Submission | None:
        if not self._q:
            return None
        if self._plain:
            return self._q[0]
        return min(self._q, key=Submission.sort_key)

    def pop_best(self) -> Submission | None:
        best = self.peek_best()
        if best is not None:
            self._q.remove(best)  # O(1) when best is the head (FIFO path)
        return best

    def take(self, sub: Submission) -> None:
        """Remove a specific submission (the engine admits what it peeked)."""
        self._q.remove(sub)

    @staticmethod
    def admissible(sub: Submission, free_blocks: int | None, pcfg,
                   reuse_blocks: int = 0, draft_free_blocks: int | None = None,
                   draft_pcfg=None) -> bool:
        """KV-gated admission: room for :meth:`Submission.blocks_needed`
        minus ``reuse_blocks`` already resident via a prefix-cache hit
        (shared blocks are adopted, not allocated — they cost no free-list
        capacity).  ``pcfg=None`` (dense cache) always admits.

        Speculative engines pass the DRAFT pool too (``draft_free_blocks`` /
        ``draft_pcfg``): the draft mirrors the request's KV footprint in its
        own pool, with no prefix reuse (the draft always re-ingests the full
        history), so admission must clear BOTH pools — admitting a request
        the draft pool cannot hold would pin a slot that can never draft."""
        if draft_pcfg is not None and draft_free_blocks is not None:
            if draft_free_blocks < sub.blocks_needed(draft_pcfg):
                return False
        if pcfg is None or free_blocks is None:
            return True
        return free_blocks >= sub.blocks_needed(pcfg) - reuse_blocks

    @staticmethod
    def pick_victim(running: list, *, min_priority: int | None = None,
                    worse_than: Submission | None = None,
                    exclude: int | None = None) -> int | None:
        """Choose the eviction victim among ``running = [(slot, Submission)]``:
        lowest priority, then latest arrival (most recent work lost is
        cheapest).  Eligibility — the ONE home of the preemption policy:
        ``min_priority`` (admission) admits only victims STRICTLY below it;
        ``worse_than`` (mid-decode growth) also allows equal-priority
        later arrivals.  Preemption never displaces better-or-equal work.
        """
        cands = [(s, sub) for s, sub in running if s != exclude]
        if min_priority is not None:
            cands = [(s, sub) for s, sub in cands if sub.priority < min_priority]
        if worse_than is not None:
            cands = [(s, sub) for s, sub in cands
                     if sub.priority < worse_than.priority
                     or (sub.priority == worse_than.priority
                         and sub.arrival > worse_than.arrival)]
        if not cands:
            return None
        slot, _ = min(cands, key=lambda t: (t[1].priority, -t[1].arrival))
        return slot
