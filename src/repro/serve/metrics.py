"""Per-request serving telemetry (DESIGN.md §7).

Every request carries one :class:`RequestMetrics` from submit to finish;
:class:`ServeStats` aggregates finished requests into the summary the
launcher prints and ``benchmarks/bench_serve.py`` persists (TTFT, queue
wait, decode tok/s, preemption counts).  All timestamps come from the
engine's injectable clock, so tests can drive a virtual clock.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class RequestMetrics:
    rid: int
    prompt_len: int = 0
    submit_t: float = 0.0
    admit_t: float | None = None
    first_token_t: float | None = None
    finish_t: float | None = None
    n_generated: int = 0
    n_prefill_chunks: int = 0
    n_preemptions: int = 0
    prefix_hit_tokens: int = 0    # prompt tokens served from the prefix cache
    prefix_hit_blocks: int = 0    # physical blocks reused (incl. COW copies)
    qos: str | None = None

    @property
    def queue_wait(self) -> float | None:
        """Submit → (first) admission.  Re-admissions after preemption do not
        reset it — the user-visible wait is to the first byte of service."""
        if self.admit_t is None:
            return None
        return self.admit_t - self.submit_t

    @property
    def ttft(self) -> float | None:
        if self.first_token_t is None:
            return None
        return self.first_token_t - self.submit_t

    @property
    def decode_tok_s(self) -> float | None:
        if self.finish_t is None or self.first_token_t is None:
            return None
        dt = self.finish_t - self.first_token_t
        if dt <= 0 or self.n_generated <= 1:
            return None
        return (self.n_generated - 1) / dt

    def as_dict(self) -> dict:
        return {
            "rid": self.rid, "prompt_len": self.prompt_len,
            "queue_wait": self.queue_wait, "ttft": self.ttft,
            "decode_tok_s": self.decode_tok_s,
            "n_generated": self.n_generated,
            "n_prefill_chunks": self.n_prefill_chunks,
            "n_preemptions": self.n_preemptions,
            "prefix_hit_tokens": self.prefix_hit_tokens,
            "prefix_hit_blocks": self.prefix_hit_blocks,
            "qos": self.qos,
        }


def percentile(vals, q: float) -> float | None:
    """Nearest-rank percentile; None on empty input (no numpy dependency so
    the module stays importable from anywhere, including docs tooling)."""
    vals = sorted(v for v in vals if v is not None)
    if not vals:
        return None
    idx = min(len(vals) - 1, max(0, round(q / 100.0 * (len(vals) - 1))))
    return vals[idx]


class ServeStats:
    """Aggregator over finished requests."""

    def __init__(self):
        self.finished: list[RequestMetrics] = []

    def add(self, m: RequestMetrics) -> None:
        self.finished.append(m)

    def summary(self) -> dict:
        ms = self.finished
        ttfts = [m.ttft for m in ms]
        waits = [m.queue_wait for m in ms]
        toks = [m.decode_tok_s for m in ms if m.decode_tok_s is not None]
        total_tokens = sum(m.n_generated for m in ms)
        total_prompt = sum(m.prompt_len for m in ms)
        t0 = min((m.submit_t for m in ms), default=0.0)
        t1 = max((m.finish_t for m in ms if m.finish_t is not None), default=t0)
        span = t1 - t0
        return {
            "requests": len(ms),
            "generated_tokens": total_tokens,
            "throughput_tok_s": (total_tokens / span) if span > 0 else None,
            "ttft_p50": percentile(ttfts, 50),
            "ttft_p95": percentile(ttfts, 95),
            "ttft_mean": (sum(t for t in ttfts if t is not None) /
                          max(1, sum(t is not None for t in ttfts)))
                         if any(t is not None for t in ttfts) else None,
            "queue_wait_p50": percentile(waits, 50),
            "queue_wait_p95": percentile(waits, 95),
            # per-request decode rate (first token → finish), the number
            # speculative decoding moves; throughput_tok_s includes queue +
            # prefill time and undersells a decode-phase win
            "decode_tok_s_mean": (sum(toks) / len(toks)) if toks else None,
            "preemptions": sum(m.n_preemptions for m in ms),
            "prefix_hit_requests": sum(m.prefix_hit_tokens > 0 for m in ms),
            "prefix_hit_rate": (sum(m.prefix_hit_tokens for m in ms)
                                / total_prompt) if total_prompt else 0.0,
            "prefill_tokens_skipped": sum(m.prefix_hit_tokens for m in ms),
            "blocks_reused": sum(m.prefix_hit_blocks for m in ms),
        }
