"""Paged KV cache management (DESIGN.md §7).

Device side, the pools live inside the model decode state
(``models.layers.paged_attn_state_init`` — one [num_blocks + 1, block_size,
...] pool per attention layer, last block = trash).  This module owns the
HOST side of their lifecycle:

  * :class:`BlockAllocator` — free-list allocation keyed by request id,
    release, and :meth:`compact` (defragmentation: in-use blocks packed to
    the front, returning the gather map the engine applies to the pools);
  * :class:`BlockTables` — the numpy [slots, max_blocks] logical→physical
    table with a lazily refreshed device mirror;
  * :func:`scrub_blocks` — reset the ``pos`` rows of recycled blocks to −1
    so a new owner never sees a previous sequence's keys (the pos mask is
    the only read barrier; stale k/v bytes are harmless once masked).

Pools are batch-free, so every helper that touches the model state walks it
by layer kind: attention states are pools (block axis right after the
pattern-scan ``reps`` axis), everything else is per-slot.
"""

from __future__ import annotations

import collections
import dataclasses

import jax
import numpy as np


@dataclasses.dataclass(frozen=True)
class PagedKVConfig:
    """Pool geometry.  ``max_blocks_per_seq`` bounds a sequence's logical
    length (the block-table width L); ``num_blocks`` bounds total residency
    across all slots — admission and preemption police the difference."""

    block_size: int = 16
    num_blocks: int = 64
    max_blocks_per_seq: int = 16

    @property
    def trash_block(self) -> int:
        return self.num_blocks  # pools allocate num_blocks + 1; last = trash

    def blocks_for(self, n_tokens: int) -> int:
        return max(1, -(-n_tokens // self.block_size))

    @classmethod
    def for_engine(cls, batch_slots: int, max_seq: int, block_size: int = 16,
                   num_blocks: int | None = None) -> "PagedKVConfig":
        per_seq = max(1, -(-max_seq // block_size))
        if num_blocks is None:
            num_blocks = batch_slots * per_seq
        return cls(block_size=block_size, num_blocks=num_blocks,
                   max_blocks_per_seq=per_seq)


class BlockAllocator:
    """Refcounted free-list block allocator; ownership tracked per request id.

    A physical block carries one reference per owning request PLUS one if
    the prefix index caches it (DESIGN.md §7): shared prefix blocks appear
    in several ownership lists at refcount > 1 and only return to the free
    list when the last reference drops.  A block with refcount > 1 is never
    scrubbed or reused — eviction and compaction preserve it.  When the
    free list runs dry, :meth:`alloc` asks the installed ``reclaimer``
    (the prefix index's LRU leaf eviction) to release cached-only blocks
    before giving up.
    """

    def __init__(self, pcfg: PagedKVConfig):
        self.pcfg = pcfg
        self._free: collections.deque[int] = collections.deque(range(pcfg.num_blocks))
        self._owned: dict[int, list[int]] = {}
        self._refs = np.zeros(pcfg.num_blocks, np.int64)
        self._reclaimer = None    # callable(n) -> freed count (prefix index)

    @property
    def free_count(self) -> int:
        return len(self._free)

    def owned(self, rid: int) -> list[int]:
        return self._owned.get(rid, [])

    def can_alloc(self, n: int) -> bool:
        return len(self._free) >= n

    def set_reclaimer(self, fn) -> None:
        self._reclaimer = fn

    # -- reference counting --------------------------------------------------

    def refcount(self, block: int) -> int:
        return int(self._refs[block])

    def shared_count(self) -> int:
        """Blocks referenced more than once (request+request or request+index)."""
        return int((self._refs > 1).sum())

    def ref_inc(self, block: int) -> None:
        self._refs[block] += 1

    def ref_dec(self, block: int) -> bool:
        """Drop one reference; True if the block returned to the free list."""
        self._refs[block] -= 1
        if self._refs[block] <= 0:
            self._refs[block] = 0
            self._free.append(block)
            return True
        return False

    def adopt(self, rid: int, blocks: list[int]) -> None:
        """Append already-live SHARED blocks to ``rid``'s run (prefix hits):
        one new reference each, no free-list traffic, never scrubbed."""
        for b in blocks:
            self._refs[b] += 1
        self._owned.setdefault(rid, []).extend(blocks)

    def alloc(self, rid: int, n: int) -> list[int] | None:
        """Append ``n`` fresh blocks to ``rid``'s run; None (no change) if the
        pool cannot satisfy the whole request — partial grants would leave the
        caller with an unusable mid-sequence hole.  A dry free list first
        asks the reclaimer to evict cached prefix blocks (LRU leaves)."""
        if n <= 0:
            return []
        if len(self._free) < n and self._reclaimer is not None:
            self._reclaimer(n - len(self._free))
        if len(self._free) < n:
            return None
        got = [self._free.popleft() for _ in range(n)]
        for b in got:
            self._refs[b] = 1
        self._owned.setdefault(rid, []).extend(got)
        return got

    def release_tail(self, rid: int, keep_n: int) -> list[int]:
        """Truncate ``rid``'s run to its first ``keep_n`` blocks (speculative
        KV rollback, DESIGN.md §10) and return the freed block ids — the
        caller must scrub them before the pool is read again.  Tail blocks
        must be PRIVATE (refcount 1): rejected-draft positions live strictly
        beyond the published prompt prefix, so a shared tail block means the
        engine's never-index-draft-blocks invariant broke — raise loudly
        rather than corrupt a neighbour's (or the prefix index's) KV."""
        run = self._owned.get(rid, [])
        tail = run[keep_n:]
        if not tail:
            return []
        del run[keep_n:]
        freed = []
        for b in tail:
            if self._refs[b] != 1:
                raise RuntimeError(
                    f"release_tail: block {b} of rid {rid} has refcount "
                    f"{int(self._refs[b])}; speculative tails must be private")
            if self.ref_dec(b):
                freed.append(b)
        return freed

    def release(self, rid: int) -> list[int]:
        """Drop ``rid``'s references (eviction / completion); returns the
        blocks that actually became free — shared blocks survive under
        their remaining owners / the prefix index."""
        freed = []
        for b in self._owned.pop(rid, []):
            if self.ref_dec(b):
                freed.append(b)
        return freed

    def compact(self, extra_live=()) -> tuple[np.ndarray, np.ndarray]:
        """Defragment: renumber live blocks to the lowest physical ids.

        Live = owned by any request ∪ ``extra_live`` (the prefix index's
        cached blocks — the engine passes ``prefix.blocks()`` and calls
        ``prefix.remap`` afterwards).  A shared block is assigned ONE new id
        no matter how many ownership lists carry it, so shared mappings
        survive compaction intact.  Returns ``(src, remap)`` over the FULL
        pool incl. trash: the engine gathers each pool as ``pool[src]``
        (``src[new] = old``) and rewrites tables as ``remap[table]``
        (``remap[old] = new``).  Ownership lists, refcounts and the free
        list are updated in place.
        """
        nb = self.pcfg.num_blocks
        src = np.arange(nb + 1, dtype=np.int32)
        remap = np.arange(nb + 1, dtype=np.int32)
        assigned: dict[int, int] = {}
        nxt = 0

        def assign(old: int) -> int:
            nonlocal nxt
            new = assigned.get(old)
            if new is None:
                new = assigned[old] = nxt
                src[nxt] = old
                remap[old] = new
                nxt += 1
            return new

        for rid in sorted(self._owned):
            self._owned[rid] = [assign(b) for b in self._owned[rid]]
        for b in extra_live:
            assign(b)
        leftovers = [b for b in range(nb) if b not in assigned]
        for i, old in enumerate(leftovers):
            src[nxt + i] = old
            remap[old] = nxt + i
        refs = np.zeros_like(self._refs)
        for old, new in assigned.items():
            refs[new] = self._refs[old]
        self._refs = refs
        self._free = collections.deque(range(nxt, nb))
        return src, remap


class BlockTables:
    """Host [slots, max_blocks] logical→physical table + device mirror.

    Unallocated entries point at the trash block, whose pos rows are −1
    forever — gathered reads of unallocated ranges are always masked."""

    def __init__(self, slots: int, pcfg: PagedKVConfig):
        self.pcfg = pcfg
        self.np = np.full((slots, pcfg.max_blocks_per_seq),
                          pcfg.trash_block, np.int32)
        self._dev = None

    def set_row(self, slot: int, blocks: list[int]) -> None:
        row = np.full((self.pcfg.max_blocks_per_seq,), self.pcfg.trash_block,
                      np.int32)
        row[: len(blocks)] = blocks
        self.np[slot] = row
        self._dev = None

    def clear_row(self, slot: int) -> None:
        self.np[slot] = self.pcfg.trash_block
        self._dev = None

    def remap(self, remap: np.ndarray) -> None:
        self.np = remap[self.np]
        self._dev = None

    def device(self):
        import jax.numpy as jnp

        if self._dev is None:
            self._dev = jnp.asarray(self.np)
        return self._dev


# ---------------------------------------------------------------------------
# State walking: apply per-layer fns to the {"scan": ..., "rest": ...} pytree
# ---------------------------------------------------------------------------


def map_layer_states(state, cfg, fn):
    """Apply ``fn(layer_state, kind, stacked)`` to every layer sub-state.

    ``stacked`` is True for pattern-scan states (extra leading ``reps``
    axis).  ``fn`` must return the (possibly new) layer state."""
    pattern = cfg.block_pattern
    scan = tuple(
        st if st is None else fn(st, pattern[i], True)
        for i, st in enumerate(state["scan"])
    )
    rest = [st if st == () else fn(st, pattern[i], False)
            for i, st in enumerate(state["rest"])]
    return {"scan": scan, "rest": rest}


def scrub_blocks(state, cfg, block_ids):
    """Reset ``pos`` rows of recycled physical blocks to −1 in every
    attention pool (eager jnp ops; a handful of tiny scatters)."""
    import jax.numpy as jnp

    ids = jnp.asarray(np.asarray(block_ids, np.int32))
    if ids.size == 0:
        return state

    def one(st, kind, stacked):
        if kind not in ("attn", "local"):
            return st
        out = dict(st)
        if stacked:
            out["pos"] = st["pos"].at[:, ids].set(-1)
        else:
            out["pos"] = st["pos"].at[ids].set(-1)
        return out

    return map_layer_states(state, cfg, one)


def mask_block_tails(state, cfg, block_ids, keep_offsets):
    """Partial-block speculative rollback (DESIGN.md §10): in each physical
    block ``block_ids[i]`` mask the ``pos`` entries at in-block offsets
    >= ``keep_offsets[i]`` to −1.  The pos plane is the only read barrier
    (stale k/v bytes are harmless once masked), so this plus
    :meth:`BlockAllocator.release_tail` on the whole-block tail IS the
    rollback: rejected positions become invisible and the next verify/decode
    write simply reclaims their slots."""
    import jax.numpy as jnp

    if not len(block_ids):
        return state
    ids = jnp.asarray(np.asarray(block_ids, np.int32))
    keeps = jnp.asarray(np.asarray(keep_offsets, np.int32))

    def one(st, kind, stacked):
        if kind not in ("attn", "local"):
            return st
        out = dict(st)
        p = st["pos"]
        bs = p.shape[-1]
        drop = jnp.arange(bs)[None, :] >= keeps[:, None]      # [n, bs]
        if stacked:
            rows = p[:, ids]                                  # [reps, n, bs]
            out["pos"] = p.at[:, ids].set(jnp.where(drop[None], -1, rows))
        else:
            rows = p[ids]                                     # [n, bs]
            out["pos"] = p.at[ids].set(jnp.where(drop, -1, rows))
        return out

    return map_layer_states(state, cfg, one)


def rollback_dense_positions(state, cfg, lo, hi):
    """Dense-cache speculative rollback: per slot ``i`` mask every attention
    ``pos`` entry whose VALUE lies in [lo[i], hi[i]] to −1.  Value-based
    masking is layout-agnostic — dense caches index slots as ``pos % width``
    (with per-layer ring widths for windowed attention), but the rejected
    positions are exactly the entries holding those absolute values, and
    per-slot rows mean no cross-sequence collisions (unlike the shared paged
    pools, which take the block-targeted path above).  An empty range
    (lo > hi) leaves the slot untouched."""
    import jax.numpy as jnp

    lo = jnp.asarray(np.asarray(lo, np.int32))
    hi = jnp.asarray(np.asarray(hi, np.int32))

    def one(st, kind, stacked):
        if kind not in ("attn", "local"):
            return st
        out = dict(st)
        p = st["pos"]                       # [B, w] or [reps, B, w]
        l, h = lo[:, None], hi[:, None]
        if stacked:
            l, h = l[None], h[None]
        out["pos"] = jnp.where((p >= l) & (p <= h), -1, p)
        return out

    return map_layer_states(state, cfg, one)


def cow_copy_block(state, cfg, src: int, dst: int, valid: int):
    """Copy-on-write: duplicate physical block ``src`` into ``dst`` keeping
    only the first ``valid`` positions (the shared run up to the divergence
    point); the tail's pos slots are masked to −1 so the new owner's prefill
    overwrites them.  ``dst`` must be freshly allocated (refcount 1) and must
    NOT be on any pending-scrub list — callers flush scrubs first, or a later
    flush would wipe the copied positions."""
    import jax.numpy as jnp

    def one(st, kind, stacked):
        if kind not in ("attn", "local"):
            return st
        out = {}
        for name, a in st.items():
            blk = a[:, src] if stacked else a[src]
            if name == "pos":
                keep = jnp.arange(blk.shape[-1]) < valid
                blk = jnp.where(keep, blk, -1)
            out[name] = a.at[:, dst].set(blk) if stacked else a.at[dst].set(blk)
        return out

    return map_layer_states(state, cfg, one)


def reset_slot_states(state, cfg, slot: int):
    """Zero slot ``slot``'s recurrent / conv states (RG-LRU, SSD) on slot
    reuse.  Attention caches need no reset: stale dense rows and paged
    blocks are invalidated by the pos mask / table indirection, but a
    recurrent hidden state has no position plane — a new occupant would
    otherwise continue from the previous request's carry."""
    import jax.numpy as jnp

    def one(st, kind, stacked):
        if kind not in ("rec", "ssd"):
            return st
        if stacked:
            return jax.tree_util.tree_map(
                lambda a: a.at[:, slot].set(jnp.zeros((), a.dtype)), st)
        return jax.tree_util.tree_map(
            lambda a: a.at[slot].set(jnp.zeros((), a.dtype)), st)

    return map_layer_states(state, cfg, one)


def apply_compaction(state, cfg, src):
    """Gather every attention pool along the block axis: new[i] = old[src[i]].
    Free-slot sources may carry stale bytes — tables never reference them."""
    import jax.numpy as jnp

    s = jnp.asarray(np.asarray(src, np.int32))

    def one(st, kind, stacked):
        if kind not in ("attn", "local"):
            return st
        return jax.tree_util.tree_map(
            lambda a: a[:, s] if stacked else a[s], st)

    return map_layer_states(state, cfg, one)
