"""Prefix-sharing index over paged KV blocks (DESIGN.md §7).

Millions of users share system prompts and few-shot templates, so the
KV bytes for a popular prefix are computed over and over.  The paged pool
already makes KV a logical→physical mapping; this module adds the missing
piece: a **radix index at block granularity** mapping token *content* to
the physical block that already holds its keys/values.

  * A trie node per FULL block, keyed by the block's token tuple.  A chain
    root→node spells out a token prefix in ``block_size`` steps; content
    addressing makes reuse trivially correct — a block is reusable iff the
    exact same tokens produced it (KV at position p depends only on tokens
    0..p for attention layers).
  * Matching walks full blocks, then checks the children of the deepest
    node for a **partial** in-block match: the engine copies that block and
    masks the tail (copy-on-write at the divergence point,
    :func:`repro.serve.kvcache.cow_copy_block`) so the new request reuses
    the shared positions and writes its divergent suffix privately.
  * Reference counting lives in the :class:`~repro.serve.kvcache.
    BlockAllocator` (one count per physical block: one per owning request
    plus one for the index).  Indexed blocks OUTLIVE their request — that
    is the whole point — and are reclaimed lazily, LRU leaves first, when
    the allocator runs dry (:meth:`reclaim` is wired in as the allocator's
    reclaimer).  A block with refcount > 1 (a running request holds it) is
    NEVER evicted or scrubbed.

Only attention KV is content-addressed; recurrent (RG-LRU / SSD) hidden
state is a per-slot carry with no block identity, so the engine keeps the
index inert for architectures that include such layers (documented in
``ServeEngine.prefix_inert_reason``).
"""

from __future__ import annotations


def _common_prefix_len(a, b) -> int:
    n = 0
    for x, y in zip(a, b):
        if x != y:
            break
        n += 1
    return n


class _Node:
    __slots__ = ("key", "block", "parent", "children", "last_use")

    def __init__(self, key: tuple, block: int, parent: "_Node | None"):
        self.key = key
        self.block = block
        self.parent = parent
        self.children: dict[tuple, _Node] = {}
        self.last_use = 0


class PrefixIndex:
    """Radix trie over prompt-token blocks → cached physical KV blocks.

    ``allocator`` must expose ``refcount(b)``, ``ref_inc(b)`` and
    ``ref_dec(b)`` (duck-typed; :class:`repro.serve.kvcache.BlockAllocator`).
    The index holds exactly one reference per indexed block.
    """

    def __init__(self, block_size: int, allocator):
        self.bs = block_size
        self._alloc = allocator
        self._children: dict[tuple, _Node] = {}   # root level
        self._clock = 0

    # -- introspection ------------------------------------------------------

    def _nodes(self):
        stack = list(self._children.values())
        while stack:
            n = stack.pop()
            yield n
            stack.extend(n.children.values())

    @property
    def size(self) -> int:
        """Number of indexed (cached) physical blocks."""
        return sum(1 for _ in self._nodes())

    def blocks(self) -> list[int]:
        return [n.block for n in self._nodes()]

    def evictable_count(self) -> int:
        """Blocks reclaimable by cascading leaf eviction.  A node whose
        block has refcount 1 is held by the index alone; every descendant
        of such a node also has refcount 1 (a request using a deep block
        necessarily holds the whole chain above it), so the count is simply
        the number of index-only nodes."""
        return sum(1 for n in self._nodes()
                   if self._alloc.refcount(n.block) == 1)

    # -- match / insert -----------------------------------------------------

    def match(self, tokens) -> tuple[list[int], int]:
        """Longest cached prefix of ``tokens``.

        Returns ``(blocks, length)``: the ordered physical blocks covering
        the match and the matched token count.  All blocks but possibly the
        last cover a full ``block_size`` run; a shorter final contribution
        means the last block is a **partial** (divergence-mid-block) match
        the caller must copy-on-write, never share.  Touches the matched
        path for LRU."""
        self._clock += 1
        children = self._children
        blocks: list[int] = []
        matched = 0
        i = 0
        while i + self.bs <= len(tokens):
            node = children.get(tuple(tokens[i:i + self.bs]))
            if node is None:
                break
            node.last_use = self._clock
            blocks.append(node.block)
            matched += self.bs
            i += self.bs
            children = node.children
        rest = tuple(tokens[i:i + self.bs])
        best, best_m = None, 0
        for key, node in children.items():
            m = _common_prefix_len(key, rest)
            if m > best_m:
                best, best_m = node, m
        if best is not None:
            best.last_use = self._clock
            blocks.append(best.block)
            matched += best_m
        return blocks, matched

    def insert(self, tokens, phys_blocks) -> int:
        """Index the full blocks of a prefilled history: ``phys_blocks[i]``
        holds the KV of ``tokens[i·bs:(i+1)·bs]``.  Existing nodes win (two
        requests racing the same content keep the first block; the loser's
        copy stays private and is freed with its request).  Returns the
        number of newly indexed blocks (each takes one index reference)."""
        self._clock += 1
        children = self._children
        parent = None
        added = 0
        for i, blk in enumerate(phys_blocks):
            key = tuple(tokens[i * self.bs:(i + 1) * self.bs])
            if len(key) < self.bs:
                break
            node = children.get(key)
            if node is None:
                node = _Node(key, blk, parent)
                children[key] = node
                self._alloc.ref_inc(blk)
                added += 1
            node.last_use = self._clock
            parent, children = node, node.children
        return added

    # -- eviction / maintenance --------------------------------------------

    def reclaim(self, n: int) -> int:
        """Free up to ``n`` blocks by dropping LRU evictable LEAVES (a
        dropped leaf may expose its parent as the next candidate — deepest,
        coldest template tails go first; hot shared roots go last).  Blocks
        with refcount > 1 are refused — a running request still reads them.
        Returns the number of blocks actually freed."""
        freed = 0
        while freed < n:
            leaf = None
            for node in self._nodes():
                if node.children:
                    continue
                if self._alloc.refcount(node.block) != 1:
                    continue
                if leaf is None or node.last_use < leaf.last_use:
                    leaf = node
            if leaf is None:
                break
            siblings = leaf.parent.children if leaf.parent else self._children
            del siblings[leaf.key]
            self._alloc.ref_dec(leaf.block)
            freed += 1
        return freed

    def remap(self, remap) -> None:
        """Renumber physical ids after :meth:`BlockAllocator.compact`."""
        for node in self._nodes():
            node.block = int(remap[node.block])
