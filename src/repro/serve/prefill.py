"""Chunked prefill (DESIGN.md §7): consume prompts in C-token chunks.

A chunk runs as a batch-1 call of :func:`repro.models.lm.prefill_chunk`, so
its flattened mpGEMM batch is N = C — prefill chunks ride the GEMM (MAD/MXU)
regime of the PR-1 dispatch table while the engine's single-token decode tick
keeps its regime (GEMV / ``lut_gemv`` at one slot).  Chunks for one slot
interleave with decode ticks for the others.

State surgery: the model decode state mixes PER-SLOT leaves (recurrent /
conv states; dense KV rows) with SHARED paged pools (batch-free).  A chunk
for slot *i* slices the per-slot leaves with ``dynamic_slice`` (traced *i* →
one trace serves every slot), runs the chunk at batch 1, and merges the
per-slot leaves back; shared pools pass through whole, already updated by
the chunk's block-table writes.
"""

from __future__ import annotations

import jax

from repro.models import lm
from repro.serve.kvcache import map_layer_states


def _is_shared(kind: str, paged: bool) -> bool:
    return paged and kind in ("attn", "local")


def slice_slot(state, cfg, i, *, paged: bool):
    """Extract slot ``i``'s batch-1 view of the decode state."""

    def one(st, kind, stacked):
        if _is_shared(kind, paged):
            return st
        axis = 1 if stacked else 0
        return jax.tree_util.tree_map(
            lambda a: jax.lax.dynamic_slice_in_dim(a, i, 1, axis), st)

    return map_layer_states(state, cfg, one)


def merge_slot(full, part, cfg, i, *, paged: bool):
    """Write slot ``i``'s updated batch-1 state back into the full state."""
    pattern = cfg.block_pattern

    def merge_layer(f, p, kind, stacked):
        if _is_shared(kind, paged):
            return p  # the pool itself was updated in place-of
        axis = 1 if stacked else 0
        return jax.tree_util.tree_map(
            lambda a, b: jax.lax.dynamic_update_slice_in_dim(a, b, i, axis),
            f, p)

    scan = tuple(
        f if f is None else merge_layer(f, p, pattern[j], True)
        for j, (f, p) in enumerate(zip(full["scan"], part["scan"]))
    )
    rest = [f if f == () else merge_layer(f, p, pattern[j], False)
            for j, (f, p) in enumerate(zip(full["rest"], part["rest"]))]
    return {"scan": scan, "rest": rest}


def make_chunk_fn(cfg, *, paged: bool):
    """Jitted ``(params, state, table, toks [1, C], pos0, slot) →
    (last-position logits [1, 1, V], new state)``.

    Retraces per distinct chunk length C (the final partial chunk of a
    prompt), bounded by the configured chunk size.  ``table`` is traced but
    unused (XLA prunes it) in dense mode.
    """

    def _chunk(params, state, table, toks, pos0, slot):
        part = slice_slot(state, cfg, slot, paged=paged)
        trow = (jax.lax.dynamic_slice_in_dim(table, slot, 1, 0)
                if paged else None)
        logits, newpart = lm.prefill_chunk(params, toks, pos0, cfg, part,
                                           table=trow)
        return logits, merge_slot(state, newpart, cfg, slot, paged=paged)

    return jax.jit(_chunk)
