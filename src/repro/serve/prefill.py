"""Chunked prefill (DESIGN.md §7): consume prompts in C-token chunks.

Sequential mode: a chunk runs as a batch-1 call of
:func:`repro.models.lm.prefill_chunk`, so its flattened mpGEMM batch is
N = C — prefill chunks ride the GEMM (MAD/MXU) regime of the PR-1 dispatch
table while the engine's single-token decode tick keeps its regime (GEMV /
``lut_gemv`` at one slot).  Chunks for one slot interleave with decode
ticks for the others.

Batched concurrent mode (``ServeConfig.prefill_budget`` > 0): the chunks of
ALL prefilling slots stack into ONE jitted [S, C] call of
:func:`repro.models.lm.prefill_chunk_batched`, flattening to mpGEMM batch
N = S·C — one kernel launch and one host sync per tick instead of S.

State surgery: the model decode state mixes PER-SLOT leaves (recurrent /
conv states; dense KV rows) with SHARED paged pools (batch-free).
Sequential chunks slice/merge slot *i*'s leaves with ``dynamic_slice`` on a
traced scalar slot id; batched chunks GATHER the leaves over a traced
[S] slot-index vector and SCATTER them back.  Padding rows carry an
out-of-bounds slot index: the gather clamps (mode="clip" — harmless reads
of some real slot), the scatter DROPS them (mode="drop" — no state is
written), so one [S, C] trace serves every occupancy.  Shared pools pass
through whole, already updated by the chunk's block-table writes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import lm
from repro.serve.kvcache import map_layer_states


def _is_shared(kind: str, paged: bool) -> bool:
    return paged and kind in ("attn", "local")


def _read_per_slot(state, cfg, paged, leaf_fn):
    """Per-slot-leaf read walk: ``leaf_fn(array, batch_axis)`` per leaf;
    shared paged pools pass through untouched."""

    def one(st, kind, stacked):
        if _is_shared(kind, paged):
            return st
        axis = 1 if stacked else 0
        return jax.tree_util.tree_map(lambda a: leaf_fn(a, axis), st)

    return map_layer_states(state, cfg, one)


def _write_per_slot(full, part, cfg, paged, leaf_fn):
    """Two-tree write walk: ``leaf_fn(full_leaf, part_leaf, batch_axis)``
    per per-slot leaf; shared paged pools take ``part`` whole (the pool
    itself was updated in place-of)."""
    pattern = cfg.block_pattern

    def merge_layer(f, p, kind, stacked):
        if _is_shared(kind, paged):
            return p
        axis = 1 if stacked else 0
        return jax.tree_util.tree_map(
            lambda a, b: leaf_fn(a, b, axis), f, p)

    scan = tuple(
        f if f is None else merge_layer(f, p, pattern[j], True)
        for j, (f, p) in enumerate(zip(full["scan"], part["scan"]))
    )
    rest = [f if f == () else merge_layer(f, p, pattern[j], False)
            for j, (f, p) in enumerate(zip(full["rest"], part["rest"]))]
    return {"scan": scan, "rest": rest}


def slice_slot(state, cfg, i, *, paged: bool):
    """Extract slot ``i``'s batch-1 view of the decode state."""
    return _read_per_slot(
        state, cfg, paged,
        lambda a, axis: jax.lax.dynamic_slice_in_dim(a, i, 1, axis))


def merge_slot(full, part, cfg, i, *, paged: bool):
    """Write slot ``i``'s updated batch-1 state back into the full state."""
    return _write_per_slot(
        full, part, cfg, paged,
        lambda a, b, axis: jax.lax.dynamic_update_slice_in_dim(a, b, i, axis))


def make_chunk_fn(cfg, *, paged: bool):
    """Jitted ``(params, state, table, toks [1, C], pos0, slot) →
    (last-position logits [1, 1, V], new state)``.

    Retraces per distinct chunk length C (the final partial chunk of a
    prompt), bounded by the configured chunk size.  ``table`` is traced but
    unused (XLA prunes it) in dense mode.
    """

    def _chunk(params, state, table, toks, pos0, slot):
        part = slice_slot(state, cfg, slot, paged=paged)
        trow = (jax.lax.dynamic_slice_in_dim(table, slot, 1, 0)
                if paged else None)
        logits, newpart = lm.prefill_chunk(params, toks, pos0, cfg, part,
                                           table=trow)
        return logits, merge_slot(state, newpart, cfg, slot, paged=paged)

    return jax.jit(_chunk)


# ---------------------------------------------------------------------------
# Batched concurrent prefill: gather/scatter over a slot-index VECTOR
# ---------------------------------------------------------------------------


def gather_slots(state, cfg, idx, *, paged: bool):
    """Batch-S view of the per-slot state leaves, rows gathered at ``idx``.

    ``idx`` is a traced [S] int32 vector; out-of-bounds entries (padding
    rows) clamp to the last real slot — their reads are harmless because
    :func:`scatter_slots` drops the same rows on the way back."""
    return _read_per_slot(
        state, cfg, paged,
        lambda a, axis: jnp.take(a, idx, axis=axis, mode="clip"))


def scatter_slots(full, part, cfg, idx, *, paged: bool):
    """Write S updated batch rows back into the full state at ``idx``.

    Out-of-bounds indices are DROPPED (padding rows write nothing); real
    indices are unique by construction (one row per prefilling slot), so
    the scatter is conflict-free."""
    return _write_per_slot(
        full, part, cfg, paged,
        lambda a, b, axis: a.at[(slice(None),) * axis + (idx,)].set(
            b, mode="drop"))


def make_batched_chunk_fn(cfg, *, paged: bool):
    """Jitted ``(params, state, table, toks [S, C], pos [S, C], idx [S]) →
    (per-row last-valid logits [S, 1, V], new state)``.

    One trace serves every (occupancy, final-chunk-length) combination: the
    [S, C] shape is FIXED by the engine's token budget — idle rows carry an
    out-of-bounds ``idx`` and all-(−1) positions, short final chunks are
    right-padded with pos = −1 tokens — so unlike the sequential path there
    is no per-chunk-length retrace.  ``table`` is traced but unused (XLA
    prunes it) in dense mode.
    """

    def _chunk(params, state, table, toks, pos, idx):
        part = gather_slots(state, cfg, idx, paged=paged)
        trows = jnp.take(table, idx, axis=0, mode="clip") if paged else None
        logits, newpart = lm.prefill_chunk_batched(params, toks, pos, cfg,
                                                   part, table=trows)
        return logits, scatter_slots(state, newpart, cfg, idx, paged=paged)

    return jax.jit(_chunk)
