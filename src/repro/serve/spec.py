"""Speculative decoding: draft models, per-slot draft state, KV rollback
(DESIGN.md §10).

The engine drafts ``k`` tokens per decode tick with a cheap DRAFT model
(single-token steps over the draft's OWN KV run), then scores all k+1
positions on the target in ONE ``[B, k+1]`` verify call
(:func:`repro.models.lm.verify_chunk_batched`) — flattened mpGEMM batch
N = B·(k+1), the GEMM/MAD regime — and commits the longest prefix of
drafted tokens that match the target's greedy argmax, plus one bonus token
from the first mismatching position.  Greedy acceptance makes the output
token-for-token identical to non-speculative decoding: every committed
token IS the target's argmax at its position, whatever the draft proposed.

This module owns the pieces that are not the engine tick itself:

  * :class:`DraftModel` — packed params + config of a draft
    (:func:`self_draft` builds the self-speculation variant: the target's
    own weights, optionally re-packed at a cheaper registry format);
  * :class:`DraftRunner` — the per-engine draft serving state: its own
    block allocator / tables / pools (or dense caches) mirroring the
    target's geometry, per-slot draft cursors, and the draft's own sampler
    key (the engine's key stream must not see draft traffic, or spec on/off
    would perturb temperature>0 sampling);
  * :class:`LookupDraft` / :class:`LookupRunner` — the model-free
    prompt-lookup (n-gram) draft source: proposals come from the slot's
    own token history, so the entire speculative cost is the verify;
  * :func:`longest_prefix_accept` — the acceptance rule, one home;
  * :func:`rollback_paged` — block-table truncation: whole rejected blocks
    are freed (``BlockAllocator.release_tail``) and queued for scrub, the
    partial boundary block has its tail pos-masked.  Rejected-draft blocks
    can never reach the prefix trie: the index only ever publishes FULL
    PROMPT blocks (strictly before any decode-region write), and
    ``release_tail`` asserts the tail is private.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import numpy as np

from repro.models import lm
from repro.models.config import ModelConfig
from repro.serve import kvcache
from repro.serve.kvcache import BlockAllocator, BlockTables, PagedKVConfig


@dataclasses.dataclass
class DraftModel:
    """A draft: params ready for ``lm`` calls + the config they obey.

    ``params`` must already be packed when ``cfg.quant.mode == 'quant'``
    (use :func:`self_draft` / :func:`make_draft`, or hand the engine's own
    packed params straight in for zero-copy self-speculation)."""

    params: Any
    cfg: ModelConfig
    label: str = "draft"


def self_draft(raw_params, cfg: ModelConfig, fmt: str | None = None) -> DraftModel:
    """Self-speculation from RAW (unpacked) target params: the same weights
    re-packed at registry format ``fmt`` (e.g. ``int2_g128`` — cheaper
    bytes/weight, lossier proposals), or at the target's own format when
    ``fmt`` is None.  For the zero-extra-memory variant that shares the
    target's already-packed params object, pass ``draft=None`` to the
    engine instead — it wraps ``self.params`` directly."""
    dcfg = cfg
    if fmt is not None:
        dcfg = cfg.with_quant(dataclasses.replace(cfg.quant, fmt=fmt))
    return make_draft(raw_params, dcfg, label=f"self:{fmt or cfg.quant.fmt}")


def make_draft(raw_params, dcfg: ModelConfig, label: str = "draft") -> DraftModel:
    """Pack arbitrary raw params at ``dcfg`` into a :class:`DraftModel`
    (the separate-small-model drafting path)."""
    params = (lm.pack(raw_params, dcfg)
              if dcfg.quant.mode == "quant" else raw_params)
    return DraftModel(params, dcfg, label=label)


@dataclasses.dataclass(frozen=True)
class LookupDraft:
    """Model-free draft source: prompt-lookup (n-gram) speculation.

    Proposals come from the slot's OWN token history — the continuation
    that followed the most recent earlier occurrence of the last ``n``
    committed tokens — so drafting costs zero model calls and zero draft
    KV.  The entire speculative overhead is the ``[B, k+1]`` verify,
    making this the purest expression of the GEMV→GEMM amortization:
    every accepted token is a decode-step's worth of GEMV traffic folded
    into the batched verify for free.  Acceptance tracks how
    self-similar the output stream is (templated/structured generation:
    high; free prose: lower) — and, as with any draft, a miss costs only
    the rejected columns, never correctness."""

    n: int = 2

    @property
    def label(self) -> str:
        return f"ngram:{self.n}"


def ngram_propose(tokens, c: int, k: int, n: int) -> list:
    """``k`` proposals for positions ``c+1..`` given committed
    ``tokens[0..c]``: find the most recent earlier occurrence of the
    trailing ``n``-gram ``tokens[c+1-n..c]`` and continue from it,
    cycling the ``d`` tokens between the match and the sequence end when
    ``d < k`` (the match distance is a period hypothesis; greedy decode
    loves short loops and this predicts them exactly).  Filling all ``k``
    columns is free — the verify call is a fixed ``[B, k+1]`` width, so a
    mispredicted tail costs only its rejected columns — while a truncated
    proposal wastes verify columns that could have carried tokens.  Empty
    when the history is too short or the n-gram never recurred (→ the
    slot degrades to a plain decode step)."""
    hi = c + 1                      # committed history is tokens[:c+1]
    if k <= 0 or hi < n + 1:
        return []
    key = tuple(tokens[hi - n:hi])
    for j in range(hi - n - 1, -1, -1):
        if tuple(tokens[j:j + n]) == key:
            d = hi - n - j          # continuation length == match distance
            return [tokens[j + n + (t % d)] for t in range(k)]
    return []


class LookupRunner:
    """Degenerate draft runner for :class:`LookupDraft`: no weights, no
    draft KV, nothing to ingest, admit, or roll back.  It exposes the
    same surface :class:`DraftRunner` does so the engine's admission /
    eviction / stall / defrag paths treat both kinds uniformly — every
    method is a cheap no-op and ``pcfg is None`` marks the absence of a
    draft pool wherever block accounting branches."""

    lookup = True
    pcfg = None
    allocator = None

    def __init__(self, model: LookupDraft):
        self.model = model

    def propose(self, tokens, c: int, k: int) -> list:
        return ngram_propose(tokens, c, k, self.model.n)

    def admit(self, rid: int, n_blocks: int) -> bool:
        return True

    def attach_slot(self, slot: int, rid: int) -> None:
        pass

    def release_slot(self, slot: int, rid: int) -> None:
        pass

    def blocks_needed(self, slot: int, rid: int, target: int) -> int:
        return 0

    def flush_scrub(self) -> None:
        pass

    def defrag(self) -> None:
        pass


def longest_prefix_accept(target_greedy, drafted, n: int) -> int:
    """How many of ``n`` drafted tokens to accept: the longest prefix where
    the target's greedy token at position j equals the draft's proposal for
    position j+1.  ``target_greedy[j]`` is argmax of the verify logits at
    offset j; ``drafted[j]`` is the token the verify call FED at offset j
    (col 0 is the committed token, cols 1.. the proposals)."""
    a = 0
    while a < n and int(target_greedy[a]) == int(drafted[a + 1]):
        a += 1
    return a


def rollback_paged(state, cfg, pcfg: PagedKVConfig, allocator: BlockAllocator,
                   tables: BlockTables, pending_scrub: list, items) -> Any:
    """Truncate paged KV runs after rejection.  ``items`` is
    ``[(slot, rid, keep_tokens, written_end)]``: positions 0..keep_tokens−1
    stay valid; positions up to ``written_end`` (inclusive) may hold
    rejected writes.  Whole tail blocks are freed (+ queued for scrub, so
    reuse under a new owner starts masked); the boundary block keeps only
    its valid prefix via :func:`kvcache.mask_block_tails`."""
    bs = pcfg.block_size
    mask_blocks, mask_keeps = [], []
    for slot, rid, keep, end in items:
        if end < keep:
            continue                       # nothing rejected
        keep_n = max(1, -(-keep // bs))    # blocks covering 0..keep-1
        freed = allocator.release_tail(rid, keep_n)
        if freed:
            pending_scrub.extend(freed)
            tables.set_row(slot, allocator.owned(rid))
        off = keep - (keep_n - 1) * bs     # valid offsets in boundary block
        if off < bs:
            blk = allocator.owned(rid)[keep_n - 1]
            if allocator.refcount(blk) != 1:
                raise RuntimeError(
                    f"speculative rollback would mask shared block {blk} "
                    f"(refcount {allocator.refcount(blk)}) of rid {rid}")
            mask_blocks.append(blk)
            mask_keeps.append(off)
    if mask_blocks:
        state = kvcache.mask_block_tails(state, cfg, mask_blocks, mask_keeps)
    return state


class DraftRunner:
    """Per-engine draft serving state (DESIGN.md §10).

    Mirrors the target's KV geometry — a paged pool of the SAME block
    config (its own allocator/tables; admission accounts for both pools) or
    dense ``[slots, max_seq]`` caches — plus per-slot ``cursors`` (draft
    positions written; the draft's read horizon) and the draft's own PRNG
    key.  The jitted step/ingest callables are built BY the engine (they
    live in ``serve.engine``'s shared lru caches and get the engine's obs
    instrumentation) and handed in here.
    """

    lookup = False

    def __init__(self, model: DraftModel, batch_slots: int, max_seq: int,
                 pcfg: PagedKVConfig | None, *, step_fn, ingest_fn, seed: int):
        self.model = model
        self.params = model.params
        self.cfg = model.cfg
        self.step_fn = step_fn
        self.ingest_fn = ingest_fn
        self.key = jax.random.PRNGKey(seed)
        self.cursors = [0] * batch_slots
        self._pending_scrub: list[int] = []
        self.pcfg = pcfg
        if pcfg is not None:
            self.allocator = BlockAllocator(pcfg)
            self.tables = BlockTables(batch_slots, pcfg)
            self.state = lm.init_paged_state(
                model.cfg, batch_slots, pcfg.num_blocks, pcfg.block_size)
            self._dummy_table = None
        else:
            self.allocator = None
            self.tables = None
            self.state = lm.init_state(model.cfg, batch_slots, max_seq)
            import jax.numpy as jnp
            self._dummy_table = jnp.zeros((batch_slots, 1), jnp.int32)

    # -- slot lifecycle ------------------------------------------------------

    def admit(self, rid: int, n_blocks: int) -> bool:
        """Reserve the draft-side KV footprint at admission (the engine's
        draft-aware accounting already checked ``free_count``)."""
        if self.pcfg is None:
            return True
        got = self.allocator.alloc(rid, n_blocks)
        if got is None:
            return False
        self._pending_scrub.extend(got)
        return True

    def attach_slot(self, slot: int, rid: int) -> None:
        """Bind an admitted request to a slot: draft KV restarts at 0 (the
        draft re-ingests the full committed history — it never shares prefix
        blocks, so a cache hit on the target side is still a cold draft)."""
        self.cursors[slot] = 0
        if self.pcfg is not None:
            self.tables.set_row(slot, self.allocator.owned(rid))

    def release_slot(self, slot: int, rid: int) -> None:
        """Finish / eviction: free the draft run (freed blocks are queued
        for scrub like the engine's) and reset the cursor."""
        self.cursors[slot] = 0
        if self.pcfg is not None:
            self._pending_scrub.extend(self.allocator.release(rid))
            self.tables.clear_row(slot)

    def ensure(self, slot: int, rid: int, n_tokens: int) -> bool:
        """Grow the draft run to cover ``n_tokens`` positions; False → the
        engine degrades this slot to a width-1 verify (plain decode rate,
        no stall — the draft pool is a pure accelerator, never a blocker)."""
        if self.pcfg is None:
            return True
        need = self.pcfg.blocks_for(n_tokens) - len(self.allocator.owned(rid))
        if need <= 0:
            return True
        got = self.allocator.alloc(rid, need)
        if got is None:
            return False
        self._pending_scrub.extend(got)
        self.tables.set_row(slot, self.allocator.owned(rid))
        return True

    def blocks_needed(self, slot: int, rid: int, n_tokens: int) -> int:
        """Stall diagnosis: draft blocks still missing for ``n_tokens``."""
        if self.pcfg is None:
            return 0
        return max(0, self.pcfg.blocks_for(n_tokens)
                   - len(self.allocator.owned(rid)))

    # -- device state --------------------------------------------------------

    def flush_scrub(self) -> None:
        if self._pending_scrub:
            self.state = kvcache.scrub_blocks(self.state, self.cfg,
                                              self._pending_scrub)
            self._pending_scrub = []

    def table_dev(self):
        return (self.tables.device() if self.pcfg is not None
                else self._dummy_table)

    def rollback(self, items) -> None:
        """Paged draft rollback (items as :func:`rollback_paged`)."""
        self.state = rollback_paged(self.state, self.cfg, self.pcfg,
                                    self.allocator, self.tables,
                                    self._pending_scrub, items)

    def rollback_dense(self, lo, hi) -> None:
        self.state = kvcache.rollback_dense_positions(self.state, self.cfg,
                                                      lo, hi)

    def defrag(self) -> None:
        """Compact the draft pool alongside the engine's defrag (a pure
        relabeling, like the target's — decode output is unchanged)."""
        if self.pcfg is None:
            return
        self.flush_scrub()
        src, remap = self.allocator.compact()
        self.state = kvcache.apply_compaction(self.state, self.cfg, src)
        self.tables.remap(remap)
