"""Serving subsystem (DESIGN.md §7): paged KV cache, chunked prefill
(sequential per-slot, or batched concurrently across slots under a
token budget), admission scheduling, and per-request telemetry.

Public surface:

    ServeEngine / ServeConfig   the tick-loop engine (engine.py)
    Request / Submission        request + scheduling envelope (scheduler.py)
    PagedKVConfig               block-pool geometry (kvcache.py)
    RequestMetrics / ServeStats telemetry (metrics.py)

``repro.infer.engine.Engine`` is a thin legacy facade over ServeEngine
(dense KV, token-by-token prefill, FIFO admission).
"""

from repro.serve.engine import ServeConfig, ServeEngine  # noqa: F401
from repro.serve.kvcache import BlockAllocator, PagedKVConfig  # noqa: F401
from repro.serve.metrics import RequestMetrics, ServeStats  # noqa: F401
from repro.serve.scheduler import AdmissionScheduler, Request, Submission  # noqa: F401
