"""Serving subsystem (DESIGN.md §7): paged KV cache, chunked prefill
(sequential per-slot, or batched concurrently across slots under a
token budget), prefix-sharing with copy-on-write, admission scheduling,
and per-request telemetry.

Public surface:

    ServeEngine / ServeConfig   the tick-loop engine (engine.py)
    Request / Submission        request + scheduling envelope (scheduler.py)
    PagedKVConfig               block-pool geometry (kvcache.py)
    PrefixIndex                 radix index over prompt blocks (prefix.py)
    QoSClass / select_format    per-request QoS classes (qos.py)
    RequestMetrics / ServeStats telemetry (metrics.py)
    DraftModel / self_draft / make_draft / LookupDraft
                                speculative-decoding drafts (spec.py)

``repro.infer.engine.Engine`` is a thin legacy facade over ServeEngine
(dense KV, token-by-token prefill, FIFO admission).
"""

from repro.serve.engine import ServeConfig, ServeEngine  # noqa: F401
from repro.serve.kvcache import BlockAllocator, PagedKVConfig  # noqa: F401
from repro.serve.metrics import RequestMetrics, ServeStats  # noqa: F401
from repro.serve.prefix import PrefixIndex  # noqa: F401
from repro.serve.qos import QoSClass, select_format  # noqa: F401
from repro.serve.scheduler import AdmissionScheduler, Request, Submission  # noqa: F401
from repro.serve.spec import (  # noqa: F401
    DraftModel, LookupDraft, make_draft, self_draft)
