"""The serving engine (DESIGN.md §7): paged KV + chunked prefill + scheduler.

One :class:`ServeEngine` owns B slots over ONE model decode state and runs a
tick loop; each tick it (1) admits queued requests — gated on free KV blocks,
preempting strictly-lower-priority work when the scheduler says so, (2)
advances the prefilling slots by one prompt chunk each — sequentially
(batch-1 [1, C] calls, the ``prefill_budget=0`` fallback) or BATCHED
(``prefill_budget`` > 0: one [S, C] call stacking up to S = budget // C
slots' chunks, flattening to mpGEMM batch N = S·C) — and (3) runs one
batched decode step for every slot past its prompt ([B, 1] — the GEMV
regime at one slot).  Sampling is a single jitted call over all slots per
tick (one host sync), not a per-slot ``argmax``.

Legacy compatibility: ``prefill_chunk=1, paged=False`` reproduces the
original ``infer.engine.Engine`` semantics exactly — prompts consumed
token-by-token inside the batched decode tick, dense ``[slots, max_seq]``
caches, FIFO admission — which is what the facade in ``repro.infer.engine``
instantiates.
"""

from __future__ import annotations

import dataclasses
import time
from functools import lru_cache, partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dispatch
from repro.core.dispatch import KernelPlan
from repro.models import lm
from repro.obs import NULL_OBS, Obs, format_stall
from repro.obs import kernels as obs_kernels
from repro.models.config import ModelConfig
from repro.serve import kvcache, prefill
from repro.serve import qos as qos_mod
from repro.serve import scheduler as scheduler_mod
from repro.serve.kvcache import BlockAllocator, BlockTables, PagedKVConfig
from repro.serve.metrics import RequestMetrics, ServeStats
from repro.serve.prefix import PrefixIndex
from repro.serve.scheduler import AdmissionScheduler, Request, Submission


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Engine-level serving policy (model policy lives in ModelConfig)."""

    batch_slots: int = 4
    max_seq: int = 256
    paged: bool = False           # paged block-pool KV vs dense [B, max_seq]
    block_size: int = 16
    kv_blocks: int | None = None  # pool size; None → slots · ceil(max_seq/bs)
    prefill_chunk: int = 1        # tokens per prefill chunk; 1 → legacy ticks
    prefill_budget: int = 0       # prefill tokens per tick, packed as ONE
    #                               [budget // chunk, chunk] batched call;
    #                               0 → sequential per-slot chunks (PR-2 path)
    preemption: bool = True       # evict lower-priority work under pressure
    prefix_cache: bool = False    # share prompt-prefix KV blocks across
    #                               requests (paged + attention-only archs;
    #                               otherwise inert, see prefix_inert_reason)


@dataclasses.dataclass
class _Slot:
    sub: Submission
    tokens: list                  # history: prompt (+ resume) + generated
    n_base: int                   # prefix length that is prompt/resume
    cursor: int = 0               # positions written to the KV cache so far
    indexed: bool = False         # prompt blocks published to the prefix index


def _decode_tick(params, toks, pos, state, table, *, cfg: ModelConfig, paged: bool):
    return lm.decode_step(params, toks, pos, cfg, state,
                          table=table if paged else None)


# Jitted callables are cached per (cfg, paged) at module level so every
# engine over the same config shares one trace/executable cache — a new
# ServeEngine (benchmark cells, replicas) pays zero re-compilation.
@lru_cache(maxsize=None)
def _jitted_step(cfg: ModelConfig, paged: bool):
    return jax.jit(partial(_decode_tick, cfg=cfg, paged=paged))


@lru_cache(maxsize=None)
def _jitted_chunk(cfg: ModelConfig, paged: bool):
    return prefill.make_chunk_fn(cfg, paged=paged)


@lru_cache(maxsize=None)
def _jitted_batched_chunk(cfg: ModelConfig, paged: bool):
    return prefill.make_batched_chunk_fn(cfg, paged=paged)


class ServeEngine:
    def __init__(self, params, cfg: ModelConfig, serve: ServeConfig | None = None,
                 *, pack: bool = True, seed: int = 0,
                 plan: KernelPlan | None = None, clock=time.perf_counter,
                 obs: Obs | None = None):
        if plan is not None:
            cfg = cfg.with_plan(plan)
        self.cfg = cfg
        self.obs = obs or NULL_OBS
        self._tracer = self.obs.tracer
        self._tick = 0
        self.scfg = scfg = serve or ServeConfig()
        self.max_seq = scfg.max_seq   # legacy attribute
        self.params = lm.pack(params, cfg) if pack and cfg.quant.mode == "quant" else params
        self.slots: list[_Slot | None] = [None] * scfg.batch_slots
        self.sched = AdmissionScheduler()
        self.stats = ServeStats()
        self.key = jax.random.PRNGKey(seed)
        self._clock = clock
        self._chunked = scfg.prefill_chunk > 1
        self._batched_prefill = scfg.prefill_budget > 0
        self._prefill_rows = scheduler_mod.max_prefill_rows(
            scfg.prefill_budget, scfg.prefill_chunk, scfg.batch_slots)
        self._pending_scrub: list[int] = []
        self._stall_ticks = 0
        self._has_recurrent = any(k in ("rec", "ssd") for k in cfg.block_pattern)

        if self._batched_prefill and not self._chunked:
            raise ValueError(
                "prefill_budget needs prefill_chunk > 1 (token-by-token "
                "prompts are consumed by the batched decode tick already); "
                "set prefill_chunk or drop the budget")
        if (scfg.paged or self._chunked) and cfg.is_encdec():
            raise ValueError("paged/chunked serving supports decoder-only "
                             "stacks; enc-dec models use the dense engine")
        if scfg.paged:
            self.pcfg = PagedKVConfig.for_engine(
                scfg.batch_slots, scfg.max_seq, scfg.block_size, scfg.kv_blocks)
            self.allocator = BlockAllocator(self.pcfg)
            self.tables = BlockTables(scfg.batch_slots, self.pcfg)
            self.state = lm.init_paged_state(
                cfg, scfg.batch_slots, self.pcfg.num_blocks, self.pcfg.block_size)
        else:
            self.pcfg = None
            self.allocator = None
            self.tables = None
            self.state = lm.init_state(cfg, scfg.batch_slots, scfg.max_seq)
            self._dummy_table = jnp.zeros((scfg.batch_slots, 1), jnp.int32)

        # Prefix sharing needs paged block identity AND content-addressable
        # layer state: attention KV at position p depends only on tokens
        # 0..p, but RG-LRU / SSD hidden state is a per-slot carry no block
        # gather can restore.  When the preconditions fail the cache is
        # INERT (not an error): the engine records why, serves normally, and
        # telemetry reports zero hits — so launchers can flip the flag on
        # any architecture without branching.
        self.prefix: PrefixIndex | None = None
        self.prefix_inert_reason: str | None = None
        if scfg.prefix_cache:
            if not scfg.paged:
                self.prefix_inert_reason = (
                    "dense KV has no block identity to share; "
                    "prefix cache needs paged=True")
            elif self._has_recurrent:
                self.prefix_inert_reason = (
                    "recurrent/SSD layers carry per-slot hidden state that "
                    "block reuse cannot restore; prefix cache inert")
            else:
                self.prefix = PrefixIndex(self.pcfg.block_size, self.allocator)
                self.allocator.set_reclaimer(self.prefix.reclaim)
        self._prefix_active = self.prefix is not None

        self._decision_mark = dispatch.decision_count()
        # every jitted callable goes through the obs jit-boundary wrapper:
        # capture-only (two integer reads per call) when kernel profiling is
        # off, fenced + attributed when a KernelProfiler is attached — see
        # repro.obs.kernels for why attribution must live at this boundary
        prof = self.obs.kernels
        self._step_fn = obs_kernels.instrument(
            _jitted_step(cfg, scfg.paged), "decode_step", prof)
        self._chunk_fn = (obs_kernels.instrument(
            _jitted_chunk(cfg, scfg.paged), "prefill_chunk", prof)
            if self._chunked else None)
        self._bchunk_fn = (obs_kernels.instrument(
            _jitted_batched_chunk(cfg, scfg.paged), "prefill_batched", prof)
            if self._batched_prefill else None)
        self._sample_fn = _SAMPLE_FN
        if self._batched_prefill:
            # the batched tick always flattens to exactly N = S·C (padding
            # rows compute too) — pin THAT bucket, not the per-slot chunk
            dispatch.register_chunk_bucket(
                self._prefill_rows * scfg.prefill_chunk)
        elif self._chunked:
            dispatch.register_chunk_bucket(scfg.prefill_chunk)

    # -- introspection ------------------------------------------------------

    def kernel_decisions(self) -> tuple:
        """mpGEMM dispatch decisions recorded since this engine was built.

        Decisions are logged at trace time.  The batched decode tick always
        steps all ``batch_slots`` (idle slots pad at pos −1), so only a
        single-slot engine takes the N=1 GEMV regime (``lut_gemv`` for tl1);
        prefill CHUNKS flatten to N=chunk sequentially, or to N=S·C
        (S = budget // chunk, padding rows included) under batched
        concurrent prefill, and always dispatch GEMM.  Jitted steps are
        shared per (cfg, paged) across engines — a second engine over an
        already-traced config records no new decisions (nothing was
        re-dispatched; the cached executable embeds the same routing).
        """
        return dispatch.decisions_since(self._decision_mark)

    def measured_vs_predicted(self) -> dict:
        """Per-kernel attribution vs the dispatch cost model (DESIGN.md §9);
        needs an Obs bundle with a KernelProfiler attached."""
        if self.obs.kernels is None:
            raise ValueError("no KernelProfiler attached; build the engine "
                             "with obs=repro.obs.make()")
        return self.obs.kernels.report()

    def metrics_summary(self) -> dict:
        out = self.stats.summary()
        if self.pcfg is not None:
            out["kv_blocks"] = self.pcfg.num_blocks
            out["kv_blocks_free"] = self.allocator.free_count
            out["kv_blocks_shared"] = self.allocator.shared_count()
        if self.prefix is not None:
            out["prefix_cached_blocks"] = self.prefix.size
            out["prefix_evictable_blocks"] = self.prefix.evictable_count()
        return out

    # -- request lifecycle --------------------------------------------------

    def submit(self, req: Request, *, priority: int = 0,
               deadline: float | None = None,
               qos: str | None = None) -> Submission:
        if qos is not None:
            qcls = qos_mod.get(qos)
            priority += qcls.priority_boost
        if not req.prompt:
            raise ValueError(
                f"request {req.rid}: empty prompt (nothing to decode from); "
                "submit at least one token")
        if len(req.prompt) > self.scfg.max_seq - 1:
            raise ValueError(
                f"request {req.rid}: prompt of {len(req.prompt)} tokens "
                f"cannot fit max_seq={self.scfg.max_seq} with room to "
                "generate; raise max_seq or truncate the prompt")
        m = RequestMetrics(rid=req.rid, prompt_len=len(req.prompt),
                           submit_t=self._clock(), qos=qos)
        return self.sched.submit(Submission(req=req, priority=priority,
                                            deadline=deadline, metrics=m,
                                            qos=qos))

    def step(self) -> list[Request]:
        """One scheduler tick: admit → prefill chunks → batched decode.
        Returns requests that finished this tick."""
        tr = self._tracer
        now = self._clock()
        finished: list[Request] = []
        with tr.span("tick", tick=self._tick):
            with tr.span("admit") as sp:
                progress = self._admit(now)
                sp.set(queued=len(self.sched))
            # decode candidacy snapshots BEFORE chunking: a slot that
            # finishes its prompt this tick emits its first token from chunk
            # logits and joins the decode tick on the NEXT step (chunks
            # interleave, not stack).
            decode_idx = [i for i, sl in enumerate(self.slots)
                          if sl is not None
                          and (not self._chunked or sl.cursor >= sl.n_base)]
            if self._chunked:
                if self._batched_prefill:
                    with tr.span("prefill_batched"):
                        progress |= self._prefill_tick_batched(now, finished)
                else:
                    with tr.span("prefill"):
                        progress |= self._prefill_tick(now, finished)
            with tr.span("decode", slots=len(decode_idx)):
                progress |= self._decode_tick_host(decode_idx, now, finished)
            if self.obs.metrics.enabled:
                self._sample_metrics(now)
        self._tick += 1
        if progress or finished:
            self._stall_ticks = 0
        else:
            self._stall_ticks += 1
            if self._stall_ticks > 3:
                diag = self._stall_diagnosis()
                tr.event("stall", **diag)
                raise RuntimeError(format_stall(diag))
        return finished

    def _sample_metrics(self, tick_start: float) -> None:
        """Per-tick gauge samples + counters (metrics registry attached)."""
        m = self.obs.metrics
        m.counter("serve_ticks_total").inc()
        m.gauge("serve_queue_depth").set(len(self.sched))
        m.gauge("serve_slots_occupied").set(
            sum(s is not None for s in self.slots))
        m.histogram("serve_tick_duration_s").observe(
            self._clock() - tick_start)
        if self.pcfg is not None:
            m.gauge("serve_kv_blocks_free").set(self.allocator.free_count)
            m.gauge("serve_kv_blocks_shared").set(
                self.allocator.shared_count())
        if self.prefix is not None:
            m.gauge("serve_prefix_cached_blocks").set(self.prefix.size)
            m.gauge("serve_prefix_evictable_blocks").set(
                self.prefix.evictable_count())

    def _stall_diagnosis(self) -> dict:
        """Structured stall diagnosis: which slots are blocked, how many KV
        blocks each still needs, and what the pool has left.  Emitted as a
        ``stall`` tracer event; rendered by ``repro.obs.format_stall``."""
        slots = []
        for i, sl in enumerate(self.slots):
            if sl is None:
                continue
            prefilling = sl.cursor < sl.n_base
            target = (min(sl.n_base, sl.cursor + self.scfg.prefill_chunk)
                      if prefilling and self._chunked else sl.cursor + 1)
            entry = {"slot": i, "rid": sl.sub.req.rid,
                     "priority": sl.sub.priority,
                     "phase": "prefill" if prefilling else "decode",
                     "cursor": sl.cursor, "n_base": sl.n_base}
            if self.pcfg is not None:
                need = (self.pcfg.blocks_for(target)
                        - len(self.allocator.owned(sl.sub.req.rid)))
                entry["blocks_needed"] = max(need, 0)
            slots.append(entry)
        if self.pcfg is not None:
            pool = {"kind": "paged", "free": self.allocator.free_count,
                    "total": self.pcfg.num_blocks,
                    "shared": self.allocator.shared_count()}
            if self.prefix is not None:
                pool["prefix_cached"] = self.prefix.size
                pool["prefix_evictable"] = self.prefix.evictable_count()
        else:
            pool = {"kind": "dense"}
        return {"stall_ticks": self._stall_ticks,
                "preemption": self.scfg.preemption,
                "queued": len(self.sched), "slots": slots, "pool": pool}

    def run(self) -> list[Request]:
        done: list[Request] = []
        while self.sched.pending or any(s is not None for s in self.slots):
            done.extend(self.step())
        return done

    # -- admission + preemption ---------------------------------------------

    def _running(self):
        return [(i, sl.sub) for i, sl in enumerate(self.slots) if sl is not None]

    def _admit(self, now) -> bool:
        progress = False
        while self.sched.pending:
            best = self.sched.peek_best()
            free_idx = next((i for i, s in enumerate(self.slots) if s is None), None)
            if free_idx is None:
                victim = (AdmissionScheduler.pick_victim(
                    self._running(), min_priority=best.priority)
                    if self.scfg.preemption else None)
                if victim is None:
                    break
                self._evict(victim, now)
                progress = True
                continue
            cached = 0
            if self.pcfg is not None:
                cached = self._try_admit_paged(best)
                if cached is None:
                    victim = (AdmissionScheduler.pick_victim(
                        self._running(), min_priority=best.priority)
                        if self.scfg.preemption else None)
                    if victim is None:
                        break  # head-of-line blocks (FIFO semantics)
                    self._evict(victim, now)
                    progress = True
                    continue
                self.tables.set_row(free_idx, self.allocator.owned(best.req.rid))
            self.sched.take(best)
            toks = list(best.tokens())
            self.slots[free_idx] = _Slot(sub=best, tokens=toks,
                                         n_base=len(toks), cursor=cached)
            if self._has_recurrent:  # slot reuse must not inherit h/conv carry
                self.state = kvcache.reset_slot_states(self.state, self.cfg,
                                                       free_idx)
            if best.metrics.admit_t is None:
                best.metrics.admit_t = now
            progress = True
        return progress

    def _try_admit_paged(self, best: Submission) -> int | None:
        """Reserve KV residency for ``best``: adopt cached prefix blocks
        (shared, refcount++), allocate the rest fresh, copy-on-write the
        partial tail block.  Returns the cached token count — the admitted
        slot's starting ``cursor``, so prefill computes only the un-shared
        suffix — or None if the pool cannot satisfy the request even after
        cache eviction (the caller preempts or stalls).

        The cached length is capped at len(tokens) − 1: the LAST prompt
        token is always computed (its logits emit the first generated
        token), which also guarantees at least one fresh block is needed.
        """
        rid = best.req.rid
        toks = best.tokens()
        cached, hit_blocks, cow_src = 0, [], None
        if self._prefix_active:
            hit_blocks, hit_len = self.prefix.match(toks)
            cached = min(hit_len, len(toks) - 1)
        k_full, m_part = divmod(cached, self.pcfg.block_size)
        if cached:
            self.allocator.adopt(rid, hit_blocks[:k_full])
            if m_part:
                # pin the divergence block: it may be index-only (refcount
                # 1) and the alloc below can trigger cache reclaim — the
                # COW source must survive until it is copied.
                cow_src = hit_blocks[k_full]
                self.allocator.ref_inc(cow_src)
        evictable = self.prefix.evictable_count() if self._prefix_active else 0
        ok = AdmissionScheduler.admissible(
            best, self.allocator.free_count + evictable, self.pcfg,
            reuse_blocks=k_full)
        got = (self.allocator.alloc(rid, best.blocks_needed(self.pcfg) - k_full)
               if ok else None)
        if got is None:
            if cow_src is not None:
                self.allocator.ref_dec(cow_src)
            self.allocator.release(rid)  # roll back the adoption
            return None
        if cow_src is not None:
            # flush queued scrubs BEFORE copying: the dst could be a block
            # freed earlier this tick and still on the pending-scrub list —
            # a later flush would wipe the copied positions.  The dst itself
            # never joins the list; its tail is masked by the copy.
            self._flush_scrub()
            self.state = kvcache.cow_copy_block(self.state, self.cfg,
                                                cow_src, got[0], m_part)
            self.allocator.ref_dec(cow_src)
            self._pending_scrub.extend(got[1:])
        else:
            self._pending_scrub.extend(got)
        best.metrics.prefix_hit_tokens = cached
        best.metrics.prefix_hit_blocks = k_full + (1 if m_part else 0)
        if cached:
            self._tracer.event("prefix_hit", rid=rid, tokens=cached,
                               blocks=best.metrics.prefix_hit_blocks,
                               cow=bool(m_part))
            self.obs.metrics.counter("serve_prefix_hit_tokens_total").inc(
                cached)
        return cached

    def _evict(self, idx: int, now) -> None:
        """Preemption-by-eviction: free the slot + its blocks, re-enqueue at
        the queue front with the full generated history (lossless resume)."""
        sl = self.slots[idx]
        sub = sl.sub
        sub.resume_tokens = list(sub.req.prompt) + list(sub.req.out_tokens)
        if self.pcfg is not None:
            self.allocator.release(sub.req.rid)
            self.tables.clear_row(idx)
        sub.metrics.n_preemptions += 1
        self.sched.requeue(sub)
        self.slots[idx] = None
        self._tracer.event("preempt", slot=idx, rid=sub.req.rid,
                           priority=sub.priority,
                           resumed_len=len(sub.resume_tokens))
        self.obs.metrics.counter("serve_preemptions_total").inc()

    def preempt_slot(self, idx: int) -> None:
        """Explicit eviction hook (tests / operator tooling)."""
        if self.slots[idx] is None:
            raise ValueError(f"slot {idx} is idle")
        self._evict(idx, self._clock())

    def _ensure_blocks(self, idx: int, sl: _Slot, n_tokens: int, now) -> bool:
        """Grow ``sl``'s allocation to cover ``n_tokens`` positions.  On pool
        exhaustion, evict a strictly-worse slot (lower priority, or same
        priority but later arrival); False → the caller stalls this tick."""
        if self.pcfg is None:
            return True
        rid = sl.sub.req.rid
        need = self.pcfg.blocks_for(n_tokens) - len(self.allocator.owned(rid))
        if need <= 0:
            return True
        got = self.allocator.alloc(rid, need)
        if got is None and self.scfg.preemption:
            victim = AdmissionScheduler.pick_victim(
                self._running(), worse_than=sl.sub, exclude=idx)
            if victim is not None:
                self._evict(victim, now)
                got = self.allocator.alloc(rid, need)
        if got is None:
            return False
        self._pending_scrub.extend(got)
        self.tables.set_row(idx, self.allocator.owned(rid))
        return True

    def defrag(self) -> None:
        """Compact the block pool: in-use blocks → lowest physical ids.  A
        pure relabeling (gather + table rewrite); decode output is unchanged."""
        if self.pcfg is None:
            return
        self._flush_scrub()
        extra = self.prefix.blocks() if self.prefix is not None else ()
        src, remap = self.allocator.compact(extra_live=extra)
        self.state = kvcache.apply_compaction(self.state, self.cfg, src)
        self.tables.remap(remap)
        if self.prefix is not None:
            self.prefix.remap(remap)

    def _flush_scrub(self) -> None:
        if self._pending_scrub:
            self.state = kvcache.scrub_blocks(self.state, self.cfg,
                                              self._pending_scrub)
            self._pending_scrub = []

    def _table_dev(self):
        return self.tables.device() if self.pcfg is not None else self._dummy_table

    # -- ticks --------------------------------------------------------------

    def _prefill_tick(self, now, finished) -> bool:
        progress = False
        for i, sl in enumerate(self.slots):
            if sl is None or sl.cursor >= sl.n_base:
                continue
            end = min(sl.n_base, sl.cursor + self.scfg.prefill_chunk)
            if not self._ensure_blocks(i, sl, end, now):
                continue  # stalled on blocks this tick
            self._flush_scrub()
            toks = jnp.asarray(np.asarray([sl.tokens[sl.cursor:end]], np.int32))
            logits, self.state = self._chunk_fn(
                self.params, self.state, self._table_dev(), toks,
                jnp.int32(sl.cursor), jnp.int32(i))
            sl.cursor = end
            sl.sub.metrics.n_prefill_chunks += 1
            progress = True
            if sl.cursor >= sl.n_base:  # prompt done: first token from chunk
                with self._tracer.span("sample", rows=1):
                    self.key, sk = jax.random.split(self.key)
                    tok = self._sample_fn(
                        logits[:, -1, :],
                        jnp.asarray([sl.sub.req.temperature], jnp.float32), sk)
                    self._emit(i, sl, int(tok[0]), now, finished)
        return progress

    def _prefill_tick_batched(self, now, finished) -> bool:
        """ONE [S, C] call advances up to S = budget // C prefilling slots.

        Row packing is the scheduler's token-budget policy
        (:func:`repro.serve.scheduler.plan_prefill_rows`); the call shape is
        ALWAYS [S, C] — unused rows are padding (out-of-bounds slot index,
        all-(−1) positions), short final chunks are right-padded with
        pos = −1 tokens — so one trace serves every occupancy and the
        flattened mpGEMM batch is always N = S·C."""
        c = self.scfg.prefill_chunk
        prefilling = [(i, sl.sub) for i, sl in enumerate(self.slots)
                      if sl is not None and sl.cursor < sl.n_base]
        staged = []
        for i in scheduler_mod.plan_prefill_rows(prefilling):
            if len(staged) >= self._prefill_rows:
                break
            sl = self.slots[i]
            if sl is None:
                continue  # evicted by an earlier row's growth this tick
            end = min(sl.n_base, sl.cursor + c)
            if not self._ensure_blocks(i, sl, end, now):
                continue  # block-stalled: the next-ranked slot backfills
            staged.append((i, sl, sl.cursor, end))
        # an _ensure_blocks call for a LATER row may have preempted an
        # earlier staged slot (same hazard as the decode tick): drop rows
        # whose slot changed hands — their table rows now point at trash and
        # their progress resumes via re-prefill after re-admission.
        staged = [(i, sl, s0, s1) for i, sl, s0, s1 in staged
                  if self.slots[i] is sl]
        if not staged:
            return False
        rows = self._prefill_rows
        toks = np.zeros((rows, c), np.int32)
        pos = np.full((rows, c), -1, np.int32)
        idx = np.full((rows,), len(self.slots), np.int32)  # OOB → padding row
        for r, (i, sl, s0, s1) in enumerate(staged):
            n = s1 - s0
            toks[r, :n] = sl.tokens[s0:s1]
            pos[r, :n] = np.arange(s0, s1, dtype=np.int32)
            idx[r] = i
        self._flush_scrub()
        logits, self.state = self._bchunk_fn(
            self.params, self.state, self._table_dev(), jnp.asarray(toks),
            jnp.asarray(pos), jnp.asarray(idx))
        fin = []
        for r, (i, sl, s0, s1) in enumerate(staged):
            sl.cursor = s1
            sl.sub.metrics.n_prefill_chunks += 1
            if sl.cursor >= sl.n_base:  # prompt done: first token from chunk
                fin.append((r, i, sl))
        if fin:
            with self._tracer.span("sample", rows=len(fin)):
                self.key, sk = jax.random.split(self.key)
                sel = jnp.asarray([r for r, _, _ in fin], jnp.int32)
                temps = jnp.asarray(
                    [sl.sub.req.temperature for _, _, sl in fin], jnp.float32)
                toks_out = np.asarray(        # ONE host sync for every row
                    self._sample_fn(logits[sel, -1, :], temps, sk))
                for j, (r, i, sl) in enumerate(fin):
                    self._emit(i, sl, int(toks_out[j]), now, finished)
        return True

    def _decode_tick_host(self, decode_idx: list, now, finished) -> bool:
        b = len(self.slots)
        toks = np.zeros((b, 1), np.int32)
        pos = np.full((b,), -1, np.int32)
        staged = []
        for i in decode_idx:
            sl = self.slots[i]
            if sl is None:
                continue  # finished or evicted earlier this tick
            if not self._ensure_blocks(i, sl, sl.cursor + 1, now):
                continue
            toks[i, 0] = sl.tokens[sl.cursor]
            pos[i] = sl.cursor
            staged.append((i, sl))
        # a later slot's growth may have PREEMPTED an earlier staged slot:
        # drop evictees AND reset their staged position to −1 — a pos ≥ 0
        # write would land in the trash block (their table row now points at
        # it) and record a real position there, breaking the trash pos = −1
        # invariant every sequence's masking relies on.  Their progress
        # resumes via re-prefill after re-admission.
        kept = []
        for i, sl in staged:
            if self.slots[i] is sl:
                kept.append((i, sl))
            else:
                toks[i, 0] = 0
                pos[i] = -1
        staged = kept
        if not staged:
            return False
        self._flush_scrub()
        logits, self.state = self._step_fn(
            self.params, jnp.asarray(toks), jnp.asarray(pos), self.state,
            self._table_dev())
        temps = np.zeros((b,), np.float32)
        for i, sl in staged:
            temps[i] = sl.sub.req.temperature
        with self._tracer.span("sample", rows=len(staged)):
            self.key, sk = jax.random.split(self.key)
            sampled = np.asarray(self._sample_fn(
                logits[:, 0, :], jnp.asarray(temps), sk))  # ONE host sync/tick
            for i, sl in staged:
                sl.cursor += 1
                if sl.cursor < sl.n_base:
                    continue  # token-mode prefill still consuming the prompt
                self._emit(i, sl, int(sampled[i]), now, finished)
        return True

    def _emit(self, idx: int, sl: _Slot, tok: int, now, finished) -> None:
        req = sl.sub.req
        m = sl.sub.metrics
        if self._prefix_active and not sl.indexed:
            # Prompt complete (first emit): publish its full blocks to the
            # prefix index.  The owned run is in logical order (adopted
            # prefix blocks first, then fresh), so block i holds tokens
            # [i·bs, (i+1)·bs).  Must happen before any release — the index
            # reference is what lets these blocks outlive the request.
            sl.indexed = True
            bs = self.pcfg.block_size
            n_full = sl.n_base // bs
            if n_full:
                self.prefix.insert(sl.tokens[:n_full * bs],
                                   self.allocator.owned(req.rid)[:n_full])
        sl.tokens.append(tok)
        req.out_tokens.append(tok)
        if m.first_token_t is None:
            m.first_token_t = now
        m.n_generated = len(req.out_tokens)
        if len(req.out_tokens) >= req.max_new_tokens or sl.cursor >= self.scfg.max_seq - 1:
            req.done = True
            m.finish_t = now
            if self.pcfg is not None:
                self.allocator.release(req.rid)
                self.tables.clear_row(idx)
            self.stats.add(m)
            self.slots[idx] = None
            finished.append(req)
            reg = self.obs.metrics
            reg.counter("serve_requests_finished_total").inc()
            reg.counter("serve_tokens_generated_total").inc(m.n_generated)


def _sample_batched(logits, temps, key):
    """[B, V] logits + per-slot temperatures → [B] tokens, one device call.

    temp == 0 → exact argmax (bitwise-identical to per-slot greedy); temp > 0
    → Gumbel-max categorical at that temperature."""
    greedy = jnp.argmax(logits, axis=-1)
    g = jax.random.gumbel(key, logits.shape, jnp.float32)
    t = jnp.maximum(temps, 1e-6)[:, None]
    samp = jnp.argmax(logits / t + g, axis=-1)
    return jnp.where(temps > 0, samp, greedy).astype(jnp.int32)


_SAMPLE_FN = jax.jit(_sample_batched)
