"""The serving engine (DESIGN.md §7): paged KV + chunked prefill + scheduler.

One :class:`ServeEngine` owns B slots over ONE model decode state and runs a
tick loop; each tick it (1) admits queued requests — gated on free KV blocks,
preempting strictly-lower-priority work when the scheduler says so, (2)
advances the prefilling slots by one prompt chunk each — sequentially
(batch-1 [1, C] calls, the ``prefill_budget=0`` fallback) or BATCHED
(``prefill_budget`` > 0: one [S, C] call stacking up to S = budget // C
slots' chunks, flattening to mpGEMM batch N = S·C) — and (3) runs one
batched decode step for every slot past its prompt ([B, 1] — the GEMV
regime at one slot).  Sampling is a single jitted call over all slots per
tick (one host sync), not a per-slot ``argmax``.

Legacy compatibility: ``prefill_chunk=1, paged=False`` reproduces the
original ``infer.engine.Engine`` semantics exactly — prompts consumed
token-by-token inside the batched decode tick, dense ``[slots, max_seq]``
caches, FIFO admission — which is what the facade in ``repro.infer.engine``
instantiates.
"""

from __future__ import annotations

import dataclasses
import time
from functools import lru_cache, partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dispatch
from repro.core.dispatch import KernelPlan
from repro.distributed import sharding
from repro.models import lm
from repro.obs import NULL_OBS, Obs, format_stall
from repro.obs import kernels as obs_kernels
from repro.models.config import ModelConfig
from repro.serve import kvcache, prefill
from repro.serve import qos as qos_mod
from repro.serve import spec as spec_mod
from repro.serve import scheduler as scheduler_mod
from repro.serve.kvcache import BlockAllocator, BlockTables, PagedKVConfig
from repro.serve.metrics import RequestMetrics, ServeStats
from repro.serve.prefix import PrefixIndex
from repro.serve.scheduler import AdmissionScheduler, Request, Submission


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Engine-level serving policy (model policy lives in ModelConfig)."""

    batch_slots: int = 4
    max_seq: int = 256
    paged: bool = False           # paged block-pool KV vs dense [B, max_seq]
    block_size: int = 16
    kv_blocks: int | None = None  # pool size; None → slots · ceil(max_seq/bs)
    prefill_chunk: int = 1        # tokens per prefill chunk; 1 → legacy ticks
    prefill_budget: int = 0       # prefill tokens per tick, packed as ONE
    #                               [budget // chunk, chunk] batched call;
    #                               0 → sequential per-slot chunks (PR-2 path)
    preemption: bool = True       # evict lower-priority work under pressure
    prefix_cache: bool = False    # share prompt-prefix KV blocks across
    #                               requests (paged + attention-only archs;
    #                               otherwise inert, see prefix_inert_reason)
    speculate_k: int = 0          # draft tokens per decode tick; the verify
    #                               call scores [B, k+1] positions at once
    #                               (GEMM regime).  0 → speculation off: the
    #                               engine takes the plain decode tick,
    #                               trace-for-trace identical to pre-spec.


@dataclasses.dataclass
class _Slot:
    sub: Submission
    tokens: list                  # history: prompt (+ resume) + generated
    n_base: int                   # prefix length that is prompt/resume
    cursor: int = 0               # positions written to the KV cache so far
    indexed: bool = False         # prompt blocks published to the prefix index


def _decode_tick(params, toks, pos, state, table, *, cfg: ModelConfig, paged: bool):
    return lm.decode_step(params, toks, pos, cfg, state,
                          table=table if paged else None)


# Jitted callables are cached per (cfg, paged) at module level so every
# engine over the same config shares one trace/executable cache — a new
# ServeEngine (benchmark cells, replicas) pays zero re-compilation.
@lru_cache(maxsize=None)
def _jitted_step(cfg: ModelConfig, paged: bool):
    return jax.jit(partial(_decode_tick, cfg=cfg, paged=paged))


@lru_cache(maxsize=None)
def _jitted_chunk(cfg: ModelConfig, paged: bool):
    return prefill.make_chunk_fn(cfg, paged=paged)


@lru_cache(maxsize=None)
def _jitted_batched_chunk(cfg: ModelConfig, paged: bool):
    return prefill.make_batched_chunk_fn(cfg, paged=paged)


def _verify_tick(params, toks, pos, state, table, *, cfg: ModelConfig,
                 paged: bool):
    return lm.verify_chunk_batched(params, toks, pos, cfg, state,
                                   table=table if paged else None)


# The [B, W] multi-position verify call (DESIGN.md §10).  Rows are ALL the
# engine's slots (like the decode tick — idle/short rows pad at pos −1), so
# no gather/scatter surgery is needed; the same callable, at ingest width,
# feeds committed history into the DRAFT's cache (logits discarded).  Under
# self-speculation the draft shares the target's cfg, so both roles hit one
# lru_cache entry and the draft costs zero extra traces beyond its shapes.
@lru_cache(maxsize=None)
def _jitted_verify(cfg: ModelConfig, paged: bool):
    return jax.jit(partial(_verify_tick, cfg=cfg, paged=paged))


def _draft_loop_tick(params, forced, fmask, dpos, state, table, *,
                     cfg: ModelConfig, paged: bool, k: int):
    """All k forced/feedback draft steps fused under ONE jit.

    Step ``s`` consumes ``forced[:, s]`` where ``fmask[:, s]`` (committed
    history folded into the loop) and the previous step's greedy token
    elsewhere, writing draft position ``dpos[:, s]`` (−1 = trash).  Fusing
    matters because speculation's economics are per-CALL: a tick that paid
    k + 1 jit dispatches to commit ~k tokens only breaks even against the
    one-dispatch plain decode tick, so the k draft steps must share one.
    Drafting is greedy (argmax) regardless of slot temperature — only
    temperature-0 slots speculate, and the proposals steer acceptance
    only, never the committed distribution."""
    prev, outs = forced[:, 0], []
    for s in range(k):
        tok_s = jnp.where(fmask[:, s], forced[:, s], prev)
        logits, state = lm.decode_step(params, tok_s[:, None], dpos[:, s],
                                       cfg, state,
                                       table=table if paged else None)
        prev = jnp.argmax(logits[:, 0, :], axis=-1).astype(jnp.int32)
        outs.append(prev)
    return jnp.stack(outs, axis=1), state


@lru_cache(maxsize=None)
def _jitted_draft_loop(cfg: ModelConfig, paged: bool, k: int):
    return jax.jit(partial(_draft_loop_tick, cfg=cfg, paged=paged, k=k))


class ServeEngine:
    def __init__(self, params, cfg: ModelConfig, serve: ServeConfig | None = None,
                 *, pack: bool = True, seed: int = 0,
                 plan: KernelPlan | None = None, clock=time.perf_counter,
                 obs: Obs | None = None,
                 draft: spec_mod.DraftModel | spec_mod.LookupDraft | None = None,
                 mesh=None):
        if plan is not None:
            cfg = cfg.with_plan(plan)
        self.cfg = cfg
        self.obs = obs or NULL_OBS
        self._tracer = self.obs.tracer
        self._tick = 0
        self.scfg = scfg = serve or ServeConfig()
        self.max_seq = scfg.max_seq   # legacy attribute
        self.params = lm.pack(params, cfg) if pack and cfg.quant.mode == "quant" else params
        self.mesh = mesh
        if mesh is not None:
            # TP serving (DESIGN.md §12): install the mesh so bare
            # PartitionSpec constraints resolve in jit, pin packed planes
            # M-sharded (scale columns travel with their code rows — the
            # "scale" rule in sharding.param_spec), and let GSPMD propagate
            # through the model body.  M-sharded weights keep every kernel's
            # per-output-row arithmetic identical to unsharded, so serving
            # stays bit-identical (asserted by the sharded test tier).
            sharding.set_mesh(mesh)
            self.params = jax.device_put(
                self.params, sharding.shard_params(self.params, mesh, "infer"))
        self.slots: list[_Slot | None] = [None] * scfg.batch_slots
        self.sched = AdmissionScheduler()
        self.stats = ServeStats()
        self.key = jax.random.PRNGKey(seed)
        self._clock = clock
        self._chunked = scfg.prefill_chunk > 1
        self._batched_prefill = scfg.prefill_budget > 0
        self._prefill_rows = scheduler_mod.max_prefill_rows(
            scfg.prefill_budget, scfg.prefill_chunk, scfg.batch_slots)
        self._pending_scrub: list[int] = []
        self._stall_ticks = 0
        self._has_recurrent = any(k in ("rec", "ssd") for k in cfg.block_pattern)

        if self._batched_prefill and not self._chunked:
            raise ValueError(
                "prefill_budget needs prefill_chunk > 1 (token-by-token "
                "prompts are consumed by the batched decode tick already); "
                "set prefill_chunk or drop the budget")
        if (scfg.paged or self._chunked) and cfg.is_encdec():
            raise ValueError("paged/chunked serving supports decoder-only "
                             "stacks; enc-dec models use the dense engine")
        if scfg.paged:
            self.pcfg = PagedKVConfig.for_engine(
                scfg.batch_slots, scfg.max_seq, scfg.block_size, scfg.kv_blocks)
            self.allocator = BlockAllocator(self.pcfg)
            self.tables = BlockTables(scfg.batch_slots, self.pcfg)
            self.state = lm.init_paged_state(
                cfg, scfg.batch_slots, self.pcfg.num_blocks, self.pcfg.block_size)
        else:
            self.pcfg = None
            self.allocator = None
            self.tables = None
            self.state = lm.init_state(cfg, scfg.batch_slots, scfg.max_seq)
            self._dummy_table = jnp.zeros((scfg.batch_slots, 1), jnp.int32)
        if mesh is not None:
            # sharded KV pools: paged block pools and dense caches take the
            # same state_spec rules (KV heads on "model" when they divide)
            self.state = jax.device_put(
                self.state,
                sharding.shard_state(self.state, mesh, batch=scfg.batch_slots))

        # Prefix sharing needs paged block identity AND content-addressable
        # layer state: attention KV at position p depends only on tokens
        # 0..p, but RG-LRU / SSD hidden state is a per-slot carry no block
        # gather can restore.  When the preconditions fail the cache is
        # INERT (not an error): the engine records why, serves normally, and
        # telemetry reports zero hits — so launchers can flip the flag on
        # any architecture without branching.
        self.prefix: PrefixIndex | None = None
        self.prefix_inert_reason: str | None = None
        if scfg.prefix_cache:
            if not scfg.paged:
                self.prefix_inert_reason = (
                    "dense KV has no block identity to share; "
                    "prefix cache needs paged=True")
            elif self._has_recurrent:
                self.prefix_inert_reason = (
                    "recurrent/SSD layers carry per-slot hidden state that "
                    "block reuse cannot restore; prefix cache inert")
            else:
                self.prefix = PrefixIndex(self.pcfg.block_size, self.allocator)
                self.allocator.set_reclaimer(self.prefix.reclaim)
        self._prefix_active = self.prefix is not None

        # Speculative decoding (DESIGN.md §10).  Guard rails are ERRORS, not
        # inert fallbacks: unlike the prefix cache, a spec engine that
        # silently served non-speculatively would invalidate the latency
        # contract the caller asked for.
        self.spec: spec_mod.DraftRunner | spec_mod.LookupRunner | None = None
        self._spec_totals = {"steps": 0, "drafted": 0, "accepted": 0,
                             "rejected": 0, "committed": 0}
        if scfg.speculate_k > 0:
            if cfg.is_encdec():
                raise ValueError("speculative decoding supports decoder-only "
                                 "stacks")
            if self._has_recurrent:
                raise ValueError(
                    "speculative decoding needs per-position KV to roll back "
                    "rejected drafts; recurrent/SSD layers carry a per-slot "
                    "hidden state no truncation can restore — use an "
                    "attention-only arch or speculate_k=0")
            if cfg.quant.mode == "quant" and cfg.quant.act == "tensor":
                raise ValueError(
                    "speculative decoding with per-TENSOR activation quant: "
                    "one absmax per step ties logits to the batch "
                    "composition, so the [B, k+1] verify call would score "
                    "different logits than the [B, 1] decode it replaces and "
                    "greedy acceptance would NOT be bit-identical; use "
                    "act='token' (composition-invariant) or speculate_k=0")
            d = draft if draft is not None else spec_mod.DraftModel(
                self.params, cfg, label="self")
            if not isinstance(d, spec_mod.LookupDraft):
                # model-draft-only guards: a LookupDraft has no weights, no
                # vocab of its own, and no KV to roll back
                if d.cfg.padded_vocab != cfg.padded_vocab:
                    raise ValueError(
                        f"draft vocab {d.cfg.padded_vocab} != target vocab "
                        f"{cfg.padded_vocab}: proposals would not be "
                        "comparable")
                if any(k in ("rec", "ssd") for k in d.cfg.block_pattern):
                    raise ValueError("draft model must be attention-only "
                                     "(its KV rolls back by truncation too)")
        elif draft is not None:
            raise ValueError("draft model given but speculate_k == 0; set "
                             "ServeConfig.speculate_k >= 1")

        self._decision_mark = dispatch.decision_count()
        # every jitted callable goes through the obs jit-boundary wrapper:
        # capture-only (two integer reads per call) when kernel profiling is
        # off, fenced + attributed when a KernelProfiler is attached — see
        # repro.obs.kernels for why attribution must live at this boundary
        prof = self.obs.kernels
        self._step_fn = obs_kernels.instrument(
            _jitted_step(cfg, scfg.paged), "decode_step", prof)
        self._chunk_fn = (obs_kernels.instrument(
            _jitted_chunk(cfg, scfg.paged), "prefill_chunk", prof)
            if self._chunked else None)
        self._bchunk_fn = (obs_kernels.instrument(
            _jitted_batched_chunk(cfg, scfg.paged), "prefill_batched", prof)
            if self._batched_prefill else None)
        self._sample_fn = _SAMPLE_FN
        if scfg.speculate_k > 0:
            k = scfg.speculate_k
            self._verify_fn = obs_kernels.instrument(
                _jitted_verify(cfg, scfg.paged), "spec_verify", prof)
            if isinstance(d, spec_mod.LookupDraft):
                # model-free prompt-lookup drafting: no draft weights, no
                # draft KV, nothing to ingest — the [B, k+1] verify IS the
                # whole speculative cost (DESIGN.md §10)
                self._spec_ingest_w = 0
                self.spec = spec_mod.LookupRunner(d)
            else:
                # the draft catches up on committed history (decode entry,
                # or after draft-pool pressure) in fixed-width ingest
                # chunks; k+1 keeps ingest and verify on ONE trace under
                # self-speculation
                self._spec_ingest_w = max(k + 1, scfg.prefill_chunk)
                self.spec = spec_mod.DraftRunner(
                    d, scfg.batch_slots, scfg.max_seq, self.pcfg,
                    step_fn=obs_kernels.instrument(
                        _jitted_draft_loop(d.cfg, scfg.paged, k),
                        "spec_draft_step", prof),
                    ingest_fn=obs_kernels.instrument(
                        _jitted_verify(d.cfg, scfg.paged),
                        "spec_draft_ingest", prof),
                    seed=seed + 1)
                dispatch.register_chunk_bucket(
                    scfg.batch_slots * self._spec_ingest_w)
            # pin the verify batch's exact N-bucket (B·(k+1)) so
            # verification deterministically routes to the GEMM/MAD regime
            # and autotune measures the real shape
            dispatch.register_chunk_bucket(scfg.batch_slots * (k + 1))
        if self._batched_prefill:
            # the batched tick always flattens to exactly N = S·C (padding
            # rows compute too) — pin THAT bucket, not the per-slot chunk
            dispatch.register_chunk_bucket(
                self._prefill_rows * scfg.prefill_chunk)
        elif self._chunked:
            dispatch.register_chunk_bucket(scfg.prefill_chunk)

    # -- introspection ------------------------------------------------------

    def kernel_decisions(self) -> tuple:
        """mpGEMM dispatch decisions recorded since this engine was built.

        Decisions are logged at trace time.  The batched decode tick always
        steps all ``batch_slots`` (idle slots pad at pos −1), so only a
        single-slot engine takes the N=1 GEMV regime (``lut_gemv`` for tl1);
        prefill CHUNKS flatten to N=chunk sequentially, or to N=S·C
        (S = budget // chunk, padding rows included) under batched
        concurrent prefill, and always dispatch GEMM.  Jitted steps are
        shared per (cfg, paged) across engines — a second engine over an
        already-traced config records no new decisions (nothing was
        re-dispatched; the cached executable embeds the same routing).
        """
        return dispatch.decisions_since(self._decision_mark)

    def measured_vs_predicted(self) -> dict:
        """Per-kernel attribution vs the dispatch cost model (DESIGN.md §9);
        needs an Obs bundle with a KernelProfiler attached."""
        if self.obs.kernels is None:
            raise ValueError("no KernelProfiler attached; build the engine "
                             "with obs=repro.obs.make()")
        return self.obs.kernels.report()

    def metrics_summary(self) -> dict:
        out = self.stats.summary()
        if self.mesh is not None:
            out["mesh_axes"] = dict(self.mesh.shape)
            out["tp"] = int(self.mesh.shape.get("model", 1))
            out["sharding_axes_dropped"] = sharding.axes_dropped()
        if self.pcfg is not None:
            out["kv_blocks"] = self.pcfg.num_blocks
            out["kv_blocks_free"] = self.allocator.free_count
            out["kv_blocks_shared"] = self.allocator.shared_count()
        if self.prefix is not None:
            out["prefix_cached_blocks"] = self.prefix.size
            out["prefix_evictable_blocks"] = self.prefix.evictable_count()
        if self.spec is not None:
            t = self._spec_totals
            out["speculate_k"] = self.scfg.speculate_k
            out["spec_draft"] = self.spec.model.label
            out["spec_steps"] = t["steps"]
            out["spec_tokens_drafted"] = t["drafted"]
            out["spec_tokens_accepted"] = t["accepted"]
            out["spec_tokens_rejected"] = t["rejected"]
            # committed counts the bonus token too: > 1 means speculation
            # beats one-token-per-tick decode on this workload
            out["spec_accepted_per_step"] = (
                t["committed"] / t["steps"] if t["steps"] else None)
            out["spec_acceptance_rate"] = (
                t["accepted"] / t["drafted"] if t["drafted"] else None)
            if self.spec.pcfg is not None:
                out["draft_kv_blocks_free"] = self.spec.allocator.free_count
        return out

    # -- request lifecycle --------------------------------------------------

    def submit(self, req: Request, *, priority: int = 0,
               deadline: float | None = None,
               qos: str | None = None) -> Submission:
        if qos is not None:
            qcls = qos_mod.get(qos)
            priority += qcls.priority_boost
        if not req.prompt:
            raise ValueError(
                f"request {req.rid}: empty prompt (nothing to decode from); "
                "submit at least one token")
        if len(req.prompt) > self.scfg.max_seq - 1:
            raise ValueError(
                f"request {req.rid}: prompt of {len(req.prompt)} tokens "
                f"cannot fit max_seq={self.scfg.max_seq} with room to "
                "generate; raise max_seq or truncate the prompt")
        m = RequestMetrics(rid=req.rid, prompt_len=len(req.prompt),
                           submit_t=self._clock(), qos=qos)
        return self.sched.submit(Submission(req=req, priority=priority,
                                            deadline=deadline, metrics=m,
                                            qos=qos))

    def step(self) -> list[Request]:
        """One scheduler tick: admit → prefill chunks → batched decode.
        Returns requests that finished this tick."""
        tr = self._tracer
        now = self._clock()
        finished: list[Request] = []
        with tr.span("tick", tick=self._tick):
            with tr.span("admit") as sp:
                progress = self._admit(now)
                sp.set(queued=len(self.sched))
            # decode candidacy snapshots BEFORE chunking: a slot that
            # finishes its prompt this tick emits its first token from chunk
            # logits and joins the decode tick on the NEXT step (chunks
            # interleave, not stack).
            decode_idx = [i for i, sl in enumerate(self.slots)
                          if sl is not None
                          and (not self._chunked or sl.cursor >= sl.n_base)]
            if self._chunked:
                if self._batched_prefill:
                    with tr.span("prefill_batched"):
                        progress |= self._prefill_tick_batched(now, finished)
                else:
                    with tr.span("prefill"):
                        progress |= self._prefill_tick(now, finished)
            if self.spec is not None:
                progress |= self._spec_tick(decode_idx, now, finished)
            else:
                with tr.span("decode", slots=len(decode_idx)):
                    progress |= self._decode_tick_host(decode_idx, now,
                                                       finished)
            if self.obs.metrics.enabled:
                self._sample_metrics(now)
        self._tick += 1
        if progress or finished:
            self._stall_ticks = 0
        else:
            self._stall_ticks += 1
            if self._stall_ticks > 3:
                diag = self._stall_diagnosis()
                tr.event("stall", **diag)
                raise RuntimeError(format_stall(diag))
        return finished

    def _sample_metrics(self, tick_start: float) -> None:
        """Per-tick gauge samples + counters (metrics registry attached)."""
        m = self.obs.metrics
        m.counter("serve_ticks_total").inc()
        m.gauge("serve_queue_depth").set(len(self.sched))
        m.gauge("serve_slots_occupied").set(
            sum(s is not None for s in self.slots))
        m.histogram("serve_tick_duration_s").observe(
            self._clock() - tick_start)
        if self.pcfg is not None:
            m.gauge("serve_kv_blocks_free").set(self.allocator.free_count)
            m.gauge("serve_kv_blocks_shared").set(
                self.allocator.shared_count())
        if self.prefix is not None:
            m.gauge("serve_prefix_cached_blocks").set(self.prefix.size)
            m.gauge("serve_prefix_evictable_blocks").set(
                self.prefix.evictable_count())
        if self.spec is not None and self.spec.pcfg is not None:
            m.gauge("serve_draft_kv_blocks_free").set(
                self.spec.allocator.free_count)

    def _stall_diagnosis(self) -> dict:
        """Structured stall diagnosis: which slots are blocked, how many KV
        blocks each still needs, and what the pool has left.  Emitted as a
        ``stall`` tracer event; rendered by ``repro.obs.format_stall``."""
        slots = []
        for i, sl in enumerate(self.slots):
            if sl is None:
                continue
            prefilling = sl.cursor < sl.n_base
            target = (min(sl.n_base, sl.cursor + self.scfg.prefill_chunk)
                      if prefilling and self._chunked else sl.cursor + 1)
            entry = {"slot": i, "rid": sl.sub.req.rid,
                     "priority": sl.sub.priority,
                     "phase": "prefill" if prefilling else "decode",
                     "cursor": sl.cursor, "n_base": sl.n_base}
            if self.pcfg is not None:
                need = (self.pcfg.blocks_for(target)
                        - len(self.allocator.owned(sl.sub.req.rid)))
                entry["blocks_needed"] = max(need, 0)
            if self.spec is not None:
                # draft KV demand: blocks the DRAFT pool still owes this
                # slot before it can draft k tokens past the cursor (a dry
                # draft pool degrades to plain decode, it never stalls — but
                # a stalled engine with draft demand shows where the
                # speculative capacity went)
                entry["draft_blocks_needed"] = self.spec.blocks_needed(
                    i, sl.sub.req.rid, sl.cursor + self.scfg.speculate_k)
            slots.append(entry)
        if self.pcfg is not None:
            pool = {"kind": "paged", "free": self.allocator.free_count,
                    "total": self.pcfg.num_blocks,
                    "shared": self.allocator.shared_count()}
            if self.prefix is not None:
                pool["prefix_cached"] = self.prefix.size
                pool["prefix_evictable"] = self.prefix.evictable_count()
            if self.spec is not None and self.spec.pcfg is not None:
                pool["draft_free"] = self.spec.allocator.free_count
                pool["draft_total"] = self.spec.pcfg.num_blocks
        else:
            pool = {"kind": "dense"}
        return {"stall_ticks": self._stall_ticks,
                "preemption": self.scfg.preemption,
                "queued": len(self.sched), "slots": slots, "pool": pool}

    def run(self) -> list[Request]:
        done: list[Request] = []
        while self.sched.pending or any(s is not None for s in self.slots):
            done.extend(self.step())
        return done

    # -- admission + preemption ---------------------------------------------

    def _running(self):
        return [(i, sl.sub) for i, sl in enumerate(self.slots) if sl is not None]

    def _admit(self, now) -> bool:
        progress = False
        while self.sched.pending:
            best = self.sched.peek_best()
            free_idx = next((i for i, s in enumerate(self.slots) if s is None), None)
            if free_idx is None:
                victim = (AdmissionScheduler.pick_victim(
                    self._running(), min_priority=best.priority)
                    if self.scfg.preemption else None)
                if victim is None:
                    break
                self._evict(victim, now)
                progress = True
                continue
            cached = 0
            if self.pcfg is not None:
                cached = self._try_admit_paged(best)
                if cached is None:
                    victim = (AdmissionScheduler.pick_victim(
                        self._running(), min_priority=best.priority)
                        if self.scfg.preemption else None)
                    if victim is None:
                        break  # head-of-line blocks (FIFO semantics)
                    self._evict(victim, now)
                    progress = True
                    continue
                self.tables.set_row(free_idx, self.allocator.owned(best.req.rid))
            self.sched.take(best)
            toks = list(best.tokens())
            self.slots[free_idx] = _Slot(sub=best, tokens=toks,
                                         n_base=len(toks), cursor=cached)
            if self.spec is not None:
                # draft KV always restarts cold — a prefix hit on the target
                # side shares no blocks with the draft's own pool
                self.spec.attach_slot(free_idx, best.req.rid)
            if self._has_recurrent:  # slot reuse must not inherit h/conv carry
                self.state = kvcache.reset_slot_states(self.state, self.cfg,
                                                       free_idx)
            if best.metrics.admit_t is None:
                best.metrics.admit_t = now
            progress = True
        return progress

    def _try_admit_paged(self, best: Submission) -> int | None:
        """Reserve KV residency for ``best``: adopt cached prefix blocks
        (shared, refcount++), allocate the rest fresh, copy-on-write the
        partial tail block.  Returns the cached token count — the admitted
        slot's starting ``cursor``, so prefill computes only the un-shared
        suffix — or None if the pool cannot satisfy the request even after
        cache eviction (the caller preempts or stalls).

        The cached length is capped at len(tokens) − 1: the LAST prompt
        token is always computed (its logits emit the first generated
        token), which also guarantees at least one fresh block is needed.
        """
        rid = best.req.rid
        toks = best.tokens()
        cached, hit_blocks, cow_src = 0, [], None
        if self._prefix_active:
            hit_blocks, hit_len = self.prefix.match(toks)
            cached = min(hit_len, len(toks) - 1)
        k_full, m_part = divmod(cached, self.pcfg.block_size)
        if cached:
            self.allocator.adopt(rid, hit_blocks[:k_full])
            if m_part:
                # pin the divergence block: it may be index-only (refcount
                # 1) and the alloc below can trigger cache reclaim — the
                # COW source must survive until it is copied.
                cow_src = hit_blocks[k_full]
                self.allocator.ref_inc(cow_src)
        evictable = self.prefix.evictable_count() if self._prefix_active else 0
        ok = AdmissionScheduler.admissible(
            best, self.allocator.free_count + evictable, self.pcfg,
            reuse_blocks=k_full,
            draft_free_blocks=(self.spec.allocator.free_count
                               if self.spec is not None
                               and self.spec.pcfg is not None else None),
            draft_pcfg=self.spec.pcfg if self.spec is not None else None)
        got = (self.allocator.alloc(rid, best.blocks_needed(self.pcfg) - k_full)
               if ok else None)
        if got is None:
            if cow_src is not None:
                self.allocator.ref_dec(cow_src)
            self.allocator.release(rid)  # roll back the adoption
            return None
        if (self.spec is not None and self.spec.pcfg is not None
                and not self.spec.admit(
                    rid, best.blocks_needed(self.spec.pcfg))):
            # draft pool refused (admissible raced an eviction): roll back
            # the target-side reservation too — admission is both-or-neither
            if cow_src is not None:
                self.allocator.ref_dec(cow_src)
            self.allocator.release(rid)
            return None
        if cow_src is not None:
            # flush queued scrubs BEFORE copying: the dst could be a block
            # freed earlier this tick and still on the pending-scrub list —
            # a later flush would wipe the copied positions.  The dst itself
            # never joins the list; its tail is masked by the copy.
            self._flush_scrub()
            self.state = kvcache.cow_copy_block(self.state, self.cfg,
                                                cow_src, got[0], m_part)
            self.allocator.ref_dec(cow_src)
            self._pending_scrub.extend(got[1:])
        else:
            self._pending_scrub.extend(got)
        best.metrics.prefix_hit_tokens = cached
        best.metrics.prefix_hit_blocks = k_full + (1 if m_part else 0)
        if cached:
            self._tracer.event("prefix_hit", rid=rid, tokens=cached,
                               blocks=best.metrics.prefix_hit_blocks,
                               cow=bool(m_part))
            self.obs.metrics.counter("serve_prefix_hit_tokens_total").inc(
                cached)
        return cached

    def _evict(self, idx: int, now) -> None:
        """Preemption-by-eviction: free the slot + its blocks, re-enqueue at
        the queue front with the full generated history (lossless resume)."""
        sl = self.slots[idx]
        sub = sl.sub
        sub.resume_tokens = list(sub.req.prompt) + list(sub.req.out_tokens)
        if self.pcfg is not None:
            self.allocator.release(sub.req.rid)
            self.tables.clear_row(idx)
        if self.spec is not None:
            self.spec.release_slot(idx, sub.req.rid)
        sub.metrics.n_preemptions += 1
        self.sched.requeue(sub)
        self.slots[idx] = None
        self._tracer.event("preempt", slot=idx, rid=sub.req.rid,
                           priority=sub.priority,
                           resumed_len=len(sub.resume_tokens))
        self.obs.metrics.counter("serve_preemptions_total").inc()

    def preempt_slot(self, idx: int) -> None:
        """Explicit eviction hook (tests / operator tooling)."""
        if self.slots[idx] is None:
            raise ValueError(f"slot {idx} is idle")
        self._evict(idx, self._clock())

    def _ensure_blocks(self, idx: int, sl: _Slot, n_tokens: int, now) -> bool:
        """Grow ``sl``'s allocation to cover ``n_tokens`` positions.  On pool
        exhaustion, evict a strictly-worse slot (lower priority, or same
        priority but later arrival); False → the caller stalls this tick."""
        if self.pcfg is None:
            return True
        rid = sl.sub.req.rid
        need = self.pcfg.blocks_for(n_tokens) - len(self.allocator.owned(rid))
        if need <= 0:
            return True
        got = self.allocator.alloc(rid, need)
        if got is None and self.scfg.preemption:
            victim = AdmissionScheduler.pick_victim(
                self._running(), worse_than=sl.sub, exclude=idx)
            if victim is not None:
                self._evict(victim, now)
                got = self.allocator.alloc(rid, need)
        if got is None:
            return False
        self._pending_scrub.extend(got)
        self.tables.set_row(idx, self.allocator.owned(rid))
        return True

    def defrag(self) -> None:
        """Compact the block pool: in-use blocks → lowest physical ids.  A
        pure relabeling (gather + table rewrite); decode output is unchanged."""
        if self.pcfg is None:
            return
        self._flush_scrub()
        extra = self.prefix.blocks() if self.prefix is not None else ()
        src, remap = self.allocator.compact(extra_live=extra)
        self.state = kvcache.apply_compaction(self.state, self.cfg, src)
        self.tables.remap(remap)
        if self.prefix is not None:
            self.prefix.remap(remap)
        if self.spec is not None:
            self.spec.defrag()

    def _flush_scrub(self) -> None:
        if self._pending_scrub:
            self.state = kvcache.scrub_blocks(self.state, self.cfg,
                                              self._pending_scrub)
            self._pending_scrub = []

    def _table_dev(self):
        return self.tables.device() if self.pcfg is not None else self._dummy_table

    # -- ticks --------------------------------------------------------------

    def _prefill_tick(self, now, finished) -> bool:
        progress = False
        for i, sl in enumerate(self.slots):
            if sl is None or sl.cursor >= sl.n_base:
                continue
            end = min(sl.n_base, sl.cursor + self.scfg.prefill_chunk)
            if not self._ensure_blocks(i, sl, end, now):
                continue  # stalled on blocks this tick
            self._flush_scrub()
            toks = jnp.asarray(np.asarray([sl.tokens[sl.cursor:end]], np.int32))
            logits, self.state = self._chunk_fn(
                self.params, self.state, self._table_dev(), toks,
                jnp.int32(sl.cursor), jnp.int32(i))
            sl.cursor = end
            sl.sub.metrics.n_prefill_chunks += 1
            progress = True
            if sl.cursor >= sl.n_base:  # prompt done: first token from chunk
                with self._tracer.span("sample", rows=1):
                    self.key, sk = jax.random.split(self.key)
                    tok = self._sample_fn(
                        logits[:, -1, :],
                        jnp.asarray([sl.sub.req.temperature], jnp.float32), sk)
                    self._emit(i, sl, int(tok[0]), now, finished)
        return progress

    def _prefill_tick_batched(self, now, finished) -> bool:
        """ONE [S, C] call advances up to S = budget // C prefilling slots.

        Row packing is the scheduler's token-budget policy
        (:func:`repro.serve.scheduler.plan_prefill_rows`); the call shape is
        ALWAYS [S, C] — unused rows are padding (out-of-bounds slot index,
        all-(−1) positions), short final chunks are right-padded with
        pos = −1 tokens — so one trace serves every occupancy and the
        flattened mpGEMM batch is always N = S·C."""
        c = self.scfg.prefill_chunk
        prefilling = [(i, sl.sub) for i, sl in enumerate(self.slots)
                      if sl is not None and sl.cursor < sl.n_base]
        staged = []
        for i in scheduler_mod.plan_prefill_rows(prefilling):
            if len(staged) >= self._prefill_rows:
                break
            sl = self.slots[i]
            if sl is None:
                continue  # evicted by an earlier row's growth this tick
            end = min(sl.n_base, sl.cursor + c)
            if not self._ensure_blocks(i, sl, end, now):
                continue  # block-stalled: the next-ranked slot backfills
            staged.append((i, sl, sl.cursor, end))
        # an _ensure_blocks call for a LATER row may have preempted an
        # earlier staged slot (same hazard as the decode tick): drop rows
        # whose slot changed hands — their table rows now point at trash and
        # their progress resumes via re-prefill after re-admission.
        staged = [(i, sl, s0, s1) for i, sl, s0, s1 in staged
                  if self.slots[i] is sl]
        if not staged:
            return False
        rows = self._prefill_rows
        toks = np.zeros((rows, c), np.int32)
        pos = np.full((rows, c), -1, np.int32)
        idx = np.full((rows,), len(self.slots), np.int32)  # OOB → padding row
        for r, (i, sl, s0, s1) in enumerate(staged):
            n = s1 - s0
            toks[r, :n] = sl.tokens[s0:s1]
            pos[r, :n] = np.arange(s0, s1, dtype=np.int32)
            idx[r] = i
        self._flush_scrub()
        logits, self.state = self._bchunk_fn(
            self.params, self.state, self._table_dev(), jnp.asarray(toks),
            jnp.asarray(pos), jnp.asarray(idx))
        fin = []
        for r, (i, sl, s0, s1) in enumerate(staged):
            sl.cursor = s1
            sl.sub.metrics.n_prefill_chunks += 1
            if sl.cursor >= sl.n_base:  # prompt done: first token from chunk
                fin.append((r, i, sl))
        if fin:
            with self._tracer.span("sample", rows=len(fin)):
                self.key, sk = jax.random.split(self.key)
                sel = jnp.asarray([r for r, _, _ in fin], jnp.int32)
                temps = jnp.asarray(
                    [sl.sub.req.temperature for _, _, sl in fin], jnp.float32)
                toks_out = np.asarray(        # ONE host sync for every row
                    self._sample_fn(logits[sel, -1, :], temps, sk))
                for j, (r, i, sl) in enumerate(fin):
                    self._emit(i, sl, int(toks_out[j]), now, finished)
        return True

    def _decode_tick_host(self, decode_idx: list, now, finished) -> bool:
        b = len(self.slots)
        toks = np.zeros((b, 1), np.int32)
        pos = np.full((b,), -1, np.int32)
        staged = []
        for i in decode_idx:
            sl = self.slots[i]
            if sl is None:
                continue  # finished or evicted earlier this tick
            if not self._ensure_blocks(i, sl, sl.cursor + 1, now):
                continue
            toks[i, 0] = sl.tokens[sl.cursor]
            pos[i] = sl.cursor
            staged.append((i, sl))
        # a later slot's growth may have PREEMPTED an earlier staged slot:
        # drop evictees AND reset their staged position to −1 — a pos ≥ 0
        # write would land in the trash block (their table row now points at
        # it) and record a real position there, breaking the trash pos = −1
        # invariant every sequence's masking relies on.  Their progress
        # resumes via re-prefill after re-admission.
        kept = []
        for i, sl in staged:
            if self.slots[i] is sl:
                kept.append((i, sl))
            else:
                toks[i, 0] = 0
                pos[i] = -1
        staged = kept
        if not staged:
            return False
        self._flush_scrub()
        logits, self.state = self._step_fn(
            self.params, jnp.asarray(toks), jnp.asarray(pos), self.state,
            self._table_dev())
        temps = np.zeros((b,), np.float32)
        for i, sl in staged:
            temps[i] = sl.sub.req.temperature
        with self._tracer.span("sample", rows=len(staged)):
            self.key, sk = jax.random.split(self.key)
            sampled = np.asarray(self._sample_fn(
                logits[:, 0, :], jnp.asarray(temps), sk))  # ONE host sync/tick
            for i, sl in staged:
                sl.cursor += 1
                if sl.cursor < sl.n_base:
                    continue  # token-mode prefill still consuming the prompt
                self._emit(i, sl, int(sampled[i]), now, finished)
        return True

    def _spec_draft(self, staged, b: int, k: int):
        """Model-draft half of the speculative tick: catch the draft KV up
        on committed history, then run the k fused draft steps.  Returns
        ``(drafts, gaps)`` — the [B, k] device proposals and each slot's
        cursor gap (which proposal column maps to which verify column).
        Never called under lookup drafting (no draft model to run)."""
        sp = self.spec
        gaps = {}
        with self._tracer.span("spec_draft", slots=len(staged), k=k):
            # -- draft catch-up: fixed-width [B, W] ingest of committed
            # history (logits discarded), batched across every slot that
            # needs it, until each is one forced step behind its cursor
            pend = {i: (sl, c) for i, sl, c, n, ing in staged if ing}
            w = self._spec_ingest_w
            while pend:
                itoks = np.zeros((b, w), np.int32)
                ipos = np.full((b, w), -1, np.int32)
                caught = []
                for i, (sl, c) in pend.items():
                    dc = sp.cursors[i]
                    g = min(w, c - dc)
                    itoks[i, :g] = sl.tokens[dc:dc + g]
                    ipos[i, :g] = np.arange(dc, dc + g, dtype=np.int32)
                    sp.cursors[i] = dc + g
                    if sp.cursors[i] >= c:
                        caught.append(i)
                sp.flush_scrub()
                _, sp.state = sp.ingest_fn(sp.params, jnp.asarray(itoks),
                                           jnp.asarray(ipos), sp.state,
                                           sp.table_dev())
                for i in caught:
                    del pend[i]
            # -- the k draft steps over ALL slots, fused in ONE jitted call
            # (_draft_loop_tick).  Step s writes draft position dc+s:
            # forced to the committed token while dc+s <= cursor (folding
            # steady-state gaps of <= k into the loop instead of paying a
            # [B, W] ingest), fed back from the previous step's greedy
            # token beyond, masked to pos −1 (trash write) past each
            # slot's horizon.
            drafts = None
            if any(n for _, _, _, n, _ in staged):
                forced = np.zeros((b, k), np.int32)
                fmask = np.ones((b, k), bool)
                dpos = np.full((b, k), -1, np.int32)
                for i, sl, c, n, _ in staged:
                    if n == 0:
                        continue
                    dc = sp.cursors[i]
                    gaps[i] = c - dc
                    for s in range(k):
                        p = dc + s
                        if p > c + n - 1:
                            break
                        dpos[i, s] = p
                        if p <= c:
                            forced[i, s] = sl.tokens[p]
                        else:
                            fmask[i, s] = False
                sp.flush_scrub()
                drafts, sp.state = sp.step_fn(                # [B, k] device
                    sp.params, jnp.asarray(forced), jnp.asarray(fmask),
                    jnp.asarray(dpos), sp.state, sp.table_dev())
                for i, sl, c, n, _ in staged:
                    if n:
                        sp.cursors[i] = c + n
        return drafts, gaps

    def _spec_tick(self, decode_idx: list, now, finished) -> bool:
        """Speculative decode tick (DESIGN.md §10): draft up to k tokens per
        slot — with the DRAFT model (fused forced/feedback steps over the
        draft's own KV) or, under lookup drafting, straight off the slot's
        committed history at zero model cost — score all k+1 positions on
        the TARGET in one [B, k+1] verify call (flattened mpGEMM batch
        N = B·(k+1), the GEMM regime), commit the longest drafted prefix
        matching the target's greedy argmax plus one bonus token, and roll
        back every rejected KV write — block-table truncation when paged,
        position-value masking when dense.

        Identity invariant: column 0 of the verify call feeds exactly what
        the plain decode tick would (``tokens[cursor]`` at ``pos cursor``),
        every committed token is the TARGET's own token at its position
        (accepted drafts equal the target argmax by construction; the bonus
        IS the target sample), and the engine's key splits once per tick
        either way — so greedy output is bit-identical to the
        non-speculative engine whatever the draft proposes.  Slots that
        cannot speculate this tick (temperature > 0, still consuming a
        token-mode prompt, out of draft blocks, one token from a cap)
        degrade to n_extra = 0: a width-1 verify that IS a plain decode
        step.  The draft pool is an accelerator, never a blocker.
        """
        tr = self._tracer
        sp = self.spec
        k = self.scfg.speculate_k
        b = len(self.slots)
        staged = []   # (slot, _Slot, cursor, n_extra, needs_ingest)
        props = {}    # slot -> host proposal list (lookup drafting only)
        for i in decode_idx:
            sl = self.slots[i]
            if sl is None:
                continue  # finished or evicted earlier this tick
            c = sl.cursor
            req = sl.sub.req
            rid = req.rid
            # how many positions beyond ``c`` speculation may write: stay one
            # short of every cap so the bonus token still fits, and never
            # draft for sampled slots (temperature ties tokens to the key
            # stream; only greedy acceptance is exact) or mid-prompt slots
            cap = min(self.scfg.max_seq - 2 - c,
                      req.max_new_tokens - len(req.out_tokens) - 1)
            n_extra, needs_ingest = 0, False
            if req.temperature == 0.0 and c >= sl.n_base and cap > 0:
                if sp.lookup:
                    # prompt-lookup proposals come straight off the slot's
                    # committed history — no draft KV, no ingest, and an
                    # empty match degrades to a width-1 verify (plain decode)
                    p = sp.propose(sl.tokens, c, min(k, cap))
                    if p:
                        props[i] = p
                        n_extra = len(p)
                elif (gap := c - sp.cursors[i]) > k:
                    # decode entry / post-stall: the draft must ingest the
                    # committed history before the fold-as-forced-steps
                    # window can cover the gap
                    want = min(k, cap)
                    if sp.ensure(i, rid, c + want):
                        n_extra, needs_ingest = want, True
                else:
                    want = min(k - gap, cap)
                    if want > 0 and sp.ensure(i, rid, c + want):
                        n_extra = want
            if not self._ensure_blocks(i, sl, c + 1 + n_extra, now):
                if n_extra == 0 or not self._ensure_blocks(i, sl, c + 1, now):
                    continue  # stalled on target blocks this tick
                n_extra, needs_ingest = 0, False
            staged.append((i, sl, c, n_extra, needs_ingest))
        # _ensure_blocks for a later slot may have preempted an earlier
        # staged one (same hazard as the plain decode tick)
        staged = [t for t in staged if self.slots[t[0]] is t[1]]
        if not staged:
            return False
        if not any(n for _, _, _, n, _ in staged):
            # every slot degraded to width 1 this tick (no proposals): the
            # plain [B, 1] decode step commits the same tokens as a verify
            # full of padding columns, at GEMV-regime cost.  Identity holds
            # — same logits position, same once-per-tick key split.
            with tr.span("decode", slots=len(decode_idx)):
                return self._decode_tick_host(decode_idx, now, finished)

        drafts, gaps = (None, {}) if sp.lookup else \
            self._spec_draft(staged, b, k)

        with tr.span("spec_verify", slots=len(staged)):
            # -- one [B, k+1] verify: column 0 replays the plain decode
            # step, columns 1..n_extra are the proposals (gathered on-device
            # so verify dispatch never waits on a draft host sync)
            vpos = np.full((b, k + 1), -1, np.int32)
            col0 = np.zeros((b,), np.int32)
            temps = np.zeros((b,), np.float32)
            sel = np.zeros((b, k), np.int32) if k else None
            prop_cols = np.zeros((b, k), np.int32) if k else None
            for i, sl, c, n, _ in staged:
                col0[i] = sl.tokens[c]
                vpos[i, 0] = c
                vpos[i, 1:n + 1] = np.arange(c + 1, c + n + 1, dtype=np.int32)
                temps[i] = sl.sub.req.temperature
                if n and sp.lookup:
                    prop_cols[i, :n] = props[i]   # host-side n-gram proposals
                elif n:
                    # proposal j is the output of draft step gap+j−1
                    sel[i] = np.clip(gaps[i] + np.arange(k), 0, k - 1)
            if drafts is not None:
                vtok = jnp.concatenate(
                    [jnp.asarray(col0)[:, None],
                     jnp.take_along_axis(drafts, jnp.asarray(sel), axis=1)],
                    axis=1)
            else:
                # lookup proposals (or an all-degraded tick): columns 1..k
                # are already on the host, no device gather needed
                vtok = jnp.concatenate(
                    [jnp.asarray(col0)[:, None],
                     jnp.asarray(prop_cols) if k else
                     jnp.zeros((b, k), jnp.int32)], axis=1)
            self._flush_scrub()
            logits, self.state = self._verify_fn(
                self.params, vtok, jnp.asarray(vpos), self.state,
                self._table_dev())
            greedy = jnp.argmax(logits, axis=-1)             # [B, k+1]
            with tr.span("sample", rows=len(staged)):
                self.key, sk = jax.random.split(self.key)
                samp0 = self._sample_fn(logits[:, 0, :], jnp.asarray(temps),
                                        sk)
                greedy_h, samp0_h, vtok_h = jax.device_get(
                    (greedy, samp0, vtok))  # ONE wait: everything above is
                #                             already queued behind it

            # -- acceptance + rollback + commit
            m = self.obs.metrics
            t_items, d_items, commits = [], [], []
            lo_t = np.ones((b,), np.int32)
            hi_t = np.zeros((b,), np.int32)   # empty [1, 0] value ranges
            lo_d = np.ones((b,), np.int32)
            hi_d = np.zeros((b,), np.int32)
            for i, sl, c, n, _ in staged:
                a = (spec_mod.longest_prefix_accept(greedy_h[i], vtok_h[i], n)
                     if n else 0)
                bonus = int(samp0_h[i]) if a == 0 else int(greedy_h[i, a])
                committed = ([int(vtok_h[i, j]) for j in range(1, a + 1)]
                             + [bonus])
                commits.append((i, sl, committed))
                self._spec_totals["steps"] += 1
                self._spec_totals["drafted"] += n
                self._spec_totals["accepted"] += a
                self._spec_totals["rejected"] += n - a
                self._spec_totals["committed"] += len(committed)
                if n:
                    m.counter("serve_spec_tokens_drafted_total").inc(n)
                    m.counter("serve_spec_tokens_accepted_total").inc(a)
                    m.counter("serve_spec_tokens_rejected_total").inc(n - a)
                    m.histogram("serve_spec_acceptance_rate").observe(a / n)
                if a < n:
                    tr.event("spec_reject", slot=i, rid=sl.sub.req.rid,
                             drafted=n, accepted=a)
                rid = sl.sub.req.rid
                if a < n:          # target wrote pos c..c+n; c+a+1.. rejected
                    if self.pcfg is not None:
                        t_items.append((i, rid, c + a + 1, c + n))
                    else:
                        lo_t[i], hi_t[i] = c + a + 1, c + n
                if n and not sp.lookup:
                    # draft wrote pos ..c+n−1; keep c+a valid (lookup
                    # drafting wrote no draft KV — nothing to roll back)
                    dkeep = min(c + a + 1, c + n)
                    if dkeep <= c + n - 1:
                        if self.pcfg is not None:
                            d_items.append((i, rid, dkeep, c + n - 1))
                        else:
                            lo_d[i], hi_d[i] = dkeep, c + n - 1
                    sp.cursors[i] = dkeep
            # rollback BEFORE commit: a commit can finish the request and
            # release its runs — truncation must happen while they exist
            if self.pcfg is not None:
                if t_items:
                    self.state = spec_mod.rollback_paged(
                        self.state, self.cfg, self.pcfg, self.allocator,
                        self.tables, self._pending_scrub, t_items)
                if d_items:
                    sp.rollback(d_items)
            else:
                if np.any(hi_t >= lo_t):
                    self.state = kvcache.rollback_dense_positions(
                        self.state, self.cfg, lo_t, hi_t)
                if np.any(hi_d >= lo_d):
                    sp.rollback_dense(lo_d, hi_d)
            for i, sl, committed in commits:
                for t in committed:
                    sl.cursor += 1
                    if sl.cursor < sl.n_base:
                        continue  # token-mode prefill consuming the prompt
                    self._emit(i, sl, t, now, finished)
                    if self.slots[i] is not sl:
                        break     # finished mid-commit: drop the rest
        return True

    def _emit(self, idx: int, sl: _Slot, tok: int, now, finished) -> None:
        req = sl.sub.req
        m = sl.sub.metrics
        if self._prefix_active and not sl.indexed:
            # Prompt complete (first emit): publish its full blocks to the
            # prefix index.  The owned run is in logical order (adopted
            # prefix blocks first, then fresh), so block i holds tokens
            # [i·bs, (i+1)·bs).  Must happen before any release — the index
            # reference is what lets these blocks outlive the request.
            sl.indexed = True
            bs = self.pcfg.block_size
            n_full = sl.n_base // bs
            if n_full:
                self.prefix.insert(sl.tokens[:n_full * bs],
                                   self.allocator.owned(req.rid)[:n_full])
        sl.tokens.append(tok)
        req.out_tokens.append(tok)
        if m.first_token_t is None:
            m.first_token_t = now
        m.n_generated = len(req.out_tokens)
        if len(req.out_tokens) >= req.max_new_tokens or sl.cursor >= self.scfg.max_seq - 1:
            req.done = True
            m.finish_t = now
            if self.pcfg is not None:
                self.allocator.release(req.rid)
                self.tables.clear_row(idx)
            if self.spec is not None:
                self.spec.release_slot(idx, req.rid)
            self.stats.add(m)
            self.slots[idx] = None
            finished.append(req)
            reg = self.obs.metrics
            reg.counter("serve_requests_finished_total").inc()
            reg.counter("serve_tokens_generated_total").inc(m.n_generated)


def _sample_batched(logits, temps, key):
    """[B, V] logits + per-slot temperatures → [B] tokens, one device call.

    temp == 0 → exact argmax (bitwise-identical to per-slot greedy); temp > 0
    → Gumbel-max categorical at that temperature."""
    greedy = jnp.argmax(logits, axis=-1)
    g = jax.random.gumbel(key, logits.shape, jnp.float32)
    t = jnp.maximum(temps, 1e-6)[:, None]
    samp = jnp.argmax(logits / t + g, axis=-1)
    return jnp.where(temps > 0, samp, greedy).astype(jnp.int32)


_SAMPLE_FN = jax.jit(_sample_batched)
