"""Per-request QoS classes and registry-driven format selection (DESIGN.md §7).

A QoS class is a scheduling envelope plus a serving *objective* that picks a
weight format from :mod:`repro.core.formats` — the admission-time contract is
deliberately thin: the class maps to a queue priority boost (the scheduler's
existing strict-priority policy does the rest) and to a format the OPERATOR
applies at the replica level.  Formats are baked into packed weight planes at
load time, so a single engine cannot re-quantize per request; ``select_format``
is the policy a multi-replica deployment uses to route classes to replicas
(and what ``launch/serve.py --qos`` uses to pick the demo engine's format).

Objectives, resolved against the live format registry (never hard-coded names,
so newly registered formats participate automatically):

  * ``latency``  — fastest GEMV decode: grouped-scale variants (per-group
    absmean keeps accuracy at low bpw) whose codes drive the true-LUT GEMV
    kernel, preferring power-of-two alphabets (the packed field IS the table
    index — no base-b digit decode on the hot path, no wasted LUT slots),
    then minimal bpw.  Resolves to ``int2_g128`` in the stock registry.
  * ``memory``   — minimal HBM residency among lossless formats that still
    have a practical table path (lut_size bounded; rules out the MAD-only
    tq1 baseline).  Resolves to ``tl2`` in the stock registry.
  * ``balanced`` — the serving default (``i2s``: simplest lossless kernel).
"""

from __future__ import annotations

import dataclasses

from repro.core import formats


@dataclasses.dataclass(frozen=True)
class QoSClass:
    name: str
    priority_boost: int     # added to the submission's priority at admission
    objective: str          # "latency" | "memory" | "balanced"


CLASSES = {
    "latency": QoSClass("latency", priority_boost=2, objective="latency"),
    "standard": QoSClass("standard", priority_boost=0, objective="balanced"),
    "memory": QoSClass("memory", priority_boost=0, objective="memory"),
}


def get(name: str) -> QoSClass:
    try:
        return CLASSES[name]
    except KeyError:
        raise KeyError(
            f"unknown QoS class {name!r}; expected one of {sorted(CLASSES)}"
        ) from None


def _is_pow2(n: int) -> bool:
    return n > 0 and (n & (n - 1)) == 0


def select_format(qos_name: str, candidates=None) -> str:
    """Pick the registry format serving ``qos_name``'s objective (see module
    docstring).  ``candidates`` restricts the choice (default: the full
    registry); ties break on name for determinism."""
    cls = get(qos_name)
    names = list(candidates) if candidates is not None else list(formats.names())
    specs = [formats.get(n) for n in names]

    if cls.objective == "latency":
        # grouped-scale LUT-GEMV formats first; if the candidate set has
        # none (e.g. the model's K dims don't divide the group size), any
        # true-LUT GEMV format still beats the MAD fallback for decode
        for pool in ([s for s in specs
                      if s.group_scale_cols and s.supports_lut_gemv()],
                     [s for s in specs if s.supports_lut_gemv()]):
            if pool:
                return min(pool, key=lambda s: (not _is_pow2(s.base),
                                                s.bpw, s.name)).name
    elif cls.objective == "memory":
        pool = [s for s in specs
                if s.lossless and s.group >= 2 and 0 < s.lut_size <= 64]
        if pool:
            return min(pool, key=lambda s: (s.bpw, s.name)).name

    # balanced / fallback: the simplest lossless single-element code.
    pool = [s for s in specs if s.lossless and s.base] or specs
    return min(pool, key=lambda s: (s.group != 1, s.bpw, s.name)).name
