"""BitNet b1.58 model family — the paper's own evaluation ladder.

Sizes follow (Wang et al., 2024b) "1-bit AI Infra Part 1.1" / paper Table 7:
700M, 1.5B, 3.8B, 7B, 13B, 30B, 70B, 100B.  Llama-shaped dense transformers
trained with the b1.58 QAT scheme (absmean ternary weights, per-tensor int8
activations) — the models Bitnet.cpp serves losslessly.
"""

from repro.models.config import ModelConfig

_LADDER = {
    # name: (layers, d_model, heads, kv, d_ff)
    "700m": (24, 1536, 16, 16, 4096),
    "1.5b": (24, 2048, 16, 16, 5460),
    "3.8b": (32, 3072, 32, 32, 8192),
    "7b": (32, 4096, 32, 32, 11008),
    "13b": (40, 5120, 40, 40, 13824),
    "30b": (60, 6656, 52, 52, 17920),
    "70b": (80, 8192, 64, 8, 28672),
    "100b": (110, 8192, 64, 8, 28672),
}


def make(size: str) -> ModelConfig:
    layers, d, h, kv, ff = _LADDER[size]
    return ModelConfig(
        name=f"bitnet-b1.58-{size}",
        n_layers=layers,
        d_model=d,
        n_heads=h,
        n_kv_heads=kv,
        d_head=d // h,
        d_ff=ff,
        vocab=32002,
        rope_theta=10_000.0,
    )


CONFIG = make("700m")  # default: the bitnet_b1_58-large-scale model of Table 2
