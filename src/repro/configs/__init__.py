"""Architecture registry: ``--arch <id>`` → ModelConfig, plus smoke reducers.

Full configs are exercised only through the dry-run (ShapeDtypeStruct, no
allocation); ``smoke(arch)`` returns a same-family reduced config that runs a
real forward/train step on CPU.
"""

from __future__ import annotations

import dataclasses

from repro.models.config import ModelConfig

from repro.configs import (  # noqa: F401
    bitnet_b158,
    deepseek_coder_33b,
    gemma3_4b,
    llama4_maverick_400b_a17b,
    mamba2_1_3b,
    moonshot_v1_16b_a3b,
    phi3_vision_4_2b,
    qwen1_5_0_5b,
    qwen3_4b,
    recurrentgemma_2b,
    seamless_m4t_medium,
)

ARCHS = {
    "phi-3-vision-4.2b": phi3_vision_4_2b.CONFIG,
    "deepseek-coder-33b": deepseek_coder_33b.CONFIG,
    "gemma3-4b": gemma3_4b.CONFIG,
    "qwen3-4b": qwen3_4b.CONFIG,
    "qwen1.5-0.5b": qwen1_5_0_5b.CONFIG,
    "moonshot-v1-16b-a3b": moonshot_v1_16b_a3b.CONFIG,
    "llama4-maverick-400b-a17b": llama4_maverick_400b_a17b.CONFIG,
    "recurrentgemma-2b": recurrentgemma_2b.CONFIG,
    "seamless-m4t-medium": seamless_m4t_medium.CONFIG,
    "mamba2-1.3b": mamba2_1_3b.CONFIG,
    "bitnet-b1.58-700m": bitnet_b158.make("700m"),
    "bitnet-b1.58-3.8b": bitnet_b158.make("3.8b"),
    "bitnet-b1.58-100b": bitnet_b158.make("100b"),
}

ASSIGNED = [k for k in ARCHS if not k.startswith("bitnet")]


def get(name: str) -> ModelConfig:
    return ARCHS[name]


def smoke(name: str) -> ModelConfig:
    """Reduced same-family config: small widths, few experts, tiny vocab."""
    cfg = ARCHS[name]
    pat = len(cfg.block_pattern)
    return dataclasses.replace(
        cfg,
        n_layers=max(2 * pat + min(1, cfg.n_layers % pat), pat + 1),  # scan + remainder coverage
        d_model=192,
        n_heads=4,
        n_kv_heads=max(1, min(cfg.n_kv_heads, 2)),
        d_head=48,
        d_ff=0 if cfg.d_ff == 0 else 288,
        vocab=512,
        n_experts=min(cfg.n_experts, 4) if cfg.n_experts else 0,
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        d_inner=192 if cfg.d_inner else 0,
        ssm_state=16 if cfg.ssm_state else 0,
        ssm_heads=4 if cfg.ssm_heads else 0,
        enc_layers=2 if cfg.enc_layers else 0,
        enc_seq=16 if cfg.enc_seq else 0,
        frontend_tokens=6 if cfg.frontend_tokens else 0,
        window=32,
        attn_block=64,
    )
