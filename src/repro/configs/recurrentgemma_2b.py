"""recurrentgemma-2b [hybrid]: 26L d_model=2560 10H (GQA kv=1) d_ff=7680
vocab=256000, RG-LRU + local attention in a (rec, rec, attn) 2:1 pattern
[arXiv:2402.19427].  Sub-quadratic → long_500k eligible."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    d_head=256,
    d_ff=7680,
    vocab=256000,
    block_pattern=("rec", "rec", "local"),
    window=2048,
    d_inner=2560,
    conv_width=4,
    rope_theta=10_000.0,
)
