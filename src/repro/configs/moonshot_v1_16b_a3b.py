"""moonshot-v1-16b-a3b [moe]: 48L d_model=2048 16H (GQA kv=16) d_ff=1408/expert
vocab=163840, MoE 64 experts top-6 (kimi/moonlight) [hf:moonshotai/Moonlight-16B-A3B]."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_head=128,
    d_ff=1408,
    vocab=163840,
    ffn_kind="moe",
    n_experts=64,
    top_k=6,
    rope_theta=50_000.0,
)
