"""deepseek-coder-33b [dense]: llama-arch, 62L d_model=7168 56H (GQA kv=8)
d_ff=19200 vocab=32256 [arXiv:2401.14196]."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-coder-33b",
    n_layers=62,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_head=128,
    d_ff=19200,
    vocab=32256,
    rope_theta=100_000.0,
)
