"""llama4-maverick-400b-a17b [moe]: 48L d_model=5120 40H (GQA kv=8)
d_ff=8192/expert vocab=202048, MoE 128 experts top-1, early-fusion multimodal
[hf:meta-llama/Llama-4-*].

Simplifications vs the production model (noted per DESIGN.md): every layer is
MoE (no dense interleave / shared expert); early fusion is the stub vision
frontend prepending patch embeddings.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_head=128,
    d_ff=8192,
    vocab=202048,
    ffn_kind="moe",
    n_experts=128,
    top_k=1,
    frontend="vision",
    frontend_tokens=144,
    rope_theta=500_000.0,
)
