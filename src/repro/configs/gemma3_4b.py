"""gemma3-4b [dense]: 34L d_model=2560 8H (GQA kv=4) d_ff=10240 vocab=262144,
5:1 local:global layer pattern, 128k context [hf:google/gemma-3-*].

The dominant local layers are sliding-window (1024) → ring-buffer KV caches;
this is what makes the long_500k cell feasible (DESIGN.md §4).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-4b",
    n_layers=34,
    d_model=2560,
    n_heads=8,
    n_kv_heads=4,
    d_head=256,
    d_ff=10240,
    vocab=262144,
    block_pattern=("local", "local", "local", "local", "local", "attn"),
    window=1024,
    rope_theta=1_000_000.0,
)
