"""phi-3-vision-4.2b [vlm]: phi3-mini backbone + CLIP frontend (stub).

32L d_model=3072 32H (GQA kv=32) d_ff=8192 vocab=32064
[hf:microsoft/Phi-3-vision-128k-instruct].  The vision tower is a modality
stub per the brief: input_specs() supplies precomputed patch embeddings.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    d_head=96,
    d_ff=8192,
    vocab=32064,
    rope_theta=10_000.0,
    frontend="vision",
    frontend_tokens=576,   # 24×24 CLIP patch grid
)
