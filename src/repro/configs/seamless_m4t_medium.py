"""seamless-m4t-medium [audio]: encoder-decoder, 12L enc + 12L dec,
d_model=1024 16H (kv=16) d_ff=4096 vocab=256206 [arXiv:2308.11596].

The speech frontend is a stub: input_specs() provides precomputed frame
embeddings [B, enc_seq, d_model] consumed by the bidirectional encoder; the
decoder is causal with per-layer cross-attention.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    n_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_head=64,
    d_ff=4096,
    vocab=256206,
    enc_layers=12,
    enc_seq=1024,          # stub audio frames per sample
    frontend="audio",
    rope_theta=10_000.0,
)
