"""mamba2-1.3b [ssm]: 48L d_model=2048, attention-free SSD (state-space
duality), ssm_state=128, vocab=50280 [arXiv:2405.21060].

d_ff=0: SSD blocks have no separate FFN (the mixer IS the block).  The
ternary technique applies to in/out projections; the SSD recurrence itself is
weight-free (DESIGN.md §Arch-applicability).  O(1) state → long_500k eligible.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    n_layers=48,
    d_model=2048,
    n_heads=1,            # no attention heads (attn-free)
    n_kv_heads=1,
    d_head=64,
    d_ff=0,
    vocab=50280,
    block_pattern=("ssd",),
    d_inner=4096,         # 2 × d_model
    ssm_state=128,
    ssm_heads=64,         # head dim P = 64
    conv_width=4,
)
