"""Core: the paper's contribution — low-bit quantization, packing, mpGEMM.

The format registry (``repro.core.formats``) and the parametric ELUT engine
(``repro.core.elut``) generalize the ternary stack to any (base, group)
element-wise-lookup format (paper Appendix).
"""

from repro.core.bitlinear import BitLinearParams, QuantConfig
from repro.core.formats import FormatSpec
from repro.core.qtensor import (FORMAT_BPW, PackedWeight, pack_quantized,
                                pack_ternary, pack_weight, unpack_weight)

__all__ = [
    "BitLinearParams",
    "QuantConfig",
    "FormatSpec",
    "PackedWeight",
    "FORMAT_BPW",
    "pack_weight",
    "pack_quantized",
    "pack_ternary",
    "unpack_weight",
]
