"""Core: the paper's contribution — ternary quantization, packing, mpGEMM."""

from repro.core.bitlinear import BitLinearParams, QuantConfig
from repro.core.qtensor import FORMAT_BPW, PackedWeight, pack_ternary, pack_weight, unpack_weight

__all__ = [
    "BitLinearParams",
    "QuantConfig",
    "PackedWeight",
    "FORMAT_BPW",
    "pack_weight",
    "pack_ternary",
    "unpack_weight",
]
