"""Format registry: every weight packing format as one ``FormatSpec``.

This is the seam the ELUT engine (paper Appendix, "element-wise lookup
table for general low-bit LLMs") hangs off: a format is no longer a branch
in an if-chain but a registry entry carrying

  * ``pack`` / ``unpack`` callables (plane dict <-> int8 code matrix),
  * ``quantize`` (fp master weight -> (codes, scale), the training-side rule),
  * bpw, element base ``b`` (alphabet size), group size ``g`` (elements per
    LUT code), packed field width in bits, plane layout,
  * K-divisibility (``k_align``) and the block-fitting split-K rule,
  * capability flags: ``elut`` (plain code-plane layout -> the parametric
    ELUT kernels apply) and ``pallas`` (some fused Pallas kernel exists).

The ternary formats (i2s, tl1, tq1) are instances of the parametric base-b
packer with (b, g) = (3, 1), (3, 2), (3, 5); the non-ternary int2/int3
formats are (4, 2) and (8, 2) through the *same* code path.  tl2/tl2k keep
their mirror-consolidated sign+index planes (base 3 with a folded table);
fp/int4 are native-dtype formats with no code plane.

New bit-widths are new ``register(...)`` calls, not new kernel files.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable

import jax.numpy as jnp

from repro.core import packing, quant


@dataclasses.dataclass(frozen=True)
class FormatSpec:
    """One weight format (DESIGN.md §2).

    ``pack(w_q) -> dict[str, Array]`` and ``unpack(planes, k) -> int8 [M, K]``
    are exact inverses on matrices whose entries are valid codes (levels in
    ``[lo, hi]``).  ``quantize(w_fp) -> (w_q, scale)`` is the training-side
    rule producing those codes (None for the fp passthrough format).
    """

    name: str
    bpw: float                      # packed bits per weight in HBM
    base: int = 0                   # element alphabet size b (0: native dtype)
    group: int = 0                  # g elements per LUT code (0: not code-based)
    field_bits: int = 0             # packed bits per code field (nibble=4, ...)
    k_align: int = 1                # required K divisibility for packing
    planes: tuple = ()              # plane-dict layout (names)
    pack: Callable | None = None
    unpack: Callable | None = None
    quantize: Callable | None = None
    split_k: Callable | None = None  # K -> (main_k, tail_k) block-fitting rule
    elut: bool = False              # parametric ELUT kernels apply
    pallas: bool = False            # a fused Pallas kernel path exists
    lut_entries: int = 0            # table-size override (tl2's folded 14)
    # Per-group weight scales: one fp32 scale per G K-columns per output row
    # (scale plane [K//G, M], packing module docstring).  None = per-tensor
    # scalar scale (the b1.58 default) — the two paths must stay bit-identical
    # at None (asserted in tests/test_regression_golden.py).
    group_scale_cols: int | None = None
    # Lossless contract: integer accumulation reproduces the quantized
    # reference computation EXACTLY (conformance harness gates atol=0).
    # False only for the fp passthrough baseline (no integer semantics).
    lossless: bool = True

    # -- derived quantities (the napkin math the cost hints are built from) --

    @property
    def lut_size(self) -> int:
        """C: entries in the element-wise lookup table (b^g, or the folded
        count for mirror-consolidated formats)."""
        if self.lut_entries:
            return self.lut_entries
        return self.base ** self.group if self.group else 0

    @property
    def offset(self) -> int:
        """Weight value = digit - offset; symmetric-ish levels around 0."""
        return self.base // 2

    @property
    def levels(self) -> tuple:
        """(lo, hi) valid weight values. b=3 -> (-1, 1); b=4 -> (-2, 1)."""
        return (-self.offset, self.base - 1 - self.offset)

    @property
    def weights_per_byte(self) -> int:
        return self.group * (8 // self.field_bits) if self.field_bits else 0

    @property
    def mxu_inflation(self) -> float:
        """True-LUT one-hot contraction MXU work vs the plain MAD dot:
        C MACs per group of g weights -> C/g = b^g/g (tl1: 4.5x)."""
        return self.lut_size / self.group if self.group else 1.0

    @property
    def lut_hbm_bpw(self) -> float:
        """HBM bits/weight of the XLA one-hot path: the int8 one-hot operand
        [M, G, C] materializes -> C bytes per g weights (tl1: 36.0)."""
        return 8.0 * self.lut_size / self.group if self.group else 8.0

    def supports_lut_gemv(self) -> bool:
        """True-LUT GEMV pays off only for grouped codes (g >= 2): at g == 1
        the 'table' is the weight itself and LUT build is pure overhead."""
        return self.elut and self.group >= 2


REGISTRY: dict[str, FormatSpec] = {}


def register(spec: FormatSpec) -> FormatSpec:
    if spec.name in REGISTRY:
        raise ValueError(f"format {spec.name!r} already registered")
    REGISTRY[spec.name] = spec
    return spec


def get(name: str) -> FormatSpec:
    spec = REGISTRY.get(name)
    if spec is None:
        raise ValueError(f"unknown format {name!r}; registered: {sorted(REGISTRY)}")
    return spec


def names() -> tuple:
    return tuple(REGISTRY)


def bpw(name: str) -> float:
    return get(name).bpw


def elut_formats() -> tuple:
    return tuple(f for f, s in REGISTRY.items() if s.elut)


def pallas_formats() -> tuple:
    return tuple(f for f, s in REGISTRY.items() if s.pallas)


def lut_gemv_formats() -> tuple:
    return tuple(f for f, s in REGISTRY.items() if s.supports_lut_gemv())


def grouped_formats() -> tuple:
    """Formats carrying per-group weight scales (group_scale_cols set)."""
    return tuple(f for f, s in REGISTRY.items() if s.group_scale_cols)


class _BpwView:
    """Dict-like live view of per-format bpw (back-compat for FORMAT_BPW)."""

    def __getitem__(self, name: str) -> float:
        return get(name).bpw

    def __contains__(self, name: str) -> bool:
        return name in REGISTRY

    def __iter__(self):
        return iter(REGISTRY)

    def keys(self):
        return REGISTRY.keys()

    def items(self):
        return tuple((f, s.bpw) for f, s in REGISTRY.items())


FORMAT_BPW = _BpwView()


# ---------------------------------------------------------------------------
# Built-in registrations
# ---------------------------------------------------------------------------

def _elut_spec(name: str, b: int, g: int, field_bits: int, *,
               k_align: int | None = None, pad: bool = False,
               pallas: bool = True, elut: bool = True) -> FormatSpec:
    """A format whose planes are one packed code plane from the parametric
    base-b packer — the plain ELUT layout."""
    wpb = g * (8 // field_bits)
    return FormatSpec(
        name=name,
        bpw=8.0 / wpb,  # pad=True amortizes to the same ratio for large K
        base=b, group=g, field_bits=field_bits,
        k_align=wpb if k_align is None else k_align,
        planes=("p",),
        pack=lambda w: {"p": packing.elut_pack(w, b, g, field_bits, pad=pad)},
        unpack=lambda planes, k: packing.elut_unpack(
            planes["p"], k, b, g, field_bits),
        quantize=partial(quant.absmean_lowbit, lo=-(b // 2), hi=b - 1 - b // 2),
        elut=elut, pallas=pallas,
    )


def _splitk_fns(pack3, unpack3, split_k):
    """(pack, unpack) pair for a split-K sign+index format: the ThreeK
    prefix uses the mirror-consolidated planes, the TwoK tail packs tl1
    (block-fitting weight splitting, paper §3.1.2)."""

    def pack(w):
        three_k, two_k = split_k(w.shape[1])
        planes = {}
        if three_k:
            idx_plane, sign_plane = pack3(w[:, :three_k])
            planes["idx"] = idx_plane
            planes["sign"] = sign_plane
        if two_k:
            planes["tail"] = packing.tl1_pack(w[:, three_k:])
        return planes

    def unpack(planes, k):
        three_k, _ = split_k(k)
        parts = []
        if three_k:
            parts.append(unpack3(planes["idx"], planes["sign"], three_k))
        if three_k < k:
            parts.append(packing.tl1_unpack(planes["tail"], k - three_k))
        return jnp.concatenate(parts, axis=1) if len(parts) > 1 else parts[0]

    return pack, unpack


_tl2_pack, _tl2_unpack = _splitk_fns(
    packing.tl2_pack, packing.tl2_unpack, packing.tl2_split_k)
_tl2k_pack, _tl2k_unpack = _splitk_fns(
    packing.tl2k_pack, packing.tl2k_unpack, packing.tl2k_split_k)


def _lcm(a: int, b: int) -> int:
    import math

    return a * b // math.gcd(a, b)


def grouped_variant(base_name: str, group_cols: int) -> FormatSpec:
    """Derive the per-group-scale variant of a registered code format.

    Codes, planes, pack/unpack are IDENTICAL to the base format (scales are a
    separate [K//G, M] plane, not woven into the byte stream); only the
    training-side quantize rule (per-group absmean) and the K alignment
    (lcm of the base alignment and G, so every group is complete) change.
    bpw accounts for the fp32 scale row amortized over its G columns.
    """
    base = get(base_name)
    if base.quantize is None or not base.planes:
        raise ValueError(f"format {base_name!r} has no quantize/pack path")
    if base.elut and group_cols % base.weights_per_byte != 0:
        # Pallas kernels split the K reduction at group boundaries in BYTE
        # units; a group must cover whole packed bytes.
        raise ValueError(
            f"group_scale_cols={group_cols} must be a multiple of "
            f"{base.weights_per_byte} (weights/byte) for {base_name!r}")
    lo, hi = base.levels
    return FormatSpec(
        name=f"{base_name}_g{group_cols}",
        bpw=base.bpw + 32.0 / group_cols,
        base=base.base, group=base.group, field_bits=base.field_bits,
        k_align=_lcm(base.k_align, group_cols),
        planes=base.planes,
        pack=base.pack, unpack=base.unpack,
        quantize=partial(quant.absmean_lowbit_grouped,
                         lo=lo, hi=hi, group_cols=group_cols),
        elut=base.elut, pallas=base.pallas,
        group_scale_cols=group_cols,
    )


# fp — bf16 baseline (paper's Float16 baseline); packing handled by qtensor.
# No integer semantics → exempt from the atol=0 conformance contract.
register(FormatSpec(name="fp", bpw=16.0, planes=("w",), lossless=False))

# int4 — XLA-native sub-byte dtype storage of the ternary codes (the TPU dot
# consumes int4 directly; no code plane, no unpack intermediate).
register(FormatSpec(
    name="int4", bpw=4.0, planes=("w4",),
    pack=lambda w: {"w4": w.astype(jnp.int4)},
    unpack=lambda planes, k: planes["w4"].astype(jnp.int8),
    quantize=quant.ternary_quant,
))

# Ternary ELUT instances of the parametric packer (paper I2_S / TL1 / TQ1).
register(_elut_spec("i2s", 3, 1, 2))                       # 2.00 bpw
register(_elut_spec("tl1", 3, 2, 4))                       # 2.00 bpw
# tq1 — 5 trits/byte (1.6 bpw), K padded to a 5-multiple (idealized TQ1_0).
# Same parametric packer at (3, 5); C = 243 makes LUT kernels pointless, so
# it stays a MAD-only baseline (elut=False keeps it off the LUT registry).
register(_elut_spec("tq1", 3, 5, 8, k_align=1, pad=True,
                    pallas=False, elut=False))

# Non-ternary ELUT formats through the SAME code path (paper Appendix ELUT):
# int2 = (b=4, g=2): levels {-2..1}, 16-entry LUT, 2.00 bpw;
# int3 = (b=8, g=2): levels {-4..3}, 64-entry LUT, 4.00 bpw (byte code field).
register(_elut_spec("int2", 4, 2, 4))
register(_elut_spec("int3", 8, 2, 8))

# Grouped-scale variants (GPTQ/AWQ-style 128-column groups along K) of every
# plain code-plane format — same packed bytes, per-group absmean quantize,
# scale plane [K//128, M].  tq1's groups need not align to its 5-weight bytes
# (it is MAD/XLA-only: scales apply on the unpacked logical columns).
GROUP_SCALE_COLS = 128
for _base in ("i2s", "tl1", "tq1", "int2", "int3"):
    register(grouped_variant(_base, GROUP_SCALE_COLS))

# TL2 — mirror-consolidated sign+index planes (base 3, folded 14-entry table)
# with block-fitting split-K; the TwoK tail is packed tl1.
register(FormatSpec(
    name="tl2", bpw=5.0 / 3.0, base=3, group=3, field_bits=4, k_align=4,
    planes=("idx", "sign", "tail"),
    pack=_tl2_pack, unpack=_tl2_unpack, quantize=quant.ternary_quant,
    split_k=packing.tl2_split_k, lut_entries=14,
))

# TL2 in the Pallas kernel layout (tile-permuted planes, same 1.67 bpw).
register(FormatSpec(
    name="tl2k", bpw=5.0 / 3.0, base=3, group=3, field_bits=4, k_align=4,
    planes=("idx", "sign", "tail"),
    pack=_tl2k_pack, unpack=_tl2k_unpack, quantize=quant.ternary_quant,
    split_k=packing.tl2k_split_k, pallas=True, lut_entries=14,
))
