"""Format registry: every weight packing format as one ``FormatSpec``.

This is the seam the ELUT engine (paper Appendix, "element-wise lookup
table for general low-bit LLMs") hangs off: a format is no longer a branch
in an if-chain but a registry entry carrying

  * ``pack`` / ``unpack`` callables (plane dict <-> int8 code matrix),
  * ``quantize`` (fp master weight -> (codes, scale), the training-side rule),
  * bpw, element base ``b`` (alphabet size), group size ``g`` (elements per
    LUT code), packed field width in bits, plane layout,
  * K-divisibility (``k_align``) and the block-fitting split-K rule,
  * capability flags: ``elut`` (plain code-plane layout -> the parametric
    ELUT kernels apply) and ``pallas`` (some fused Pallas kernel exists).

The ternary formats (i2s, tl1, tq1) are instances of the parametric base-b
packer with (b, g) = (3, 1), (3, 2), (3, 5); the non-ternary int2/int3
formats are (4, 2) and (8, 2) through the *same* code path.  tl2/tl2k keep
their mirror-consolidated sign+index planes (base 3 with a folded table) —
the tl2k kernel now lives inside the parametric Pallas family
(``kernels.elut_matmul``), sharing its digit decoder; fp/int4 are
native-dtype formats with no code plane.

Derived variants compose through builder functions (DESIGN.md §11):

  * ``grouped_variant`` (``_g128``): per-group weight scales as a separate
    [K//G, M] fp32 plane;
  * ``bc_variant`` (``_bc``): bit-contiguous code fields — int3's 6-bit
    codes at a true 3.0 bpw instead of byte fields' 4.0;
  * ``occupancy_variant`` (``_z``): a per-block zero-occupancy plane the
    Pallas kernels consult to skip all-zero K-blocks.

New bit-widths are new ``register(...)`` calls, not new kernel files.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable

import jax.numpy as jnp

from repro.core import packing, quant


@dataclasses.dataclass(frozen=True)
class FormatSpec:
    """One weight format (DESIGN.md §2 is the normative format table; §11
    holds the sparsity / sub-byte layout arguments; the byte-level layout
    invariants live on the :mod:`repro.core.packing` pack functions).

    Field contract (what the conformance harness enforces per format):

    ``name``
        Registry key.  Derived variants append a suffix: ``_g{G}`` grouped
        scales, ``_bc`` bit-contiguous codes, ``_z`` occupancy metadata.
    ``bpw``
        Packed bits per weight in HBM, INCLUDING any metadata planes
        amortized over their columns (grouped scales add 32/G, the
        occupancy plane 8/occ_block) — this is the number the dispatch
        cost hints and the roofline accounting consume.
    ``base`` / ``group`` / ``field_bits`` / ``code_bits``
        Code geometry: alphabet size b, g weights per code, and the packed
        width of one code — ``field_bits`` for power-of-two byte-aligned
        fields (``elut_pack``), ``code_bits`` nonzero for the
        bit-contiguous stream (``elut_pack_bc``; field_bits then records
        the logical code width too but the stream is packed back to back).
        Exactly the data a parametric kernel needs to decode.
    ``k_align``
        Required K divisibility: packing must produce whole bytes, whole
        units, whole scale groups, and whole occupancy blocks.
    ``planes``
        Plane-dict layout (names, fixed order).  ``pack(w_q) ->
        dict[str, Array]`` and ``unpack(planes, k) -> int8 [M, K]`` are
        exact inverses on matrices of valid codes (levels in [lo, hi]) —
        the bijection the conformance harness round-trips.  ``unpack``
        ignores derived metadata planes ("occ").
    ``quantize``
        Training-side rule ``w_fp -> (w_q, scale)`` producing valid codes
        (None for the fp passthrough).
    ``split_k``
        ``K -> (main_k, tail_k)`` block-fitting rule (split-K formats).
    ``elut`` / ``pallas``
        Capability flags: plain code-plane layout (parametric ELUT kernels
        apply) / some fused Pallas kernel path exists.
    ``lut_entries``
        Table-size override (tl2's mirror-folded 14; 0 → b^g).
    ``group_scale_cols``
        Per-group weight scales: one fp32 scale per G K-columns per output
        row (scale plane [K//G, M], packing module docstring).  None =
        per-tensor scalar scale (the b1.58 default) — the two paths must
        stay bit-identical at None (tests/test_regression_golden.py).
    ``occ_block``
        Zero-occupancy metadata granularity in K-columns (0 = no
        occupancy plane).  Nonzero adds an "occ" uint8 plane
        [M, K/occ_block] (``packing.occupancy_map``) whose 0 entries
        kernels may skip — bit-identically, since a zero block contributes
        exactly 0 (DESIGN.md §11 holds the skip-is-exact argument).
    ``lossless``
        Integer accumulation reproduces the quantized reference
        computation EXACTLY (conformance harness gates atol=0).  False
        only for the fp passthrough baseline (no integer semantics).
    """

    name: str
    bpw: float                      # packed bits per weight in HBM
    base: int = 0                   # element alphabet size b (0: native dtype)
    group: int = 0                  # g elements per LUT code (0: not code-based)
    field_bits: int = 0             # packed bits per code field (nibble=4, ...)
    k_align: int = 1                # required K divisibility for packing
    planes: tuple = ()              # plane-dict layout (names)
    pack: Callable | None = None
    unpack: Callable | None = None
    quantize: Callable | None = None
    split_k: Callable | None = None  # K -> (main_k, tail_k) block-fitting rule
    elut: bool = False              # parametric ELUT kernels apply
    pallas: bool = False            # a fused Pallas kernel path exists
    lut_entries: int = 0            # table-size override (tl2's folded 14)
    group_scale_cols: int | None = None  # G columns per weight-scale group
    code_bits: int = 0              # nonzero: bit-contiguous code stream width
    occ_block: int = 0              # nonzero: occupancy-plane block columns
    lossless: bool = True

    # -- derived quantities (the napkin math the cost hints are built from) --

    @property
    def lut_size(self) -> int:
        """C: entries in the element-wise lookup table (b^g, or the folded
        count for mirror-consolidated formats)."""
        if self.lut_entries:
            return self.lut_entries
        return self.base ** self.group if self.group else 0

    @property
    def offset(self) -> int:
        """Weight value = digit - offset; symmetric-ish levels around 0."""
        return self.base // 2

    @property
    def levels(self) -> tuple:
        """(lo, hi) valid weight values. b=3 -> (-1, 1); b=4 -> (-2, 1)."""
        return (-self.offset, self.base - 1 - self.offset)

    @property
    def weights_per_byte(self) -> int:
        return self.group * (8 // self.field_bits) if self.field_bits else 0

    @property
    def unit_bytes(self) -> int:
        """Bytes per decode unit: 1 for byte-aligned fields,
        lcm(code_bits, 8)/8 for the bit-contiguous stream (int3_bc: 3)."""
        if self.code_bits:
            return packing.bc_unit(self.code_bits)[0]
        return 1

    @property
    def codes_per_unit(self) -> int:
        """Whole codes per decode unit (the kernels' static decode fan-out):
        8/field_bits for byte-aligned fields, unit_bytes·8/code_bits for the
        bit-contiguous stream (int3_bc: 4)."""
        if self.code_bits:
            return packing.bc_unit(self.code_bits)[1]
        return 8 // self.field_bits if self.field_bits else 0

    @property
    def weights_per_unit(self) -> int:
        """K-columns per decode unit — the packing alignment quantum
        (== weights_per_byte for byte-aligned formats; int3_bc: 8)."""
        return self.codes_per_unit * self.group

    @property
    def mxu_inflation(self) -> float:
        """True-LUT one-hot contraction MXU work vs the plain MAD dot:
        C MACs per group of g weights -> C/g = b^g/g (tl1: 4.5x)."""
        return self.lut_size / self.group if self.group else 1.0

    @property
    def lut_hbm_bpw(self) -> float:
        """HBM bits/weight of the XLA one-hot path: the int8 one-hot operand
        [M, G, C] materializes -> C bytes per g weights (tl1: 36.0)."""
        return 8.0 * self.lut_size / self.group if self.group else 8.0

    def supports_lut_gemv(self) -> bool:
        """True-LUT GEMV pays off only for grouped codes (g >= 2): at g == 1
        the 'table' is the weight itself and LUT build is pure overhead."""
        return self.elut and self.group >= 2

    # -- TP shard geometry (DESIGN.md §12) ----------------------------------

    @property
    def k_shardable(self) -> bool:
        """Row-parallel (K) sharding is a pure byte-range slice of the packed
        planes.  False for split-K formats: the ThreeK-prefix/TwoK-tail
        structure is a function of the FULL K, so a K slice of the planes is
        not the packing of the K slice of the weights."""
        return self.split_k is None

    @property
    def shard_k_quantum(self) -> int:
        """Smallest K granule a row-parallel shard boundary may fall on: every
        shard must hold whole decode units (so the packed-byte stream slices
        at a byte boundary), whole scale groups (so group scales never
        straddle the psum — the accumulator-granularity argument), and whole
        occupancy blocks (so the ``occ`` bitmap slices with its codes).
        Usually equal to ``k_align``; tq1's zero-padded packing loosens
        k_align to 1 while its 5-weight bytes still pin the shard quantum."""
        q = max(self.k_align, 1)
        if self.weights_per_unit:
            q = _lcm(q, self.weights_per_unit)
        if self.group_scale_cols:
            q = _lcm(q, self.group_scale_cols)
        if self.occ_block:
            q = _lcm(q, self.occ_block)
        return q


REGISTRY: dict[str, FormatSpec] = {}


def register(spec: FormatSpec) -> FormatSpec:
    if spec.name in REGISTRY:
        raise ValueError(f"format {spec.name!r} already registered")
    REGISTRY[spec.name] = spec
    return spec


def get(name: str) -> FormatSpec:
    spec = REGISTRY.get(name)
    if spec is None:
        raise ValueError(f"unknown format {name!r}; registered: {sorted(REGISTRY)}")
    return spec


def names() -> tuple:
    return tuple(REGISTRY)


def bpw(name: str) -> float:
    return get(name).bpw


def elut_formats() -> tuple:
    return tuple(f for f, s in REGISTRY.items() if s.elut)


def pallas_formats() -> tuple:
    return tuple(f for f, s in REGISTRY.items() if s.pallas)


def lut_gemv_formats() -> tuple:
    return tuple(f for f, s in REGISTRY.items() if s.supports_lut_gemv())


def grouped_formats() -> tuple:
    """Formats carrying per-group weight scales (group_scale_cols set)."""
    return tuple(f for f, s in REGISTRY.items() if s.group_scale_cols)


def occupancy_formats() -> tuple:
    """Formats carrying a zero-occupancy metadata plane (occ_block set)."""
    return tuple(f for f, s in REGISTRY.items() if s.occ_block)


class _BpwView:
    """Dict-like live view of per-format bpw (back-compat for FORMAT_BPW)."""

    def __getitem__(self, name: str) -> float:
        return get(name).bpw

    def __contains__(self, name: str) -> bool:
        return name in REGISTRY

    def __iter__(self):
        return iter(REGISTRY)

    def keys(self):
        return REGISTRY.keys()

    def items(self):
        return tuple((f, s.bpw) for f, s in REGISTRY.items())


FORMAT_BPW = _BpwView()


# ---------------------------------------------------------------------------
# Built-in registrations
# ---------------------------------------------------------------------------

def _elut_spec(name: str, b: int, g: int, field_bits: int, *,
               k_align: int | None = None, pad: bool = False,
               pallas: bool = True, elut: bool = True) -> FormatSpec:
    """A format whose planes are one packed code plane from the parametric
    base-b packer — the plain ELUT layout."""
    wpb = g * (8 // field_bits)
    return FormatSpec(
        name=name,
        bpw=8.0 / wpb,  # pad=True amortizes to the same ratio for large K
        base=b, group=g, field_bits=field_bits,
        k_align=wpb if k_align is None else k_align,
        planes=("p",),
        pack=lambda w: {"p": packing.elut_pack(w, b, g, field_bits, pad=pad)},
        unpack=lambda planes, k: packing.elut_unpack(
            planes["p"], k, b, g, field_bits),
        quantize=partial(quant.absmean_lowbit, lo=-(b // 2), hi=b - 1 - b // 2),
        elut=elut, pallas=pallas,
    )


def _splitk_fns(pack3, unpack3, split_k):
    """(pack, unpack) pair for a split-K sign+index format: the ThreeK
    prefix uses the mirror-consolidated planes, the TwoK tail packs tl1
    (block-fitting weight splitting, paper §3.1.2)."""

    def pack(w):
        three_k, two_k = split_k(w.shape[1])
        planes = {}
        if three_k:
            idx_plane, sign_plane = pack3(w[:, :three_k])
            planes["idx"] = idx_plane
            planes["sign"] = sign_plane
        if two_k:
            planes["tail"] = packing.tl1_pack(w[:, three_k:])
        return planes

    def unpack(planes, k):
        three_k, _ = split_k(k)
        parts = []
        if three_k:
            parts.append(unpack3(planes["idx"], planes["sign"], three_k))
        if three_k < k:
            parts.append(packing.tl1_unpack(planes["tail"], k - three_k))
        return jnp.concatenate(parts, axis=1) if len(parts) > 1 else parts[0]

    return pack, unpack


_tl2_pack, _tl2_unpack = _splitk_fns(
    packing.tl2_pack, packing.tl2_unpack, packing.tl2_split_k)
_tl2k_pack, _tl2k_unpack = _splitk_fns(
    packing.tl2k_pack, packing.tl2k_unpack, packing.tl2k_split_k)


def _lcm(a: int, b: int) -> int:
    import math

    return a * b // math.gcd(a, b)


def grouped_variant(base_name: str, group_cols: int) -> FormatSpec:
    """Derive the per-group-scale variant of a registered code format.

    Codes, planes, pack/unpack are IDENTICAL to the base format (scales are a
    separate [K//G, M] plane, not woven into the byte stream); only the
    training-side quantize rule (per-group absmean) and the K alignment
    (lcm of the base alignment and G, so every group is complete) change.
    bpw accounts for the fp32 scale row amortized over its G columns.
    """
    base = get(base_name)
    if base.quantize is None or not base.planes:
        raise ValueError(f"format {base_name!r} has no quantize/pack path")
    if base.elut and group_cols % base.weights_per_unit != 0:
        # Pallas kernels split the K reduction at group boundaries in whole
        # decode units; a group must cover whole packed bytes/units.
        raise ValueError(
            f"group_scale_cols={group_cols} must be a multiple of "
            f"{base.weights_per_unit} (weights/unit) for {base_name!r}")
    lo, hi = base.levels
    return FormatSpec(
        name=f"{base_name}_g{group_cols}",
        bpw=base.bpw + 32.0 / group_cols,
        base=base.base, group=base.group, field_bits=base.field_bits,
        k_align=_lcm(base.k_align, group_cols),
        planes=base.planes,
        pack=base.pack, unpack=base.unpack,
        quantize=partial(quant.absmean_lowbit_grouped,
                         lo=lo, hi=hi, group_cols=group_cols),
        elut=base.elut, pallas=base.pallas,
        code_bits=base.code_bits,
        group_scale_cols=group_cols,
    )


def bc_variant(base_name: str) -> FormatSpec:
    """Derive the bit-contiguous code-field variant of a plain ELUT format.

    Code VALUES are identical to the base format (same digits, same
    big-endian code construction, same quantize rule); only the byte layout
    changes — codes of minimal width ceil(log2 b^g) laid back to back
    (``packing.elut_pack_bc``) instead of power-of-two byte fields.  int3's
    6-bit codes drop from 4.0 to a true 3.0 bpw.  Raises for formats whose
    codes already fill their fields (nothing to reclaim).
    """
    base = get(base_name)
    if not base.elut or base.pack is None:
        raise ValueError(f"format {base_name!r} is not a plain ELUT format")
    if base.group_scale_cols or base.occ_block:
        raise ValueError("derive _bc from the base format, then compose")
    cb = (base.base ** base.group - 1).bit_length()
    if cb == base.field_bits:
        raise ValueError(
            f"{base_name!r} codes already fill their {cb}-bit fields")
    b, g = base.base, base.group
    ub, cpu = packing.bc_unit(cb)
    wpu = cpu * g
    return FormatSpec(
        name=f"{base_name}_bc",
        bpw=8.0 * ub / wpu,
        base=b, group=g, field_bits=base.field_bits, code_bits=cb,
        k_align=_lcm(base.k_align, wpu),
        planes=("p",),
        pack=lambda w: {"p": packing.elut_pack_bc(w, b, g, cb)},
        unpack=lambda planes, k: packing.elut_unpack_bc(
            planes["p"], k, b, g, cb),
        quantize=base.quantize,
        elut=True, pallas=True,
    )


def occupancy_variant(base_name: str, occ_block: int) -> FormatSpec:
    """Derive the zero-occupancy (``_z``) variant of a plain ELUT format.

    The code planes are IDENTICAL to the base format; one extra "occ" uint8
    plane [M, K/occ_block] (``packing.occupancy_map``) marks which K-blocks
    of each output row hold any nonzero weight.  The Pallas kernels consult
    it to skip all-zero blocks in the K walk — exactly (DESIGN.md §11); the
    XLA kernels ignore it.  bpw accounts the plane at 8/occ_block.

    ``occ_block`` must cover whole decode units (kernels skip in unit-sized
    byte slices) and K must divide into whole blocks (k_align).
    """
    base = get(base_name)
    if not base.elut or base.pack is None:
        raise ValueError(f"format {base_name!r} is not a plain ELUT format")
    if base.group_scale_cols:
        raise ValueError(
            "occupancy composes with per-tensor scales only (the grouped "
            "kernels' scale-group walk does not skip yet)")
    if occ_block % base.weights_per_unit != 0:
        raise ValueError(
            f"occ_block={occ_block} must be a multiple of "
            f"{base.weights_per_unit} (weights/unit) for {base_name!r}")
    base_pack = base.pack

    def pack(w, _bp=base_pack, _ob=occ_block):
        planes = dict(_bp(w))
        planes["occ"] = packing.occupancy_map(w, _ob)
        return planes

    return FormatSpec(
        name=f"{base_name}_z",
        bpw=base.bpw + 8.0 / occ_block,
        base=base.base, group=base.group, field_bits=base.field_bits,
        code_bits=base.code_bits,
        k_align=_lcm(base.k_align, occ_block),
        planes=base.planes + ("occ",),
        pack=pack, unpack=base.unpack,
        quantize=base.quantize,
        elut=True, pallas=True,
        occ_block=occ_block,
    )


# fp — bf16 baseline (paper's Float16 baseline); packing handled by qtensor.
# No integer semantics → exempt from the atol=0 conformance contract.
register(FormatSpec(name="fp", bpw=16.0, planes=("w",), lossless=False))

# int4 — XLA-native sub-byte dtype storage of the ternary codes (the TPU dot
# consumes int4 directly; no code plane, no unpack intermediate).
register(FormatSpec(
    name="int4", bpw=4.0, planes=("w4",),
    pack=lambda w: {"w4": w.astype(jnp.int4)},
    unpack=lambda planes, k: planes["w4"].astype(jnp.int8),
    quantize=quant.ternary_quant,
))

# Ternary ELUT instances of the parametric packer (paper I2_S / TL1 / TQ1).
register(_elut_spec("i2s", 3, 1, 2))                       # 2.00 bpw
register(_elut_spec("tl1", 3, 2, 4))                       # 2.00 bpw
# tq1 — 5 trits/byte (1.6 bpw), K padded to a 5-multiple (idealized TQ1_0).
# Same parametric packer at (3, 5); C = 243 makes LUT kernels pointless, so
# it stays a MAD-only baseline (elut=False keeps it off the LUT registry).
register(_elut_spec("tq1", 3, 5, 8, k_align=1, pad=True,
                    pallas=False, elut=False))

# Non-ternary ELUT formats through the SAME code path (paper Appendix ELUT):
# int2 = (b=4, g=2): levels {-2..1}, 16-entry LUT, 2.00 bpw;
# int3 = (b=8, g=2): levels {-4..3}, 64-entry LUT, 4.00 bpw (byte code field).
register(_elut_spec("int2", 4, 2, 4))
register(_elut_spec("int3", 8, 2, 8))

# Grouped-scale variants (GPTQ/AWQ-style 128-column groups along K) of every
# plain code-plane format — same packed bytes, per-group absmean quantize,
# scale plane [K//128, M].  tq1's groups need not align to its 5-weight bytes
# (it is MAD/XLA-only: scales apply on the unpacked logical columns).
GROUP_SCALE_COLS = 128
for _base in ("i2s", "tl1", "tq1", "int2", "int3"):
    register(grouped_variant(_base, GROUP_SCALE_COLS))

# TL2 — mirror-consolidated sign+index planes (base 3, folded 14-entry table)
# with block-fitting split-K; the TwoK tail is packed tl1.
register(FormatSpec(
    name="tl2", bpw=5.0 / 3.0, base=3, group=3, field_bits=4, k_align=4,
    planes=("idx", "sign", "tail"),
    pack=_tl2_pack, unpack=_tl2_unpack, quantize=quant.ternary_quant,
    split_k=packing.tl2_split_k, lut_entries=14,
))

# TL2 in the Pallas kernel layout (tile-permuted planes, same 1.67 bpw).
register(FormatSpec(
    name="tl2k", bpw=5.0 / 3.0, base=3, group=3, field_bits=4, k_align=4,
    planes=("idx", "sign", "tail"),
    pack=_tl2k_pack, unpack=_tl2k_unpack, quantize=quant.ternary_quant,
    split_k=packing.tl2k_split_k, pallas=True, lut_entries=14,
))

# Bit-contiguous code fields (DESIGN.md §11): int3's 6-bit codes at a true
# 3.0 bpw (3-byte/4-code decode units) instead of 4.0 in byte fields.  The
# other ELUT formats already fill power-of-two fields exactly, so int3 is
# the only registration that gains.
register(bc_variant("int3"))

# Zero-occupancy (_z) variants: per-block nonzero metadata the Pallas
# kernels consult to skip all-zero K-blocks — TENET-style sparsity riding
# the zero-heavy ternary weight distribution.  64-column blocks cost
# 8/64 = 0.125 bpw; int3_bc_z lands at 3.125 bpw incl. metadata.
OCC_BLOCK_COLS = 64
register(occupancy_variant("tl1", OCC_BLOCK_COLS))
register(occupancy_variant("int3_bc", OCC_BLOCK_COLS))
