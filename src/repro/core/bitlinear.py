"""BitLinear: the paper's technique as a composable JAX module.

A BitLinear is a drop-in linear layer with three operating modes:

  * ``fp``    — plain high-precision matmul (the Float16 baseline).
  * ``qat``   — BitNet b1.58 training forward: STE fake-quant of weights
                (per-tensor absmean ternary) and activations (per-tensor
                absmax int8), matmul in fp.  This is the scheme inference
                must match bit-for-bit to be "lossless" (paper §2.1).
  * ``quant`` — integer inference: the weight is a PackedWeight in any
                registered format (i2s / tl1 / tl2 / tq1 / int4 / int2 /
                int3, or a grouped-scale ``*_g128`` variant whose
                [K//G, M] scale plane rides the pytree beside the codes),
                activations are quantized per the config, and the
                contraction runs through ``repro.core.dispatch.mpgemm``.

Packing is generic over any parameter pytree: ``pack_tree`` rewrites every
``BitLinearParams`` leaf in place, so whole models (dense / MoE / SSM /
enc-dec) quantize with one call.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import dispatch, mpgemm, quant
from repro.core.dispatch import KernelPlan
from repro.core.qtensor import PackedWeight, pack_weight


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """How BitLinears behave; threaded through model configs.

    Kernel selection is carried by ``plan`` (a hashable
    :class:`repro.core.dispatch.KernelPlan`); the default auto-plan picks
    per regime (decode GEMV vs batched GEMM) via the registry.  ``fmt``
    names any format registered in :mod:`repro.core.formats`.
    """

    mode: str = "quant"        # fp | qat | quant
    fmt: str = "i2s"           # weight packing format for quantized inference
    plan: KernelPlan = KernelPlan()  # shape-aware dispatch policy
    act: str = "tensor"        # tensor | token | block   (activation quant)
    act_block: int = 256
    # FSDP: constrain the weight *slice* inside the layer scan to TP-only so
    # the data-axis all-gather happens per layer (loop-local), instead of
    # GSPMD hoisting one giant gather of the whole stacked parameter array
    # out of the loop (which would materialize every layer's weights at once).
    w_gather: str = ""         # "" | "tp"


FP32 = jnp.float32


@partial(jax.tree_util.register_dataclass, data_fields=["w", "b"], meta_fields=[])
@dataclasses.dataclass
class BitLinearParams:
    """w: fp master weight [out, in] (train) or PackedWeight (inference)."""

    w: Any
    b: Any = None


def init(key: jax.Array, d_in: int, d_out: int, *, bias: bool = False,
         dtype=jnp.float32) -> BitLinearParams:
    scale = 1.0 / (d_in ** 0.5)
    w = jax.random.normal(key, (d_out, d_in), dtype) * scale
    b = jnp.zeros((d_out,), dtype) if bias else None
    return BitLinearParams(w=w, b=b)


def _gather_tp(w: jax.Array) -> jax.Array:
    """Constrain a weight (slice) to TP-only sharding: out-features on model,
    everything else replicated — forces the FSDP gather to be loop-local."""
    spec = jax.sharding.PartitionSpec("model", *([None] * (w.ndim - 1)))
    return jax.lax.with_sharding_constraint(w, spec)


def apply(p: BitLinearParams, x: jax.Array, cfg: QuantConfig) -> jax.Array:
    """x: [..., d_in] -> [..., d_out], output in x.dtype."""
    out_dtype = x.dtype
    if isinstance(p.w, PackedWeight):
        y = _apply_quantized(p.w, x, cfg)
    else:
        w = _gather_tp(p.w) if cfg.w_gather == "tp" else p.w
        if cfg.mode == "qat":
            w = quant.ternary_fake_quant(w)
            x = quant.act_fake_quant(x)
        elif cfg.mode == "qat_acts":
            # weights were fake-quantized ONCE per step (hoisted out of the
            # microbatch loop — see train.loop.prequantize_weights)
            x = quant.act_fake_quant(x)
        # mixed precision: matmul AND result in the activation dtype (bf16 at
        # scale).  The MXU still accumulates f32 internally; emitting bf16
        # keeps every backward cotangent bf16 — measured 8 GB/device of f32
        # stacked-weight cotangent carriers otherwise (deepseek-33b train).
        y = jax.lax.dot_general(
            x, w.astype(x.dtype).T,
            (((x.ndim - 1,), (0,)), ((), ())),
            preferred_element_type=x.dtype,
        )
    if p.b is not None:
        y = y + p.b.astype(FP32)
    return y.astype(out_dtype)


def _apply_quantized(pw: PackedWeight, x: jax.Array, cfg: QuantConfig) -> jax.Array:
    if pw.fmt == "fp":
        return x.astype(FP32) @ pw.planes["w"].T.astype(FP32)
    if cfg.act == "block":
        x_q, s_b = quant.q8_block(x, cfg.act_block)
        return mpgemm.mpgemm_q8_block(x_q, s_b, pw, cfg.act_block)
    if cfg.act == "token":
        x_q, s_x = quant.absmax_int8_per_token(x)
    else:  # "tensor" — the lossless b1.58 scheme
        x_q, s_x = quant.absmax_int8(x)
    return dispatch.mpgemm(x_q, s_x, pw, cfg.plan)


def is_bitlinear(x: Any) -> bool:
    return isinstance(x, BitLinearParams)


def prequantize_weights(params: Any) -> Any:
    """STE fake-quant of every BitLinear master weight, once.

    Perf iteration l4-2 / ds-5 (EXPERIMENTS §Perf): inside the train step the
    master weights are constant across microbatches, yet tracing fake-quant
    inside the loss made XLA recompute (and reshard, in f32) the whole
    stacked-weight quantization chain EVERY microbatch — measured 3.3
    TB/device/step of f32 weight gathers on llama4 train_4k.  Hoisting it
    here runs it once; gradients still flow to the masters through the STE.
    Per-matrix absmean scales are preserved via vmap over stack dims.
    """

    def _fq_nd(w: jax.Array) -> jax.Array:
        if w.ndim == 2:
            return quant.ternary_fake_quant(w)
        return jax.vmap(_fq_nd)(w)

    def _pre(p: Any) -> Any:
        if not is_bitlinear(p) or isinstance(p.w, PackedWeight):
            return p
        return BitLinearParams(w=_fq_nd(p.w), b=p.b)

    return jax.tree_util.tree_map(_pre, params, is_leaf=is_bitlinear)


def pack_tree(params: Any, cfg: QuantConfig) -> Any:
    """Rewrite every BitLinearParams leaf: fp master weight -> PackedWeight.

    Weights may carry leading stack dims (pattern-scan repeats, MoE experts:
    [n_rep, E, M, K]) — packing is vmapped over them, giving per-matrix
    absmean scales (the per-tensor granularity of the b1.58 scheme), or
    per-matrix [K//G, M] scale planes for grouped formats.
    """

    def _pack_nd(w: jax.Array):
        if w.ndim == 2:
            return pack_weight(w, cfg.fmt)
        return jax.vmap(_pack_nd)(w)

    def _pack(p: Any) -> Any:
        if not is_bitlinear(p) or isinstance(p.w, PackedWeight):
            return p
        return BitLinearParams(w=_pack_nd(p.w), b=p.b)

    return jax.tree_util.tree_map(_pack, params, is_leaf=is_bitlinear)


def packed_bits(params: Any) -> int:
    """Total packed weight bits across a tree (roofline byte accounting)."""
    total = 0

    def _visit(p: Any) -> Any:
        nonlocal total
        if is_bitlinear(p) and isinstance(p.w, PackedWeight):
            total += p.w.bits()
        return p

    jax.tree_util.tree_map(_visit, params, is_leaf=is_bitlinear)
    return total
