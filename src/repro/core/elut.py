"""Parametric element-wise-LUT mpGEMM, pure XLA (paper Appendix ELUT).

The paper's appendix generalizes TL (base-3 lookup) to ELUT: for any element
base ``b`` and group size ``g``, precompute per activation group the
``C = b^g``-entry table of all possible group dot products (Phase 1), then
accumulate ``Σ_g LUT[g, code[m, g]]`` over the packed weight codes
(Phase 2).  Ternary ``(3, 2)`` is exactly TL1 / Algorithm 3; ``(4, 2)`` and
``(8, 2)`` are the int2/int3 instances that come up through the same code
path.

TPU adaptation (DESIGN.md §2): the lookup is a one-hot contraction on the
MXU — for code value c, ``(codes == c)`` forms a 0/1 int8 mask that
multiplies LUT column c.  Losslessness (paper §3.2.1) is parametric too:

  * ``lossless=True``  (the ``_1`` variants): int32 tables, exact
    accumulation — the int16 pack-and-unpack technique expressed at its
    natural XLA precision (the fused Pallas kernel in
    ``repro.kernels.elut_matmul`` does the literal two-byte split).
  * ``lossless=False`` (the ``_0`` variants): the table is requantized to
    int8 with a per-tensor scale (T-MAC scheme) before accumulation.

The mirror-consolidated TL2 path (folded 14-entry table + sign plane) stays
in ``repro.core.mpgemm``; it is the one format whose table is not the plain
``b^g`` enumeration.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import formats, packing
from repro.core.qtensor import PackedWeight

build_lut = packing.elut_build_lut


def quantize_lut(lut: jax.Array) -> tuple[jax.Array, jax.Array]:
    """T-MAC-style int8 LUT requantization (per-tensor scale) — the lossy step."""
    s = jnp.maximum(jnp.max(jnp.abs(lut)).astype(jnp.float32), 1.0) / 127.0
    q = jnp.clip(jnp.round(lut.astype(jnp.float32) / s), -127, 127).astype(jnp.int32)
    return q, s


def lut_accumulate(lut: jax.Array, codes: jax.Array,
                   lossless: bool) -> tuple[jax.Array, jax.Array]:
    """sum_g LUT[..., g, codes[m, g]] -> ([..., M] int32, lut scale).

    Gather formulated as a small one-hot contraction — the MXU-friendly
    expression of "table lookup" (DESIGN.md §2): onehot [M, G, C] × lut.
    """
    if not lossless:
        lut, s_lut = quantize_lut(lut)
    else:
        s_lut = jnp.float32(1.0)
    onehot = jax.nn.one_hot(codes, lut.shape[-1], dtype=jnp.int8)  # [M, G, C]
    y32 = jnp.einsum(
        "...gc,mgc->...m", lut.astype(jnp.int32), onehot.astype(jnp.int32)
    )
    return y32, s_lut


def lut_accumulate_grouped(lut: jax.Array, codes: jax.Array,
                           scale: jax.Array, lossless: bool) -> jax.Array:
    """Per-group-scale variant of :func:`lut_accumulate` (DESIGN.md §2).

    The code-group axis is split at scale-group boundaries ([..., S, r, C]
    segments, r = G/g codes per scale group); each segment's one-hot
    contraction is an exact int32 partial that its fp32 scale ``scale[s, m]``
    multiplies at accumulator granularity.  Returns fp32 [..., M] with the
    weight scales (and the lossy table scale, if any) applied.
    """
    s_groups, m = scale.shape
    if not lossless:
        lut, s_lut = quantize_lut(lut)
    else:
        s_lut = jnp.float32(1.0)
    kg, c = lut.shape[-2:]
    r = kg // s_groups
    onehot = jax.nn.one_hot(codes, c, dtype=jnp.int8)  # [M, Kg, C]
    p32 = jnp.einsum(
        "...src,msrc->...sm",
        lut.reshape(*lut.shape[:-2], s_groups, r, c).astype(jnp.int32),
        onehot.reshape(m, s_groups, r, c).astype(jnp.int32),
    )
    return (p32.astype(jnp.float32) * scale).sum(axis=-2) * s_lut


def elut_mpgemm(x_q: jax.Array, s_x, pw: PackedWeight,
                lossless: bool = True) -> jax.Array:
    """mpGEMM via the parametric element-wise LUT.  fp32 [..., M].

    Works for every registered format with a plain code plane
    (``spec.elut``): tl1 reproduces ``tl1_lut`` bit-exactly; int2/int3 run
    the identical algorithm at (4, 2) / (8, 2); grouped-scale variants
    apply the [K//G, M] scale plane via the segment-sum reshape.
    """
    spec = formats.get(pw.fmt)
    if not spec.elut:
        raise ValueError(
            f"elut_mpgemm needs an ELUT code-plane format, got {pw.fmt!r} "
            f"(elut formats: {formats.elut_formats()})")
    lut = build_lut(x_q, spec.base, spec.group)        # [..., G, C] int32
    if spec.code_bits:
        codes = packing.elut_codes_bc(pw.planes["p"], spec.code_bits)
    else:
        codes = packing.elut_codes(pw.planes["p"], spec.field_bits)
    codes = codes[:, : pw.k // spec.group]             # drop pad-group columns
    if spec.group_scale_cols:
        y = lut_accumulate_grouped(lut, codes.astype(jnp.int32),
                                   pw.scale, lossless)
        return y * jnp.asarray(s_x, jnp.float32)
    y32, s_lut = lut_accumulate(lut, codes.astype(jnp.int32), lossless)
    return y32.astype(jnp.float32) * (s_lut * jnp.asarray(s_x, jnp.float32) * pw.scale)
