"""Kernel registry + shape-aware mpGEMM dispatch (DESIGN.md §5).

The paper's central performance claim rests on picking the right kernel per
*regime*: the element-wise-LUT kernels win the memory-bound batch-1 decode
GEMV (the MXU idles, HBM bytes are everything), while MAD-style decode wins
once the contraction is compute-bound (prefill / batched decode).  This
module is the single seam where that selection lives:

  * every kernel registers a :class:`KernelSpec` — ``(fmt, regime, backend)``
    capabilities plus cost hints (HBM bits/weight, MXU inflation);
  * :func:`mpgemm` is the one dispatch entry point: it derives the regime
    from the flattened batch N at trace time (shapes are static under jit),
    consults the plan override → autotune cache → heuristic, records the
    decision, and calls the winner;
  * :class:`KernelPlan` is the hashable per-config override object threaded
    through ``QuantConfig`` → models → engine → serve;
  * :class:`AutotuneCache` persists measured winners as JSON keyed by
    ``(backend, fmt, M, K, N-bucket)``.

Kernels are ENUMERATED from the format registry (``repro.core.formats``):
every grouped ELUT format gets ``{fmt}_lut`` / ``{fmt}_lut_lossy`` XLA
kernels and rides the parametric Pallas family, with cost hints *derived*
from the spec (HBM bits/weight from the packed bpw or the one-hot operand
C/g bytes; MXU inflation C/g = b^g/g).  Registering a new format in
``formats.py`` is sufficient for it to appear here — no hand-listing.
The enumeration runs at import time: a ``formats.register`` call made
AFTER importing this module is not picked up by the existing KernelSpecs
(register formats at ``formats.py`` import, the normal extension path).
"""

from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core import elut as _elut
from repro.core import formats as fmtreg
from repro.core import mpgemm as _mp
from repro.core.qtensor import PackedWeight

REGIMES = ("gemv", "gemm")

# v5e-ish roofline constants for the cost hints (absolute values only matter
# relatively; autotune measures reality).
_HBM_BYTES_PER_US = 819e3       # 819 GB/s
_MXU_OPS_PER_US = 394e6         # 394 int8 TOPS


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class KernelSpec:
    """One registered mpGEMM implementation.

    fn(x_q [..., K], s_x, pw, interpret) -> fp32 [..., M].  ``hbm_bpw`` is
    the per-weight HBM traffic in bits (None → the format's packed bpw, i.e.
    a fused in-VMEM decode); ``mxu_inflation`` is MXU work relative to the
    plain int8 MAD dot (the LUT one-hot contraction costs C/g = b^g/g —
    4.5× for tl1; None derives it from the format registry per fmt).
    """

    name: str
    fn: Callable
    backend: str                  # "xla" | "pallas"
    fmts: tuple                   # PackedWeight formats this kernel accepts
    regimes: tuple = REGIMES      # ("gemv",) | ("gemm",) | both
    lossless: bool = True         # bit-exact vs the b1.58 scheme
    hbm_bpw: float | None = None  # None → the format's packed bpw (fused decode)
    mxu_inflation: float | None = 1.0  # None → the format's C/g (LUT lookup)
    max_n: int | None = None      # hard cap on flattened batch (None = any)
    k_align: int = 1              # extra K divisibility beyond the format's

    def capable(self, fmt: str, regime: str, n: int, k: int, m: int) -> bool:
        if fmt not in self.fmts or regime not in self.regimes:
            return False
        if self.max_n is not None and n > self.max_n:
            return False
        # a packable weight must exist (format alignment) AND the kernel's
        # own tiling constraint must hold
        return k % max(fmtreg.get(fmt).k_align, 1) == 0 and k % self.k_align == 0

    def hbm_bytes(self, fmt: str, n: int, k: int, m: int,
                  occupancy: float = 1.0) -> float:
        """Predicted HBM traffic per call in bytes (weight operand + int8
        activations + any un-amortized scale plane) — the cost hint's memory
        term, exposed for the measured-vs-predicted attribution report.

        ``occupancy`` is the nonzero-block fraction of the weight's
        occupancy plane (``PackedWeight.occupancy()``; DESIGN.md §8/§11):
        the zero-skip Pallas kernels never stream a skipped block's code
        bytes out of VMEM-resident prefetch, so the expected weight-operand
        traffic scales with it.  It only applies when this kernel actually
        skips — a Pallas kernel on an occupancy (``_z``) format; every
        other (kernel, format) pair reads the full operand and ignores it.
        """
        fspec = fmtreg.get(fmt)
        bpw = self.hbm_bpw
        scale_bytes = 0.0
        if bpw is None or fmt in ("fp", "int4"):
            # fused decode (or a native-dtype dot): HBM traffic is the
            # format's true packed bpw regardless of the kernel — which for
            # grouped formats already amortizes the fp32 scale plane (32/G)
            bpw = fmtreg.bpw(fmt)
        elif fspec.group_scale_cols:
            # kernel-specified operand traffic (unpacked int8 / one-hot)
            # excludes the extra [K//G, M] fp32 scale-plane read
            scale_bytes = 4.0 * m * (k // fspec.group_scale_cols)
        w_bytes = m * k * bpw / 8
        if self.backend == "pallas" and fspec.occ_block:
            # skip walk: code-plane traffic scales with occupancy; the
            # occupancy plane itself (8/occ_block bpw, inside fspec.bpw)
            # is always read in full
            occ_bytes = m * k / fspec.occ_block
            w_bytes = (w_bytes - occ_bytes) * occupancy + occ_bytes
        return w_bytes + n * k + scale_bytes

    def cost(self, fmt: str, n: int, k: int, m: int,
             occupancy: float = 1.0) -> float:
        """Roofline cost hint in µs: max(HBM time, MXU time).  ``occupancy``
        scales both terms for zero-skip kernels (skipped blocks cost neither
        bytes nor decode/MAC work); ignored otherwise — see hbm_bytes."""
        fspec = fmtreg.get(fmt)
        infl = self.mxu_inflation
        if infl is None:
            infl = fspec.mxu_inflation
        mem = self.hbm_bytes(fmt, n, k, m, occupancy) / _HBM_BYTES_PER_US
        comp = 2.0 * n * m * k * infl / _MXU_OPS_PER_US
        if self.backend == "pallas" and fspec.occ_block:
            comp *= occupancy
        return max(mem, comp)


def _fn_xla(x_q, s_x, pw, interpret):
    return _mp.mpgemm_xla(x_q, s_x, pw)


def _fn_elut(lossless):
    def fn(x_q, s_x, pw, interpret):
        return _elut.elut_mpgemm(x_q, s_x, pw, lossless=lossless)

    return fn


def _fn_tl2_lut(lossless):
    def fn(x_q, s_x, pw, interpret):
        return _mp.tl2_lut(x_q, s_x, pw, lossless=lossless)

    return fn


def _fn_pallas(x_q, s_x, pw, interpret):
    from repro.kernels import ops as kops  # lazy: keeps dryrun pallas-free

    return kops.mpgemm_pallas(x_q, s_x, pw, interpret=interpret)


def _fn_lut_gemv(lossless):
    def fn(x_q, s_x, pw, interpret):
        from repro.kernels import ops as kops  # lazy: keeps dryrun pallas-free

        return kops.lut_gemv(x_q, s_x, pw, lossless=lossless, interpret=interpret)

    return fn


REGISTRY: dict[str, KernelSpec] = {}


def register(spec: KernelSpec) -> KernelSpec:
    if spec.name in REGISTRY:
        raise ValueError(f"kernel {spec.name!r} already registered")
    REGISTRY[spec.name] = spec
    return spec


# The library kernels, enumerated from the format registry (DESIGN.md §5).
# hbm_bpw for the XLA unpack path is 8 (the unpacked int8 [M, K] operand
# materializes at HLO level); the XLA LUT kernels materialize the one-hot
# [M, G, C] operand — spec.lut_hbm_bpw = 8·C/g bits/weight (tl1: 36.0) —
# and inflate MXU work by spec.mxu_inflation = C/g (tl1: 4.5×).
register(KernelSpec("xla", _fn_xla, "xla", fmtreg.names(), hbm_bpw=8.0))
register(KernelSpec("int4", _fn_xla, "xla", ("int4",), hbm_bpw=4.0))
for _f in fmtreg.names():
    _spec = fmtreg.get(_f)
    if _spec.supports_lut_gemv():
        _fns = (_fn_elut(True), _fn_elut(False))     # parametric ELUT path
    elif _f == "tl2":
        _fns = (_fn_tl2_lut(True), _fn_tl2_lut(False))  # mirror-consolidated
    else:
        continue
    register(KernelSpec(f"{_f}_lut", _fns[0], "xla", (_f,),
                        hbm_bpw=_spec.lut_hbm_bpw,
                        mxu_inflation=_spec.mxu_inflation))
    register(KernelSpec(f"{_f}_lut_lossy", _fns[1], "xla", (_f,),
                        lossless=False, hbm_bpw=_spec.lut_hbm_bpw,
                        mxu_inflation=_spec.mxu_inflation))
register(KernelSpec("pallas", _fn_pallas, "pallas", fmtreg.pallas_formats()))
for _lossless, _name in ((True, "lut_gemv"), (False, "lut_gemv_lossy")):
    register(KernelSpec(
        _name, _fn_lut_gemv(_lossless), "pallas", fmtreg.lut_gemv_formats(),
        regimes=("gemv",), lossless=_lossless, max_n=1,
        mxu_inflation=None))  # per-format C/g via the format registry


def formats() -> tuple:
    """Every format some registered kernel accepts."""
    out: list = []
    for spec in REGISTRY.values():
        for f in spec.fmts:
            if f not in out:
                out.append(f)
    return tuple(out)


def candidates(fmt: str, regime: str, n: int, k: int, m: int,
               *, lossless_only: bool = True, backend: str = "auto",
               occupancy: float = 1.0) -> list:
    """Capable specs for a shape, cheapest cost hint first.  ``occupancy``
    (nonzero-block fraction, DESIGN.md §11) re-ranks zero-skip kernels."""
    out = [
        s for s in REGISTRY.values()
        if s.capable(fmt, regime, n, k, m)
        and (not lossless_only or s.lossless)
        and (backend == "auto" or s.backend == backend)
    ]
    return sorted(out, key=lambda s: (s.cost(fmt, n, k, m, occupancy), s.name))


# ---------------------------------------------------------------------------
# KernelPlan
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class KernelPlan:
    """Per-config dispatch policy.  Hashable → lives inside ``QuantConfig``.

    gemv / gemm: registered kernel name for that regime, or "auto" to let
    the cache + heuristic decide.  backend: "auto" considers every kernel;
    "xla" restricts to pure-XLA kernels (the dryrun/compile-cost paths stay
    pallas-free); "pallas" restricts to the fused Pallas kernels.
    interpret: forced Pallas interpret mode (None → auto: off-TPU only).
    """

    gemv: str = "auto"
    gemm: str = "auto"
    backend: str = "auto"
    interpret: bool | None = None

    def named(self, regime: str) -> str:
        return self.gemv if regime == "gemv" else self.gemm


AUTO = KernelPlan()


def lut_plan(fmt: str, lossless: bool = True) -> KernelPlan:
    """Plan pinning the LUT computation model (paper TL*_1 / TL*_0 — and
    their ELUT generalizations) for ``fmt``."""
    sfx = "" if lossless else "_lossy"
    if fmt in fmtreg.lut_gemv_formats():
        return KernelPlan(gemv="lut_gemv" + sfx, gemm=f"{fmt}_lut" + sfx)
    if f"{fmt}_lut" in REGISTRY:  # tl2: mirror LUT in both regimes
        return KernelPlan(gemv=f"{fmt}_lut" + sfx, gemm=f"{fmt}_lut" + sfx)
    raise ValueError(f"no LUT kernels for format {fmt!r}")


# ---------------------------------------------------------------------------
# Autotune cache
# ---------------------------------------------------------------------------


_CHUNK_BUCKETS: set[int] = set()


def register_chunk_bucket(n: int) -> None:
    """Pin an exact N-bucket for a serving prefill or verify batch.

    The serving engine's chunked prefill always dispatches at exactly
    N = chunk (sequential per-slot chunks) or N = S·C (batched concurrent
    prefill: S = budget // C rows, padding included, every tick), so
    snapping that N to its own bucket lets the autotune cache store a
    winner for the shape that actually runs, instead of smearing it into
    the next power of two (a 48-token chunk would otherwise share the 64
    bucket; a 3·32 = 96 batched tick the 128 one).  Speculative decoding
    pins its verify batch N = B·(k+1) (and the draft-ingest width) the same
    way — that is what moves verification off the N=1 GEMV path and into
    the GEMM/MAD regime deterministically, per tick, every tick.
    Power-of-two values are already exact; idempotent.
    """
    if n > 1:
        _CHUNK_BUCKETS.add(int(n))


def n_bucket(n: int) -> int:
    """Bucket the flattened batch: 1 (GEMV), a registered prefill-chunk
    size (exact), or the next power of two ≤ 512."""
    if n <= 1:
        return 1
    if n in _CHUNK_BUCKETS:
        return n
    b = 2
    while b < n and b < 512:
        b *= 2
    return b


class AutotuneCache:
    """Measured per-shape winners, persisted as JSON.

    Entries map ``"{backend}|{fmt}|M{m}|K{k}|N{bucket}"`` → kernel name (plus
    the raw per-candidate timings for later inspection).  A loaded cache
    reproduces selections exactly: lookups are by key, no re-measurement.
    """

    def __init__(self, entries: dict | None = None, path: str | None = None):
        self.entries: dict[str, dict] = dict(entries or {})
        self.path = path

    @staticmethod
    def key(backend: str, fmt: str, n: int, k: int, m: int) -> str:
        # grouped formats key on G too: a tuned winner at one scale-group
        # size must not leak onto a future re-registration at another
        g = fmtreg.get(fmt).group_scale_cols if fmt in fmtreg.REGISTRY else None
        sfx = f"|G{g}" if g else ""
        return f"{backend}|{fmt}|M{m}|K{k}|N{n_bucket(n)}{sfx}"

    def get(self, key: str) -> str | None:
        e = self.entries.get(key)
        return e["kernel"] if e else None

    def put(self, key: str, kernel: str, timings_us: dict | None = None) -> None:
        self.entries[key] = {"kernel": kernel, "us": dict(timings_us or {})}

    def save(self, path: str | None = None) -> str:
        path = path or self.path
        if not path:
            raise ValueError("AutotuneCache.save needs a path")
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            # chunk buckets travel WITH the cache: keys for N=chunk entries
            # only resolve if the loading process pins the same buckets.
            json.dump({"version": 1, "entries": self.entries,
                       "chunk_buckets": sorted(_CHUNK_BUCKETS)},
                      f, indent=1, sort_keys=True)
        os.replace(tmp, path)
        self.path = path
        return path

    @classmethod
    def load(cls, path: str) -> "AutotuneCache":
        with open(path) as f:
            blob = json.load(f)
        for c in blob.get("chunk_buckets", ()):
            register_chunk_bucket(c)
        return cls(entries=blob.get("entries", {}), path=path)


_ACTIVE_CACHE = AutotuneCache()


def active_cache() -> AutotuneCache:
    return _ACTIVE_CACHE


def set_cache(cache: AutotuneCache) -> AutotuneCache:
    global _ACTIVE_CACHE
    _ACTIVE_CACHE = cache
    return cache


def load_cache(path: str) -> AutotuneCache:
    return set_cache(AutotuneCache.load(path))


# ---------------------------------------------------------------------------
# Selection
# ---------------------------------------------------------------------------


def _hw_backend() -> str:
    return jax.default_backend()


def _heuristic(fmt: str, regime: str, hw: str, backend: str) -> str:
    """The paper's regime table (Bitnet.cpp §3), TPU-adapted.

    GEMV decode is memory-bound → true-LUT kernel for tl1 (the headline),
    fused Pallas decode for the other packed formats on TPU.  GEMM prefill is
    compute-bound → MAD on the MXU (fused Pallas decode on TPU, the XLA int
    dot elsewhere — off-TPU the Pallas kernels only run in interpret mode, so
    they are validation vehicles, not fast paths; lut_gemv stays selected
    off-TPU because it IS the paper's decode semantics and is cheap at N=1).
    """
    if backend == "xla":
        return "int4" if fmt == "int4" else "xla"
    if backend == "pallas":
        if regime == "gemv" and fmt in fmtreg.lut_gemv_formats():
            return "lut_gemv"
        if fmt in fmtreg.pallas_formats():
            return "pallas"
        raise ValueError(f"no pallas kernel for format {fmt!r}")
    if regime == "gemv" and fmt in fmtreg.lut_gemv_formats():
        return "lut_gemv"
    if fmt in fmtreg.pallas_formats() and hw == "tpu":
        return "pallas"
    return "int4" if fmt == "int4" else "xla"


def select(fmt: str, n: int, k: int, m: int,
           plan: KernelPlan = AUTO) -> tuple[KernelSpec, str]:
    """Resolve (spec, source) for a shape.  source ∈ override|autotune|heuristic."""
    regime = "gemv" if n == 1 else "gemm"
    named = plan.named(regime)
    if named != "auto":
        spec = REGISTRY.get(named)
        if spec is None:
            raise ValueError(
                f"unknown kernel {named!r}; registered: {sorted(REGISTRY)}")
        if not spec.capable(fmt, regime, n, k, m):
            raise ValueError(
                f"kernel {named!r} cannot run fmt={fmt!r} regime={regime} "
                f"(N={n}, K={k}, M={m}); capable: "
                f"{[s.name for s in candidates(fmt, regime, n, k, m, lossless_only=False)]}")
        return spec, "override"
    hw = _hw_backend()
    cached = _ACTIVE_CACHE.get(AutotuneCache.key(hw, fmt, n, k, m))
    if cached is not None:
        spec = REGISTRY.get(cached)
        if spec is not None and spec.capable(fmt, regime, n, k, m) and (
                plan.backend == "auto" or spec.backend == plan.backend):
            return spec, "autotune"
    return REGISTRY[_heuristic(fmt, regime, hw, plan.backend)], "heuristic"


# ---------------------------------------------------------------------------
# Decision log (trace-time introspection; what the acceptance tests assert)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Decision:
    fmt: str
    regime: str
    n: int
    k: int
    m: int
    kernel: str
    source: str
    seq: int = 0  # monotone id; survives log trimming


_DECISIONS: list[Decision] = []
_MAX_DECISIONS = 4096
_SEQ = 0  # total decisions ever recorded (monotone, never reset by trimming)
_DROPPED = 0  # decisions lost to trimming (surfaced via the metrics registry)


def decisions() -> tuple:
    return tuple(_DECISIONS)


def decision_count() -> int:
    """Monotone mark for :func:`decisions_since` (NOT the retained length —
    the log trims its oldest half at capacity, so indices are unstable)."""
    return _SEQ


def decisions_since(mark: int) -> tuple:
    """Decisions recorded after ``mark`` (a prior ``decision_count()``).

    Robust to log trimming: matches by monotone seq, not list index.  If the
    log overflowed past ``mark`` the trimmed-away decisions are simply gone
    FROM THIS VIEW — but not silently: :func:`decisions_dropped` counts
    every trimmed entry, and the observability metrics snapshot surfaces it
    (``dispatch_decisions_dropped``) next to the retained log.
    """
    return tuple(d for d in _DECISIONS if d.seq >= mark)


def decisions_dropped() -> int:
    """Total decisions lost to capacity trimming since process start."""
    return _DROPPED


def clear_decisions() -> None:
    _DECISIONS.clear()


def _record(d: Decision) -> None:
    global _SEQ, _DROPPED
    if len(_DECISIONS) >= _MAX_DECISIONS:
        trim = _MAX_DECISIONS // 2
        del _DECISIONS[:trim]
        _DROPPED += trim
    _DECISIONS.append(dataclasses.replace(d, seq=_SEQ))
    _SEQ += 1


# ---------------------------------------------------------------------------
# Dispatch entry point
# ---------------------------------------------------------------------------


def mpgemm(x_q: jax.Array, s_x, pw: PackedWeight,
           plan: KernelPlan = AUTO, *, _source: str | None = None) -> jax.Array:
    """THE mpGEMM entry point: int8 [..., K] × PackedWeight [M, K] → fp32 [..., M].

    Regime is derived from the flattened batch N = prod(leading dims) at
    trace time; selection order is plan override → autotune cache →
    heuristic.  Every decision is recorded (see :func:`decisions`).
    """
    if plan is None:
        plan = AUTO
    k = x_q.shape[-1]
    if k != pw.k:
        raise ValueError(
            f"activation K={k} does not match packed weight K={pw.k} "
            f"(weight {pw.fmt!r} [M={pw.m}, K={pw.k}])")
    n = 1
    for d in x_q.shape[:-1]:
        n *= int(d)
    spec, source = select(pw.fmt, n, k, pw.m, plan)
    _record(Decision(pw.fmt, "gemv" if n == 1 else "gemm", n, k, pw.m,
                     spec.name, _source or source))
    return spec.fn(x_q, s_x, pw, plan.interpret)


def shard_shapes(shapes, *, tp: int = 1, tp_dim: str = "m") -> list:
    """Map GLOBAL (n, k, m) dispatch shapes to their TP shard-local shapes.

    Under tensor parallelism each device dispatches the SHARD-LOCAL
    contraction — M/tp for column-parallel, K/tp for row-parallel — and
    decision records and autotune-cache keys are made from those local
    shapes (``repro.distributed.tp`` runs :func:`mpgemm` inside shard_map,
    so this happens by construction at trace time).  Use this to autotune or
    :func:`explain` the shapes a TP=N launch will actually run."""
    if tp_dim not in ("m", "k"):
        raise ValueError(f"tp_dim must be 'm' or 'k', got {tp_dim!r}")
    out = []
    for n, k, m in shapes:
        dim = m if tp_dim == "m" else k
        if dim % tp != 0:
            raise ValueError(
                f"{tp_dim.upper()}={dim} does not divide into tp={tp} shards")
        out.append((n, k // tp, m) if tp_dim == "k" else (n, k, m // tp))
    return out


def explain(fmt: str, n: int, k: int, m: int, plan: KernelPlan = AUTO,
            *, occupancy: float = 1.0, tp: int = 1, tp_dim: str = "m") -> dict:
    """Inspect a dispatch decision without running it (README quickstart).

    For occupancy (``_z``) formats pass the weight's measured nonzero-block
    fraction (``PackedWeight.occupancy()``) to see the skip-walk cost hints
    the attribution report uses; the default 1.0 is the dense upper bound.

    ``tp``/``tp_dim`` preview the SHARD-LOCAL decision a TP launch makes:
    the (n, k, m) given here are the GLOBAL shapes, and the hint reflects
    the per-device contraction (see :func:`shard_shapes`).
    """
    ((n, k, m),) = shard_shapes([(n, k, m)], tp=tp, tp_dim=tp_dim)
    regime = "gemv" if n == 1 else "gemm"
    spec, source = select(fmt, n, k, m, plan)
    return {
        "fmt": fmt, "regime": regime, "n": n, "k": k, "m": m,
        "kernel": spec.name, "source": source, "backend": spec.backend,
        "occupancy": occupancy, "tp": tp, "tp_dim": tp_dim,
        "cost_hint_us": spec.cost(fmt, n, k, m, occupancy),
        "candidates": [
            (s.name, round(s.cost(fmt, n, k, m, occupancy), 3))
            for s in candidates(fmt, regime, n, k, m, lossless_only=False,
                                occupancy=occupancy)
        ],
    }


# ---------------------------------------------------------------------------
# Autotuner
# ---------------------------------------------------------------------------


def _time_call(fn, *args, reps: int = 5) -> float:
    out = fn(*args)  # warmup / compile
    out.block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    out.block_until_ready()
    return (time.perf_counter() - t0) / reps * 1e6  # µs


def autotune(fmt: str, shapes, *, cache: AutotuneCache | None = None,
             names: tuple | None = None, reps: int = 5, seed: int = 0,
             interpret: bool | None = None) -> AutotuneCache:
    """Measure every capable lossless kernel per (N, K, M) shape; store winners.

    shapes: iterable of (n, k, m).  Off-TPU the Pallas kernels execute in
    interpret mode (Python, minutes per large shape, timings meaningless) —
    they are skipped unless explicitly requested via ``names``, which
    otherwise just restricts the candidate set.  Winners land in ``cache``
    (default: the active cache) keyed by (hardware backend, fmt, M, K,
    N-bucket).
    """
    import numpy as np

    cache = cache or _ACTIVE_CACHE
    hw = _hw_backend()
    rng = np.random.default_rng(seed)
    from repro.core.qtensor import pack_ternary, pack_weight

    for n, k, m in shapes:
        regime = "gemv" if n == 1 else "gemm"
        cands = candidates(fmt, regime, n, k, m)
        if names is not None:
            cands = [s for s in cands if s.name in names]
        elif hw != "tpu":
            cands = [s for s in cands if s.backend != "pallas"]
        if not cands:
            continue
        w = jnp.asarray(rng.integers(-1, 2, size=(m, k)), jnp.int8)
        x_q = jnp.asarray(rng.integers(-127, 128, size=(n, k)), jnp.int8)
        if fmt == "fp":  # the bf16 baseline has no ternary pack path
            pw = pack_weight(w.astype(jnp.float32), fmt)
        else:
            pw = pack_ternary(w, jnp.float32(1.0), fmt)
        timings: dict[str, float] = {}
        for spec in cands:
            fn = jax.jit(lambda xq, s, spec=spec: spec.fn(xq, s, pw, interpret))
            timings[spec.name] = _time_call(fn, x_q, jnp.float32(1.0), reps=reps)
        best = min(timings, key=timings.get)
        cache.put(AutotuneCache.key(hw, fmt, n, k, m), best, timings)
    return cache
