"""Packed-weight container used across the framework.

A ``PackedWeight`` holds the HBM representation of one low-bit weight matrix
in one of the registered formats (``repro.core.formats``, DESIGN.md §2),
plus its per-tensor absmean scale.  It is a registered pytree so it can flow
through jit/pjit/scan and be sharded with NamedSharding like any other
parameter.

Pack/unpack and the training-side quantization rule are resolved through
the :mod:`repro.core.formats` registry — this module holds no per-format
branches.  ``FORMAT_BPW`` is kept as a live dict-like view for callers that
only need bits-per-weight.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core import formats, packing
from repro.core.formats import FORMAT_BPW  # re-export (legacy import site)

__all__ = ["FORMAT_BPW", "PackedWeight", "pack_weight", "pack_ternary",
           "pack_quantized", "unpack_weight"]


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["planes", "scale"],
    meta_fields=["fmt", "shape", "three_k"],
)
@dataclasses.dataclass
class PackedWeight:
    """Packed low-bit weight of logical shape [M, K] (output-major)."""

    planes: dict  # str -> jax.Array
    scale: jax.Array  # fp32 absmean: scalar, or [K//G, M] for grouped formats
    fmt: str
    shape: tuple  # (M, K)
    three_k: int = 0  # split-K formats only: K prefix on the main path

    @property
    def m(self) -> int:
        return self.shape[0]

    @property
    def k(self) -> int:
        return self.shape[1]

    @property
    def spec(self) -> formats.FormatSpec:
        return formats.get(self.fmt)

    def bits(self) -> int:
        """Total packed bits actually stored (for roofline byte accounting).

        int4 is a true sub-byte dtype in HBM on TPU (2 elems/byte) even though
        host numpy reports itemsize 1 — account 4 bits per element.
        """
        total = 0
        for p in self.planes.values():
            if p.dtype == jnp.int4:
                total += int(p.size) * 4
            else:
                total += int(p.size) * p.dtype.itemsize * 8
        if self.scale.ndim:  # grouped: the [K//G, M] scale plane is HBM too
            total += int(self.scale.size) * 32
        return total

    def bpw(self) -> float:
        return self.bits() / (self.m * self.k)

    def occupancy(self) -> float:
        """Nonzero-block fraction of the occupancy plane (1.0 when the
        format carries none — the dense upper bound).

        This is the ``occupancy`` argument the dispatch cost hints and the
        bench attribution take (DESIGN.md §8/§11): the zero-skip kernels'
        expected code-plane HBM bytes and decode work scale with it.
        """
        occ = self.planes.get("occ")
        if occ is None:
            return 1.0
        return float(jnp.mean((occ != 0).astype(jnp.float32)))


def pack_weight(w: jax.Array, fmt: str) -> PackedWeight:
    """Quantize an fp master weight [M, K] via the format's training-side
    rule (absmean ternary / low-bit) and pack as ``fmt``."""
    M, K = w.shape
    if fmt == "fp":
        return PackedWeight({"w": w.astype(jnp.bfloat16)}, jnp.float32(1.0), "fp", (M, K))
    spec = formats.get(fmt)
    w_q, s = spec.quantize(w)
    return pack_quantized(w_q, s, fmt)


def pack_quantized(w_q: jax.Array, scale: jax.Array, fmt: str) -> PackedWeight:
    """Pack an already-quantized int8 code matrix (values in the format's
    ``levels`` range; ternary {-1,0,1} is valid for every integer format).

    For grouped formats (``spec.group_scale_cols``) ``scale`` is the
    [K//G, M] scale plane; a scalar is broadcast to it (every group shares
    one scale — how per-tensor test/bench weights ride grouped formats).
    """
    M, K = w_q.shape
    scale = jnp.asarray(scale, jnp.float32)
    spec = formats.get(fmt)
    if spec.pack is None:
        raise ValueError(f"format {fmt!r} has no integer pack path")
    if spec.group_scale_cols:
        gshape = packing.group_scale_shape(M, K, spec.group_scale_cols)
        if scale.ndim == 0:
            scale = jnp.full(gshape, scale, jnp.float32)
        elif scale.shape != gshape:
            raise ValueError(
                f"format {fmt!r} needs a {gshape} scale plane "
                f"(G={spec.group_scale_cols}), got shape {scale.shape}")
    elif scale.ndim:
        raise ValueError(
            f"format {fmt!r} uses a per-tensor scalar scale, "
            f"got shape {scale.shape}")
    planes = spec.pack(w_q)
    three_k = spec.split_k(K)[0] if spec.split_k is not None else 0
    return PackedWeight(planes, scale, fmt, (M, K), three_k=three_k)


# The historical name: every pre-ELUT format was ternary.
pack_ternary = pack_quantized


def unpack_weight(pw: PackedWeight) -> jax.Array:
    """Recover the int8 code matrix [M, K] (fp format returns bf16)."""
    if pw.fmt == "fp":
        return pw.planes["w"]
    return pw.spec.unpack(pw.planes, pw.k)
