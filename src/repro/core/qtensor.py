"""Packed-weight container used across the framework.

A ``PackedWeight`` holds the HBM representation of one ternary weight matrix
in one of the library formats (DESIGN.md §2), plus its per-tensor absmean
scale.  It is a registered pytree so it can flow through jit/pjit/scan and be
sharded with NamedSharding like any other parameter.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core import packing, quant

# Formats and their bits-per-weight (paper Table 1 + our int4 XLA-native path).
FORMAT_BPW = {
    "fp": 16.0,     # bf16 baseline (paper's Float16 baseline)
    "int4": 4.0,    # XLA-native int4 storage (TPU dot consumes int4 directly)
    "i2s": 2.0,     # paper I2_S
    "tl1": 2.0,     # paper TL1
    "tl2": 5.0 / 3.0,   # paper TL2 (1.67)
    "tl2k": 5.0 / 3.0,  # TL2 in the Pallas kernel layout (same bpw)
    "tq1": 1.6,     # idealized llama.cpp TQ1_0 baseline
}


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["planes", "scale"],
    meta_fields=["fmt", "shape", "three_k"],
)
@dataclasses.dataclass
class PackedWeight:
    """Packed ternary weight of logical shape [M, K] (output-major)."""

    planes: dict  # str -> jax.Array
    scale: jax.Array  # fp32 scalar (absmean)
    fmt: str
    shape: tuple  # (M, K)
    three_k: int = 0  # tl2 only: K prefix handled by the g=3 path

    @property
    def m(self) -> int:
        return self.shape[0]

    @property
    def k(self) -> int:
        return self.shape[1]

    def bits(self) -> int:
        """Total packed bits actually stored (for roofline byte accounting).

        int4 is a true sub-byte dtype in HBM on TPU (2 elems/byte) even though
        host numpy reports itemsize 1 — account 4 bits per element.
        """
        total = 0
        for p in self.planes.values():
            if p.dtype == jnp.int4:
                total += int(p.size) * 4
            else:
                total += int(p.size) * p.dtype.itemsize * 8
        return total

    def bpw(self) -> float:
        return self.bits() / (self.m * self.k)


def pack_weight(w: jax.Array, fmt: str) -> PackedWeight:
    """Quantize an fp master weight [M, K] to ternary and pack as ``fmt``."""
    M, K = w.shape
    if fmt == "fp":
        return PackedWeight({"w": w.astype(jnp.bfloat16)}, jnp.float32(1.0), "fp", (M, K))
    w_t, s = quant.ternary_quant(w)
    return pack_ternary(w_t, s, fmt)


def pack_ternary(w_t: jax.Array, scale: jax.Array, fmt: str) -> PackedWeight:
    """Pack an already-ternary int8 matrix (values in {-1,0,1})."""
    M, K = w_t.shape
    scale = jnp.asarray(scale, jnp.float32)
    if fmt == "int4":
        return PackedWeight({"w4": w_t.astype(jnp.int4)}, scale, fmt, (M, K))
    if fmt == "i2s":
        return PackedWeight({"p": packing.i2s_pack(w_t)}, scale, fmt, (M, K))
    if fmt == "tl1":
        return PackedWeight({"p": packing.tl1_pack(w_t)}, scale, fmt, (M, K))
    if fmt == "tq1":
        return PackedWeight({"p": packing.tq1_pack(w_t)}, scale, fmt, (M, K))
    if fmt == "tl2":
        three_k, two_k = packing.tl2_split_k(K)
        planes = {}
        if three_k:
            idx_plane, sign_plane = packing.tl2_pack(w_t[:, :three_k])
            planes["idx"] = idx_plane
            planes["sign"] = sign_plane
        if two_k:
            planes["tail"] = packing.tl1_pack(w_t[:, three_k:])
        return PackedWeight(planes, scale, fmt, (M, K), three_k=three_k)
    if fmt == "tl2k":
        # Kernel layout (block-fitting split sized to the Pallas K-tile).
        three_k, two_k = packing.tl2k_split_k(K)
        planes = {}
        if three_k:
            idx_plane, sign_plane = packing.tl2k_pack(w_t[:, :three_k])
            planes["idx"] = idx_plane
            planes["sign"] = sign_plane
        if two_k:
            planes["tail"] = packing.tl1_pack(w_t[:, three_k:])
        return PackedWeight(planes, scale, fmt, (M, K), three_k=three_k)
    raise ValueError(f"unknown format {fmt!r}")


def unpack_weight(pw: PackedWeight) -> jax.Array:
    """Recover the int8 ternary matrix [M, K] (fp format returns bf16)."""
    M, K = pw.shape
    if pw.fmt == "fp":
        return pw.planes["w"]
    if pw.fmt == "int4":
        return pw.planes["w4"].astype(jnp.int8)
    if pw.fmt == "i2s":
        return packing.i2s_unpack(pw.planes["p"], K)
    if pw.fmt == "tl1":
        return packing.tl1_unpack(pw.planes["p"], K)
    if pw.fmt == "tq1":
        return packing.tq1_unpack(pw.planes["p"], K)
    if pw.fmt in ("tl2", "tl2k"):
        unpack3 = packing.tl2_unpack if pw.fmt == "tl2" else packing.tl2k_unpack
        parts = []
        if pw.three_k:
            parts.append(unpack3(pw.planes["idx"], pw.planes["sign"], pw.three_k))
        if pw.three_k < K:
            parts.append(packing.tl1_unpack(pw.planes["tail"], K - pw.three_k))
        return jnp.concatenate(parts, axis=1) if len(parts) > 1 else parts[0]
    raise ValueError(f"unknown format {pw.fmt!r}")
