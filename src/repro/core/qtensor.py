"""Packed-weight container used across the framework.

A ``PackedWeight`` holds the HBM representation of one low-bit weight matrix
in one of the registered formats (``repro.core.formats``, DESIGN.md §2),
plus its per-tensor absmean scale.  It is a registered pytree so it can flow
through jit/pjit/scan and be sharded with NamedSharding like any other
parameter.

Pack/unpack and the training-side quantization rule are resolved through
the :mod:`repro.core.formats` registry — this module holds no per-format
branches.  ``FORMAT_BPW`` is kept as a live dict-like view for callers that
only need bits-per-weight.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core import formats, packing
from repro.core.formats import FORMAT_BPW  # re-export (legacy import site)

__all__ = ["FORMAT_BPW", "PackedWeight", "pack_weight", "pack_ternary",
           "pack_quantized", "unpack_weight", "shard_m", "shard_k",
           "check_shard_m", "check_shard_k"]


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["planes", "scale"],
    meta_fields=["fmt", "shape", "three_k"],
)
@dataclasses.dataclass
class PackedWeight:
    """Packed low-bit weight of logical shape [M, K] (output-major)."""

    planes: dict  # str -> jax.Array
    scale: jax.Array  # fp32 absmean: scalar, or [K//G, M] for grouped formats
    fmt: str
    shape: tuple  # (M, K)
    three_k: int = 0  # split-K formats only: K prefix on the main path

    @property
    def m(self) -> int:
        return self.shape[0]

    @property
    def k(self) -> int:
        return self.shape[1]

    @property
    def spec(self) -> formats.FormatSpec:
        return formats.get(self.fmt)

    def bits(self) -> int:
        """Total packed bits actually stored (for roofline byte accounting).

        int4 is a true sub-byte dtype in HBM on TPU (2 elems/byte) even though
        host numpy reports itemsize 1 — account 4 bits per element.
        """
        total = 0
        for p in self.planes.values():
            if p.dtype == jnp.int4:
                total += int(p.size) * 4
            else:
                total += int(p.size) * p.dtype.itemsize * 8
        if self.scale.ndim:  # grouped: the [K//G, M] scale plane is HBM too
            total += int(self.scale.size) * 32
        return total

    def bpw(self) -> float:
        return self.bits() / (self.m * self.k)

    def occupancy(self) -> float:
        """Nonzero-block fraction of the occupancy plane (1.0 when the
        format carries none — the dense upper bound).

        This is the ``occupancy`` argument the dispatch cost hints and the
        bench attribution take (DESIGN.md §8/§11): the zero-skip kernels'
        expected code-plane HBM bytes and decode work scale with it.
        """
        occ = self.planes.get("occ")
        if occ is None:
            return 1.0
        return float(jnp.mean((occ != 0).astype(jnp.float32)))


def pack_weight(w: jax.Array, fmt: str) -> PackedWeight:
    """Quantize an fp master weight [M, K] via the format's training-side
    rule (absmean ternary / low-bit) and pack as ``fmt``."""
    M, K = w.shape
    if fmt == "fp":
        return PackedWeight({"w": w.astype(jnp.bfloat16)}, jnp.float32(1.0), "fp", (M, K))
    spec = formats.get(fmt)
    w_q, s = spec.quantize(w)
    return pack_quantized(w_q, s, fmt)


def pack_quantized(w_q: jax.Array, scale: jax.Array, fmt: str) -> PackedWeight:
    """Pack an already-quantized int8 code matrix (values in the format's
    ``levels`` range; ternary {-1,0,1} is valid for every integer format).

    For grouped formats (``spec.group_scale_cols``) ``scale`` is the
    [K//G, M] scale plane; a scalar is broadcast to it (every group shares
    one scale — how per-tensor test/bench weights ride grouped formats).
    """
    M, K = w_q.shape
    scale = jnp.asarray(scale, jnp.float32)
    spec = formats.get(fmt)
    if spec.pack is None:
        raise ValueError(f"format {fmt!r} has no integer pack path")
    if spec.group_scale_cols:
        gshape = packing.group_scale_shape(M, K, spec.group_scale_cols)
        if scale.ndim == 0:
            scale = jnp.full(gshape, scale, jnp.float32)
        elif scale.shape != gshape:
            raise ValueError(
                f"format {fmt!r} needs a {gshape} scale plane "
                f"(G={spec.group_scale_cols}), got shape {scale.shape}")
    elif scale.ndim:
        raise ValueError(
            f"format {fmt!r} uses a per-tensor scalar scale, "
            f"got shape {scale.shape}")
    planes = spec.pack(w_q)
    three_k = spec.split_k(K)[0] if spec.split_k is not None else 0
    return PackedWeight(planes, scale, fmt, (M, K), three_k=three_k)


# The historical name: every pre-ELUT format was ternary.
pack_ternary = pack_quantized


def unpack_weight(pw: PackedWeight) -> jax.Array:
    """Recover the int8 code matrix [M, K] (fp format returns bf16)."""
    if pw.fmt == "fp":
        return pw.planes["w"]
    return pw.spec.unpack(pw.planes, pw.k)


# ---------------------------------------------------------------------------
# TP shard slicing (DESIGN.md §12).
#
# A PackedWeight shards WITHOUT repacking because every plane packs along K
# in consumption order and every metadata plane is aligned to the code plane:
#
#   * column-parallel (M): every plane is row-major in M ([M, ...]), so an
#     M shard is a row slice of every plane; the grouped [K//G, M] scale
#     plane slices its COLUMNS — scale columns travel with their code rows.
#   * row-parallel (K): a shard boundary on the format's shard_k_quantum
#     (whole decode units × whole scale groups × whole occupancy blocks)
#     slices each plane's bytes contiguously (packing.col_slice_bytes), the
#     occ plane at block granularity, and the scale plane at group rows.
#
# Misaligned requests RAISE — silently repacking would change the bytes a
# checkpoint pins and break the concat-reconstructs-exactly property the
# sharded test tier asserts.
# ---------------------------------------------------------------------------


def check_shard_m(m: int, n_shards: int) -> int:
    """Validate a column-parallel split; returns the per-shard M."""
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    if m % n_shards != 0:
        raise ValueError(
            f"M={m} does not divide into {n_shards} column-parallel shards")
    return m // n_shards


def check_shard_k(spec: formats.FormatSpec, k: int, n_shards: int) -> int:
    """Validate a row-parallel split; returns the per-shard K.

    Every shard must be a multiple of ``spec.shard_k_quantum`` so packed
    bytes slice at unit boundaries, scale groups never straddle the psum,
    and occupancy blocks stay whole; split-K formats refuse entirely."""
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    if not spec.k_shardable:
        raise ValueError(
            f"format {spec.name!r} is split-K (ThreeK prefix + TwoK tail is "
            "a function of the full K); row-parallel sharding would need a "
            "repack — shard along M instead")
    if k % n_shards != 0:
        raise ValueError(
            f"K={k} does not divide into {n_shards} row-parallel shards")
    q = spec.shard_k_quantum
    if (k // n_shards) % q != 0:
        raise ValueError(
            f"K={k} over {n_shards} shards gives {k // n_shards} columns per "
            f"shard, not a multiple of {spec.name!r}'s shard quantum {q} "
            "(whole decode units / scale groups / occupancy blocks)")
    return k // n_shards


def _slice_planes_m(planes: dict, m0: int, m1: int) -> dict:
    return {name: p[m0:m1] for name, p in planes.items()}


def _slice_plane_k(name: str, p: jax.Array, spec: formats.FormatSpec,
                   k0: int, k1: int) -> jax.Array:
    if name in ("w", "w4"):        # native-dtype planes: one element per column
        return p[:, k0:k1]
    if name == "occ":              # [M, K/occ_block] block bitmap
        return p[:, k0 // spec.occ_block: k1 // spec.occ_block]
    # packed code plane: contiguous bytes per whole decode units
    b0, b1 = packing.col_slice_bytes(
        k0, k1, spec.weights_per_unit, spec.unit_bytes)
    return p[:, b0:b1]


def shard_m(pw: PackedWeight, n_shards: int) -> tuple:
    """Column-parallel split -> ``n_shards`` self-contained PackedWeights.

    Shard i holds output rows [i·M/n, (i+1)·M/n): a row slice of every code
    and metadata plane, and the matching COLUMN slice of the grouped scale
    plane (a scalar scale replicates).  Concatenating the shards' planes
    along M reconstructs the unsharded planes byte-for-byte."""
    m_local = check_shard_m(pw.m, n_shards)
    out = []
    for i in range(n_shards):
        m0, m1 = i * m_local, (i + 1) * m_local
        scale = pw.scale if pw.scale.ndim == 0 else pw.scale[:, m0:m1]
        out.append(PackedWeight(
            _slice_planes_m(pw.planes, m0, m1), scale, pw.fmt,
            (m_local, pw.k), three_k=pw.three_k))
    return tuple(out)


def shard_k(pw: PackedWeight, n_shards: int) -> tuple:
    """Row-parallel split -> ``n_shards`` self-contained PackedWeights.

    Shard i holds K-columns [i·K/n, (i+1)·K/n): a contiguous byte slice of
    each code plane, the matching occupancy blocks, and the matching scale
    GROUP ROWS of the [K//G, M] plane (a per-tensor scalar replicates — the
    caller owns applying it ONCE, after the cross-shard reduction, at int32
    accumulator granularity; see repro.distributed.tp.mpgemm_kshard)."""
    spec = pw.spec
    k_local = check_shard_k(spec, pw.k, n_shards)
    out = []
    for i in range(n_shards):
        k0, k1 = i * k_local, (i + 1) * k_local
        planes = {name: _slice_plane_k(name, p, spec, k0, k1)
                  for name, p in pw.planes.items()}
        if pw.scale.ndim == 0:
            scale = pw.scale
        else:
            g = spec.group_scale_cols
            scale = pw.scale[k0 // g: k1 // g]
        out.append(PackedWeight(planes, scale, pw.fmt, (pw.m, k_local)))
    return tuple(out)
