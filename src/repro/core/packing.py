"""Low-bit weight packing (paper §3, Table 1 + Appendix ELUT).

All formats store a weight matrix W of shape [M, K] packed along K (the
contraction axis) so each output row's packed bytes are contiguous — the
TPU analogue of the paper's LUT-centric data layout (packed bytes stream
HBM→VMEM in the same order the kernel consumes them).

The parametric base-b packer (``elut_pack``/``elut_unpack``) covers every
plain code-plane format; the named formats are its instances
(bit-identical to the legacy hand-written layouts):

i2s   2.00 bpw  (b=3, g=1)  4 trits / byte, 2-bit fields     (paper I2_S)
tl1   2.00 bpw  (b=3, g=2)  2 trits → 4-bit code (9<16)      (paper TL1)
tq1   1.60 bpw  (b=3, g=5)  5 trits / byte (243<256)         (llama.cpp
                                                              TQ1_0-like,
                                                              idealized)
int2  2.00 bpw  (b=4, g=2)  levels {-2..1}, 4-bit codes      (ELUT)
int3  4.00 bpw  (b=8, g=2)  levels {-4..3}, byte codes       (ELUT)

**Bit-contiguous variants** (``elut_pack_bc``): the byte-field layout above
rounds each code up to a power-of-two field (int3's 6-bit codes burn byte
fields → 4 bpw); the bit-contiguous layout stores codes back to back in a
little-endian bit stream, decoded per *unit* of ``lcm(code_bits, 8)/8``
bytes (DESIGN.md §11):

int3_bc  3.00 bpw  (b=8, g=2)  6-bit codes, 3-byte/4-code unit (8 weights)

**Zero-occupancy metadata** (``occupancy_map``): ``_z`` format variants
carry one extra uint8 plane marking which ``occ_block``-column K-blocks of
each output row contain any nonzero weight, letting kernels skip all-zero
blocks in the K walk (DESIGN.md §11; the skip is exact — a zero block's
contribution is exactly 0).

tl2   1.67 bpw  3 trits → 1-bit sign + 4-bit index (3^3/2=13.5<16)
                index plane: 2 idx / byte; sign plane: 8 signs / byte
                                                        (paper TL2, element-wise
                                                         mirror consolidation +
                                                         signed-unsigned split —
                                                         NOT a plain code plane)

``tl2`` requires K % 24 == 0; general K is handled by block-fitting weight
splitting (paper §3.1.2): ``tl2_split_k`` statically divides K into a ThreeK
part (multiple of 24, packed tl2) and a TwoK tail (packed tl1).

The registry in :mod:`repro.core.formats` binds these functions to format
names; nothing outside that registry should branch on a format string.

**Grouped weight scales** (``FormatSpec.group_scale_cols = G``): the packed
code planes are IDENTICAL to the per-tensor layout — scales are not woven
into the byte stream (which would misalign the code fields) but travel as a
separate fp32 plane of shape ``[K//G, M]`` beside the codes
(``PackedWeight.scale``).  The layout is *group-major*: row ``s`` holds the
scales of K-columns ``[s·G, (s+1)·G)`` for every output row, so a kernel
walking K in consumption order streams scale rows sequentially, one
``[1, M]`` row per G columns — the same HBM-order argument as the code
planes.  Dequant: ``w[m, k] ≈ w_q[m, k] · scale[k // G, m]``; per-tensor is
the degenerate ``scale`` scalar (``group_scale_cols=None``).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

TL2_CENTER = 13  # base-3 value of (0,0,0); values 0..13 keep sign=0, 14..26 mirror.


def _check_ternary(w: jax.Array) -> jax.Array:
    return w.astype(jnp.int8)


# ---------------------------------------------------------------------------
# Parametric base-b packer — the ELUT layout (paper Appendix).
#
# A format (b, g, field_bits) stores groups of g weights with values in
# [-(b//2), b-1-b//2] as one code = Σ_i digit_i · b^(g-1-i) (big-endian,
# digit = weight + b//2) in a ``field_bits``-wide field; 8/field_bits fields
# pack little-endian into each byte.  Ternary instances: i2s = (3,1,2),
# tl1 = (3,2,4), tq1 = (3,5,8).  Non-ternary: int2 = (4,2,4), int3 = (8,2,8).
# ---------------------------------------------------------------------------


def elut_pack(w: jax.Array, b: int, g: int, field_bits: int,
              *, pad: bool = False) -> jax.Array:
    """[M, K] int8 codes -> [M, ceil(K/wpb)] uint8, wpb = g · 8/field_bits.

    Layout invariants (normative; the conformance harness round-trips them):

      * digits are ``weight + b//2`` (all non-negative);
      * a group of g consecutive K-columns forms one code
        ``Σ_i digit_i · b^(g-1-i)`` — big-endian in the digit order, so
        ``elut_build_lut`` can enumerate codes the same way;
      * 8/field_bits codes pack little-endian into each byte (field f at
        bit offset ``f · field_bits``), K ascending with the byte index —
        packed bytes stream in K-consumption order;
      * ``pad=True`` zero-weight-pads K up to a whole byte (tq1); pad
        columns decode to weight 0 and are sliced off by ``elut_unpack``.

    ``field_bits`` must hold a full code (``b^g ≤ 2^field_bits``); codes
    whose minimal width is narrower than any power-of-two field waste bits
    here — see :func:`elut_pack_bc` for the bit-contiguous alternative.
    """
    w = w.astype(jnp.int8)
    M, K = w.shape
    fpb = 8 // field_bits
    wpb = g * fpb
    if K % wpb != 0:
        if not pad:
            raise ValueError(
                f"elut_pack(b={b}, g={g}) needs K % {wpb} == 0, got K={K}")
        w = jnp.pad(w, ((0, 0), (0, (-K) % wpb)))  # weight 0 = digit offset
    offset = b // 2
    d = (w.astype(jnp.int32) + offset).reshape(M, -1, g)
    code = d[..., 0]
    for i in range(1, g):
        code = code * b + d[..., i]                # big-endian digits
    code = code.astype(jnp.uint8).reshape(M, -1, fpb)
    out = code[..., 0]
    for f in range(1, fpb):                        # little-endian fields
        out = out | (code[..., f] << (f * field_bits))
    return out


def elut_codes(p: jax.Array, field_bits: int) -> jax.Array:
    """[M, n_bytes] packed bytes -> [M, G] group codes (0..b^g-1)."""
    fpb = 8 // field_bits
    mask = (1 << field_bits) - 1
    fields = [((p >> (f * field_bits)) & mask).astype(jnp.uint8)
              for f in range(fpb)]
    return jnp.stack(fields, axis=-1).reshape(p.shape[0], -1)


def elut_unpack(p: jax.Array, k: int, b: int, g: int,
                field_bits: int) -> jax.Array:
    """Inverse of elut_pack -> [M, K] int8 codes (pad columns sliced off)."""
    code = elut_codes(p, field_bits).astype(jnp.int32)
    offset = b // 2
    digits = []
    for i in range(g):
        digits.append((code // (b ** (g - 1 - i))) % b - offset)
    w = jnp.stack(digits, axis=-1).reshape(p.shape[0], -1)
    return w[:, :k].astype(jnp.int8)


# ---------------------------------------------------------------------------
# Bit-contiguous code fields — true sub-byte bpw for non-power-of-two codes
# (DESIGN.md §11).  Codes of width ``code_bits`` are laid back to back in a
# little-endian bit stream; the decode granularity is one *unit* of
# ``unit_bytes = lcm(code_bits, 8) / 8`` bytes holding
# ``codes_per_unit = unit_bytes · 8 / code_bits`` whole codes, so every unit
# boundary is also a byte AND code boundary (no code ever spans units).
# int3's 6-bit codes: unit = 3 bytes = 4 codes = 8 weights → 3.0 bpw, the
# "3-byte/8-weight decode".
# ---------------------------------------------------------------------------


def bc_unit(code_bits: int) -> tuple[int, int]:
    """(unit_bytes, codes_per_unit) of the bit-contiguous stream.

    ``unit_bytes = lcm(code_bits, 8) / 8`` is the smallest byte count whose
    bit width is a whole number of codes; this is the invariant that lets a
    kernel walk the stream with static per-unit shift/OR decode only.
    """
    import math

    lcm = code_bits * 8 // math.gcd(code_bits, 8)
    return lcm // 8, lcm // code_bits


def elut_pack_bc(w: jax.Array, b: int, g: int, code_bits: int) -> jax.Array:
    """[M, K] int8 codes -> [M, (K/wpu)·unit_bytes] uint8, bit-contiguous.

    Layout invariants (normative; DESIGN.md §11 holds the design argument):

      * digit and code construction are IDENTICAL to :func:`elut_pack`
        (digit = weight + b//2, big-endian base-b code per g columns);
      * code c of a unit occupies bits [c·code_bits, (c+1)·code_bits) of
        the unit's little-endian bit stream (bit j of byte by is stream
        bit 8·by + j) — codes may span byte boundaries but never unit
        boundaries;
      * ``code_bits`` must hold a full code (b^g ≤ 2^code_bits) and K must
        be a multiple of wpu = codes_per_unit · g (no pad option: the unit
        IS the alignment quantum).
    """
    w = w.astype(jnp.int8)
    M, K = w.shape
    if b ** g > (1 << code_bits):
        raise ValueError(
            f"code_bits={code_bits} cannot hold base-{b} group-{g} codes")
    ub, cpu = bc_unit(code_bits)
    wpu = cpu * g
    if K % wpu != 0:
        raise ValueError(
            f"elut_pack_bc(b={b}, g={g}, code_bits={code_bits}) needs "
            f"K % {wpu} == 0, got K={K}")
    offset = b // 2
    d = (w.astype(jnp.int32) + offset).reshape(M, -1, g)
    code = d[..., 0]
    for i in range(1, g):
        code = code * b + d[..., i]                    # big-endian digits
    code = code.reshape(M, -1, cpu)                    # [M, units, cpu]
    out = [jnp.zeros(code.shape[:2], jnp.int32) for _ in range(ub)]
    for c in range(cpu):
        off = c * code_bits
        first, last = off // 8, (off + code_bits - 1) // 8
        for by in range(first, last + 1):
            sh = off - 8 * by   # code bit-0 position within byte ``by``
            part = code[..., c] << sh if sh >= 0 else code[..., c] >> -sh
            out[by] = out[by] | (part & 0xFF)
    return jnp.stack(out, axis=-1).astype(jnp.uint8).reshape(M, -1)


def elut_codes_bc(p: jax.Array, code_bits: int) -> jax.Array:
    """[M, n_bytes] bit-contiguous bytes -> [M, G] group codes (0..2^cb-1).

    Static shift/OR reassembly, one unit at a time — the same arithmetic
    the Pallas kernels inline, so the two decoders agree by construction.
    """
    ub, cpu = bc_unit(code_bits)
    pu = p.astype(jnp.int32).reshape(p.shape[0], -1, ub)
    mask = (1 << code_bits) - 1
    codes = []
    for c in range(cpu):
        off = c * code_bits
        first, last = off // 8, (off + code_bits - 1) // 8
        code = jnp.zeros(pu.shape[:2], jnp.int32)
        for by in range(first, last + 1):
            sh = 8 * by - off   # byte ``by``'s bit-0 position within the code
            pb = pu[..., by]
            code = code | (pb << sh if sh >= 0 else pb >> -sh)
        codes.append((code & mask).astype(jnp.uint8))
    return jnp.stack(codes, axis=-1).reshape(p.shape[0], -1)


def elut_unpack_bc(p: jax.Array, k: int, b: int, g: int,
                   code_bits: int) -> jax.Array:
    """Inverse of elut_pack_bc -> [M, K] int8 codes."""
    code = elut_codes_bc(p, code_bits).astype(jnp.int32)
    offset = b // 2
    digits = []
    for i in range(g):
        digits.append((code // (b ** (g - 1 - i))) % b - offset)
    w = jnp.stack(digits, axis=-1).reshape(p.shape[0], -1)
    return w[:, :k].astype(jnp.int8)


# ---------------------------------------------------------------------------
# Zero-occupancy metadata — per-block nonzero bitmap (DESIGN.md §11)
# ---------------------------------------------------------------------------


def occupancy_map(w: jax.Array, occ_block: int) -> jax.Array:
    """[M, K] int8 codes -> [M, K/occ_block] uint8 block-occupancy plane.

    Entry [m, j] is 1 iff any of ``w[m, j·occ_block : (j+1)·occ_block]`` is
    nonzero, 0 otherwise (a byte-map, not a packed bitmap: one uint8 per
    block keeps the plane directly indexable by the kernel's K walk; at
    occ_block = 64 it costs 8/64 = 0.125 bpw).  Layout invariants:

      * the block axis is K ascending, aligned with the packed code planes
        (block j covers the same columns as code bytes
        [j·occ_block/wpu·unit_bytes, ...) — ``occ_block`` must be a
        multiple of the format's weights-per-unit);
      * a 0 entry GUARANTEES the block's codes all decode to weight 0, so
        a kernel may skip the block: its contribution to any dot product
        is exactly zero and integer accumulation is order-independent —
        the skip walk is bit-identical to the dense walk by construction.
    """
    M, K = w.shape
    if K % occ_block != 0:
        raise ValueError(
            f"occupancy_map needs K % {occ_block} == 0, got K={K}")
    blk = w.reshape(M, K // occ_block, occ_block)
    return jnp.any(blk != 0, axis=-1).astype(jnp.uint8)


# ---------------------------------------------------------------------------
# Grouped-scale plane layout (module docstring: group-major [K//G, M])
# ---------------------------------------------------------------------------


def group_scale_shape(m: int, k: int, group_cols: int) -> tuple[int, int]:
    """Shape of the fp32 scale plane for an [M, K] weight at group size G."""
    if k % group_cols != 0:
        raise ValueError(
            f"grouped scales need K % {group_cols} == 0, got K={k}")
    return (k // group_cols, m)


def expand_group_scales(scale: jax.Array, k: int) -> jax.Array:
    """[K//G, M] scale plane -> per-element [M, K] fp32 (dequant references).

    Broadcasts each group row across its G columns; inverse of the grouping,
    used by the XLA unpack reference and the conformance harness's
    dequantized-weight oracle.
    """
    kg, m = scale.shape
    g = k // kg
    return jnp.repeat(scale.T.astype(jnp.float32), g, axis=1).reshape(m, k)


def col_slice_bytes(k0: int, k1: int, weights_per_unit: int,
                    unit_bytes: int) -> tuple[int, int]:
    """Packed-byte range [b0, b1) of a plane covering K-columns [k0, k1).

    Because every plane packs along K in consumption order and no code ever
    spans a decode unit, a K range that starts and ends on unit boundaries
    maps to a CONTIGUOUS byte range — the invariant that makes row-parallel
    (K) sharding of a PackedWeight a pure slice, never a repack
    (DESIGN.md §12).  Raises when a bound falls inside a unit.
    """
    if k0 % weights_per_unit or k1 % weights_per_unit:
        raise ValueError(
            f"K slice [{k0}, {k1}) not aligned to {weights_per_unit}-weight "
            "decode units; a mid-unit boundary would split a packed code")
    return (k0 // weights_per_unit * unit_bytes,
            k1 // weights_per_unit * unit_bytes)


# ---------------------------------------------------------------------------
# I2_S — 2-bit codes, 4 per byte
# ---------------------------------------------------------------------------

def i2s_pack(w: jax.Array) -> jax.Array:
    """[M, K] ternary int8 -> [M, K//4] uint8 (codes = w+1, little-endian).

    ELUT instance (b=3, g=1, 2-bit fields)."""
    if w.shape[1] % 4 != 0:
        raise ValueError(f"i2s_pack needs K % 4 == 0, got K={w.shape[1]}")
    return elut_pack(_check_ternary(w), 3, 1, 2)


def i2s_unpack(p: jax.Array, k: int) -> jax.Array:
    """[M, K//4] uint8 -> [M, K] int8 in {-1,0,1}."""
    return elut_unpack(p, k, 3, 1, 2)


# ---------------------------------------------------------------------------
# TL1 — base-3 pairs, 4-bit codes, 2 per byte
# ---------------------------------------------------------------------------

def tl1_pack(w: jax.Array) -> jax.Array:
    """[M, K] ternary -> [M, K//4] uint8; each nibble encodes 2 trits (0..8).

    ELUT instance (b=3, g=2, 4-bit fields)."""
    if w.shape[1] % 4 != 0:
        raise ValueError(f"tl1_pack needs K % 4 == 0, got K={w.shape[1]}")
    return elut_pack(_check_ternary(w), 3, 2, 4)


def tl1_unpack(p: jax.Array, k: int) -> jax.Array:
    return elut_unpack(p, k, 3, 2, 4)


def tl1_codes(p: jax.Array) -> jax.Array:
    """[M, K//4] packed bytes -> [M, K//2] 4-bit group codes (0..8)."""
    return elut_codes(p, 4)


# ---------------------------------------------------------------------------
# TL2 — element-wise mirror consolidation: sign plane + index plane
# ---------------------------------------------------------------------------

def tl2_encode_groups(w: jax.Array) -> tuple[jax.Array, jax.Array]:
    """[M, K] (K%3==0) -> (idx uint8 [M, K//3] in 0..13, sign uint8 [M, K//3])."""
    w = _check_ternary(w)
    M, K = w.shape
    if K % 3 != 0:
        raise ValueError(f"tl2 groups need K % 3 == 0, got K={K}")
    t = (w + 1).astype(jnp.int32).reshape(M, K // 3, 3)
    v = t[..., 0] * 9 + t[..., 1] * 3 + t[..., 2]          # 0..26
    sign = (v > TL2_CENTER).astype(jnp.uint8)               # mirror half
    idx = jnp.where(sign == 1, 26 - v, v).astype(jnp.uint8)  # 0..13
    return idx, sign


def tl2_pack(w: jax.Array) -> tuple[jax.Array, jax.Array]:
    """[M, K] ternary (K%24==0) -> (idx_plane [M, K//6] u8, sign_plane [M, K//24] u8).

    5 bits / 3 weights = 1.67 bpw, stored as two separately aligned planes —
    the paper's signed-unsigned weight splitting, which avoids the misaligned
    5-bit contiguous layout.
    """
    M, K = w.shape
    if K % 24 != 0:
        raise ValueError(f"tl2_pack needs K % 24 == 0, got K={K}")
    idx, sign = tl2_encode_groups(w)
    g = K // 3
    idx2 = idx.reshape(M, g // 2, 2)
    idx_plane = idx2[..., 0] | (idx2[..., 1] << 4)
    s8 = sign.reshape(M, g // 8, 8)
    sign_plane = jnp.zeros((M, g // 8), jnp.uint8)
    for b in range(8):
        sign_plane = sign_plane | (s8[..., b] << b)
    return idx_plane, sign_plane


def tl2_unpack_planes(idx_plane: jax.Array, sign_plane: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Planes -> (idx [M, G] 0..13, sign [M, G] 0/1)."""
    M = idx_plane.shape[0]
    lo = (idx_plane & 0xF).astype(jnp.uint8)
    hi = ((idx_plane >> 4) & 0xF).astype(jnp.uint8)
    idx = jnp.stack([lo, hi], axis=-1).reshape(M, -1)
    bits = [(sign_plane >> b) & 1 for b in range(8)]
    sign = jnp.stack(bits, axis=-1).reshape(M, -1).astype(jnp.uint8)
    return idx, sign


def tl2_unpack(idx_plane: jax.Array, sign_plane: jax.Array, k: int) -> jax.Array:
    """Planes -> [M, K] int8 ternary."""
    idx, sign = tl2_unpack_planes(idx_plane, sign_plane)
    v = jnp.where(sign == 1, 26 - idx.astype(jnp.int32), idx.astype(jnp.int32))
    d0 = v // 9 - 1
    d1 = (v // 3) % 3 - 1
    d2 = v % 3 - 1
    w = jnp.stack([d0, d1, d2], axis=-1).reshape(idx.shape[0], -1)
    return w[:, :k].astype(jnp.int8)


def tl2_split_k(k: int, bk3: int = 24) -> tuple[int, int]:
    """Block-fitting weight splitting (paper §3.1.2, Figure 6).

    Returns (three_k, two_k): three_k is the largest multiple of ``bk3``
    (itself a multiple of 24) ≤ K, handled by TL2; the remainder is handled
    by TL1.  Requires K % 4 == 0 so the TL1 tail packs cleanly.
    """
    if bk3 % 24 != 0:
        raise ValueError("bk3 must be a multiple of 24")
    if k % 4 != 0:
        raise ValueError(f"tl2_split_k needs K % 4 == 0, got K={k}")
    three_k = (k // bk3) * bk3
    return three_k, k - three_k


# ---------------------------------------------------------------------------
# TL2 kernel layout ("tl2k") — the TPU analogue of the paper's LUT-centric
# data layout.  Same 1.67 bpw planes, but groups are permuted per K-tile so
# the Pallas kernel decodes with static lane slices only (no interleaves):
#   * index plane: within a tile of G groups, byte j packs (idx[j], idx[G/2+j])
#     → the lo/hi nibble planes are each a *contiguous* half of the tile.
#   * sign plane: bit b of byte j is the sign of group b·G/8 + j
#     → ((plane >> b) & 1) is a contiguous G/8-wide lane slice.
# ---------------------------------------------------------------------------

TL2K_GTILE = 256  # groups per kernel K-tile (768 weights); deploy default 1024.


def tl2k_pack(w: jax.Array, g_tile: int = TL2K_GTILE) -> tuple[jax.Array, jax.Array]:
    """[M, K] ternary (K % (3·g_tile) == 0) -> (idx_plane [M, K/6], sign_plane [M, K/24])."""
    M, K = w.shape
    if g_tile % 8 != 0:
        raise ValueError("g_tile must be a multiple of 8")
    if K % (3 * g_tile) != 0:
        raise ValueError(f"tl2k_pack needs K % {3 * g_tile} == 0, got K={K}")
    idx, sign = tl2_encode_groups(w)
    g_total = K // 3
    t = g_total // g_tile
    idx_t = idx.reshape(M, t, 2, g_tile // 2)
    idx_plane = (idx_t[:, :, 0] | (idx_t[:, :, 1] << 4)).reshape(M, t * (g_tile // 2))
    sign_t = sign.reshape(M, t, 8, g_tile // 8)
    sign_plane = jnp.zeros((M, t, g_tile // 8), jnp.uint8)
    for b in range(8):
        sign_plane = sign_plane | (sign_t[:, :, b] << b)
    return idx_plane, sign_plane.reshape(M, t * (g_tile // 8))


def tl2k_unpack(idx_plane: jax.Array, sign_plane: jax.Array, k: int,
                g_tile: int = TL2K_GTILE) -> jax.Array:
    """Inverse of tl2k_pack -> [M, K] int8 ternary."""
    M = idx_plane.shape[0]
    g_total = k // 3
    t = g_total // g_tile
    ip = idx_plane.reshape(M, t, g_tile // 2)
    lo = (ip & 0xF).astype(jnp.uint8)
    hi = ((ip >> 4) & 0xF).astype(jnp.uint8)
    idx = jnp.concatenate([lo, hi], axis=-1).reshape(M, g_total)  # tile order restored
    sp = sign_plane.reshape(M, t, g_tile // 8)
    bits = [(sp >> b) & 1 for b in range(8)]
    sign = jnp.concatenate(bits, axis=-1).reshape(M, g_total).astype(jnp.int32)
    v = idx.astype(jnp.int32) * (1 - 2 * sign) + 26 * sign  # mirror decode
    d0 = v // 9 - 1
    d1 = (v // 3) % 3 - 1
    d2 = v % 3 - 1
    return jnp.stack([d0, d1, d2], axis=-1).reshape(M, k).astype(jnp.int8)


def tl2k_split_k(k: int, g_tile: int = TL2K_GTILE) -> tuple[int, int]:
    """Block-fitting split for the kernel layout: ThreeK % (3·g_tile) == 0."""
    if k % 4 != 0:
        raise ValueError(f"tl2k_split_k needs K % 4 == 0, got K={k}")
    bk3 = 3 * g_tile
    three_k = (k // bk3) * bk3
    return three_k, k - three_k


# ---------------------------------------------------------------------------
# TQ1-like — 5 trits per byte (idealized llama.cpp TQ1_0 baseline, 1.6 bpw)
# ---------------------------------------------------------------------------

def tq1_pack(w: jax.Array) -> jax.Array:
    """[M, K] ternary -> [M, ceil(K/5)] uint8 base-3 (zero padded).

    ELUT instance (b=3, g=5, byte fields) with weight-0 padding."""
    return elut_pack(_check_ternary(w), 3, 5, 8, pad=True)


def tq1_unpack(p: jax.Array, k: int) -> jax.Array:
    return elut_unpack(p, k, 3, 5, 8)


# ---------------------------------------------------------------------------
# eLUT construction (paper Eq. 3 / Algorithms 3–4)
# ---------------------------------------------------------------------------

def elut_build_lut(a_q: jax.Array, b: int, g: int) -> jax.Array:
    """int8 activations [..., K] (K%g==0) -> eLUT [..., K//g, b^g] int32.

    Entry c of group k is dot(a[gk:gk+g], digits(c)) where digits(c)
    enumerate the b^g base-b weight groups — the element-wise LUT of
    Algorithm 3, parametric in (b, g) (paper Appendix ELUT).
    """
    k = a_q.shape[-1]
    offset = b // 2
    a = a_q.astype(jnp.int32).reshape(*a_q.shape[:-1], k // g, g)
    codes = jnp.arange(b ** g, dtype=jnp.int32)
    lut = 0
    for i in range(g):
        d = (codes // (b ** (g - 1 - i))) % b - offset
        lut = lut + a[..., i : i + 1] * d
    return lut


def tl1_build_lut(a_q: jax.Array) -> jax.Array:
    """int8 activations [..., K] (K%2==0) -> eLUT [..., K//2, 9] int32.

    The ternary (b=3, g=2) instance of :func:`elut_build_lut` (Algorithm 3).
    """
    return elut_build_lut(a_q, 3, 2)


def tl2_build_lut(a_q: jax.Array) -> jax.Array:
    """int8 activations [..., K] (K%3==0) -> unsigned eLUT [..., K//3, 14] int32.

    14 entries via element-wise mirror consolidation (3^3/2 rounded up to the
    self-mirrored center); the sign bit is applied after lookup (Eq. 5).
    """
    k = a_q.shape[-1]
    a = a_q.astype(jnp.int32).reshape(*a_q.shape[:-1], k // 3, 3)
    v = jnp.arange(14, dtype=jnp.int32)  # unsigned half: 0..13
    d0 = v // 9 - 1
    d1 = (v // 3) % 3 - 1
    d2 = v % 3 - 1
    return a[..., 0:1] * d0 + a[..., 1:2] * d1 + a[..., 2:3] * d2
