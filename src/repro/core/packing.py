"""Ternary weight packing formats (paper §3, Table 1).

All formats store a weight matrix W of shape [M, K] with entries in
{-1, 0, +1} (int8).  Packing is along K (the contraction axis) so each
output row's packed bytes are contiguous — the TPU analogue of the paper's
LUT-centric data layout (packed bytes stream HBM→VMEM in the same order the
kernel consumes them).

Formats
-------
i2s   2.00 bpw  4 trits / byte, 2-bit codes            (paper I2_S)
tl1   2.00 bpw  2 trits → 4-bit code (3^2=9<16), 2 codes / byte  (paper TL1)
tl2   1.67 bpw  3 trits → 1-bit sign + 4-bit index (3^3/2=13.5<16)
                index plane: 2 idx / byte; sign plane: 8 signs / byte
                                                        (paper TL2, element-wise
                                                         mirror consolidation +
                                                         signed-unsigned split)
tq1   1.60 bpw  5 trits / byte, base-3 (3^5=243<256)    (llama.cpp TQ1_0-like
                                                         baseline, idealized)

``tl2`` requires K % 24 == 0; general K is handled by block-fitting weight
splitting (paper §3.1.2): ``tl2_split_k`` statically divides K into a ThreeK
part (multiple of 24, packed tl2) and a TwoK tail (packed tl1).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

TL2_CENTER = 13  # base-3 value of (0,0,0); values 0..13 keep sign=0, 14..26 mirror.


def _check_ternary(w: jax.Array) -> jax.Array:
    return w.astype(jnp.int8)


# ---------------------------------------------------------------------------
# I2_S — 2-bit codes, 4 per byte
# ---------------------------------------------------------------------------

def i2s_pack(w: jax.Array) -> jax.Array:
    """[M, K] ternary int8 -> [M, K//4] uint8 (codes = w+1, little-endian)."""
    w = _check_ternary(w)
    M, K = w.shape
    if K % 4 != 0:
        raise ValueError(f"i2s_pack needs K % 4 == 0, got K={K}")
    c = (w + 1).astype(jnp.uint8).reshape(M, K // 4, 4)
    return (c[..., 0] | (c[..., 1] << 2) | (c[..., 2] << 4) | (c[..., 3] << 6))


def i2s_unpack(p: jax.Array, k: int) -> jax.Array:
    """[M, K//4] uint8 -> [M, K] int8 in {-1,0,1}."""
    parts = [((p >> (2 * i)) & 0x3).astype(jnp.int8) - 1 for i in range(4)]
    w = jnp.stack(parts, axis=-1)  # [M, K//4, 4]
    return w.reshape(p.shape[0], -1)[:, :k]


# ---------------------------------------------------------------------------
# TL1 — base-3 pairs, 4-bit codes, 2 per byte
# ---------------------------------------------------------------------------

def tl1_pack(w: jax.Array) -> jax.Array:
    """[M, K] ternary -> [M, K//4] uint8; each nibble encodes 2 trits (0..8)."""
    w = _check_ternary(w)
    M, K = w.shape
    if K % 4 != 0:
        raise ValueError(f"tl1_pack needs K % 4 == 0, got K={K}")
    t = (w + 1).astype(jnp.uint8).reshape(M, K // 2, 2)
    code = t[..., 0] * 3 + t[..., 1]            # 0..8, fits a nibble
    code = code.reshape(M, K // 4, 2)
    return code[..., 0] | (code[..., 1] << 4)


def tl1_unpack(p: jax.Array, k: int) -> jax.Array:
    lo = (p & 0xF).astype(jnp.int8)
    hi = ((p >> 4) & 0xF).astype(jnp.int8)
    code = jnp.stack([lo, hi], axis=-1).reshape(p.shape[0], -1)  # [M, K//2]
    w0 = code // 3 - 1
    w1 = code % 3 - 1
    w = jnp.stack([w0, w1], axis=-1).reshape(p.shape[0], -1)
    return w[:, :k].astype(jnp.int8)


def tl1_codes(p: jax.Array) -> jax.Array:
    """[M, K//4] packed bytes -> [M, K//2] 4-bit group codes (0..8)."""
    lo = (p & 0xF).astype(jnp.uint8)
    hi = ((p >> 4) & 0xF).astype(jnp.uint8)
    return jnp.stack([lo, hi], axis=-1).reshape(p.shape[0], -1)


# ---------------------------------------------------------------------------
# TL2 — element-wise mirror consolidation: sign plane + index plane
# ---------------------------------------------------------------------------

def tl2_encode_groups(w: jax.Array) -> tuple[jax.Array, jax.Array]:
    """[M, K] (K%3==0) -> (idx uint8 [M, K//3] in 0..13, sign uint8 [M, K//3])."""
    w = _check_ternary(w)
    M, K = w.shape
    if K % 3 != 0:
        raise ValueError(f"tl2 groups need K % 3 == 0, got K={K}")
    t = (w + 1).astype(jnp.int32).reshape(M, K // 3, 3)
    v = t[..., 0] * 9 + t[..., 1] * 3 + t[..., 2]          # 0..26
    sign = (v > TL2_CENTER).astype(jnp.uint8)               # mirror half
    idx = jnp.where(sign == 1, 26 - v, v).astype(jnp.uint8)  # 0..13
    return idx, sign


def tl2_pack(w: jax.Array) -> tuple[jax.Array, jax.Array]:
    """[M, K] ternary (K%24==0) -> (idx_plane [M, K//6] u8, sign_plane [M, K//24] u8).

    5 bits / 3 weights = 1.67 bpw, stored as two separately aligned planes —
    the paper's signed-unsigned weight splitting, which avoids the misaligned
    5-bit contiguous layout.
    """
    M, K = w.shape
    if K % 24 != 0:
        raise ValueError(f"tl2_pack needs K % 24 == 0, got K={K}")
    idx, sign = tl2_encode_groups(w)
    g = K // 3
    idx2 = idx.reshape(M, g // 2, 2)
    idx_plane = idx2[..., 0] | (idx2[..., 1] << 4)
    s8 = sign.reshape(M, g // 8, 8)
    sign_plane = jnp.zeros((M, g // 8), jnp.uint8)
    for b in range(8):
        sign_plane = sign_plane | (s8[..., b] << b)
    return idx_plane, sign_plane


def tl2_unpack_planes(idx_plane: jax.Array, sign_plane: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Planes -> (idx [M, G] 0..13, sign [M, G] 0/1)."""
    M = idx_plane.shape[0]
    lo = (idx_plane & 0xF).astype(jnp.uint8)
    hi = ((idx_plane >> 4) & 0xF).astype(jnp.uint8)
    idx = jnp.stack([lo, hi], axis=-1).reshape(M, -1)
    bits = [(sign_plane >> b) & 1 for b in range(8)]
    sign = jnp.stack(bits, axis=-1).reshape(M, -1).astype(jnp.uint8)
    return idx, sign


def tl2_unpack(idx_plane: jax.Array, sign_plane: jax.Array, k: int) -> jax.Array:
    """Planes -> [M, K] int8 ternary."""
    idx, sign = tl2_unpack_planes(idx_plane, sign_plane)
    v = jnp.where(sign == 1, 26 - idx.astype(jnp.int32), idx.astype(jnp.int32))
    d0 = v // 9 - 1
    d1 = (v // 3) % 3 - 1
    d2 = v % 3 - 1
    w = jnp.stack([d0, d1, d2], axis=-1).reshape(idx.shape[0], -1)
    return w[:, :k].astype(jnp.int8)


def tl2_split_k(k: int, bk3: int = 24) -> tuple[int, int]:
    """Block-fitting weight splitting (paper §3.1.2, Figure 6).

    Returns (three_k, two_k): three_k is the largest multiple of ``bk3``
    (itself a multiple of 24) ≤ K, handled by TL2; the remainder is handled
    by TL1.  Requires K % 4 == 0 so the TL1 tail packs cleanly.
    """
    if bk3 % 24 != 0:
        raise ValueError("bk3 must be a multiple of 24")
    if k % 4 != 0:
        raise ValueError(f"tl2_split_k needs K % 4 == 0, got K={k}")
    three_k = (k // bk3) * bk3
    return three_k, k - three_k


# ---------------------------------------------------------------------------
# TL2 kernel layout ("tl2k") — the TPU analogue of the paper's LUT-centric
# data layout.  Same 1.67 bpw planes, but groups are permuted per K-tile so
# the Pallas kernel decodes with static lane slices only (no interleaves):
#   * index plane: within a tile of G groups, byte j packs (idx[j], idx[G/2+j])
#     → the lo/hi nibble planes are each a *contiguous* half of the tile.
#   * sign plane: bit b of byte j is the sign of group b·G/8 + j
#     → ((plane >> b) & 1) is a contiguous G/8-wide lane slice.
# ---------------------------------------------------------------------------

TL2K_GTILE = 256  # groups per kernel K-tile (768 weights); deploy default 1024.


def tl2k_pack(w: jax.Array, g_tile: int = TL2K_GTILE) -> tuple[jax.Array, jax.Array]:
    """[M, K] ternary (K % (3·g_tile) == 0) -> (idx_plane [M, K/6], sign_plane [M, K/24])."""
    M, K = w.shape
    if g_tile % 8 != 0:
        raise ValueError("g_tile must be a multiple of 8")
    if K % (3 * g_tile) != 0:
        raise ValueError(f"tl2k_pack needs K % {3 * g_tile} == 0, got K={K}")
    idx, sign = tl2_encode_groups(w)
    g_total = K // 3
    t = g_total // g_tile
    idx_t = idx.reshape(M, t, 2, g_tile // 2)
    idx_plane = (idx_t[:, :, 0] | (idx_t[:, :, 1] << 4)).reshape(M, t * (g_tile // 2))
    sign_t = sign.reshape(M, t, 8, g_tile // 8)
    sign_plane = jnp.zeros((M, t, g_tile // 8), jnp.uint8)
    for b in range(8):
        sign_plane = sign_plane | (sign_t[:, :, b] << b)
    return idx_plane, sign_plane.reshape(M, t * (g_tile // 8))


def tl2k_unpack(idx_plane: jax.Array, sign_plane: jax.Array, k: int,
                g_tile: int = TL2K_GTILE) -> jax.Array:
    """Inverse of tl2k_pack -> [M, K] int8 ternary."""
    M = idx_plane.shape[0]
    g_total = k // 3
    t = g_total // g_tile
    ip = idx_plane.reshape(M, t, g_tile // 2)
    lo = (ip & 0xF).astype(jnp.uint8)
    hi = ((ip >> 4) & 0xF).astype(jnp.uint8)
    idx = jnp.concatenate([lo, hi], axis=-1).reshape(M, g_total)  # tile order restored
    sp = sign_plane.reshape(M, t, g_tile // 8)
    bits = [(sp >> b) & 1 for b in range(8)]
    sign = jnp.concatenate(bits, axis=-1).reshape(M, g_total).astype(jnp.int32)
    v = idx.astype(jnp.int32) * (1 - 2 * sign) + 26 * sign  # mirror decode
    d0 = v // 9 - 1
    d1 = (v // 3) % 3 - 1
    d2 = v % 3 - 1
    return jnp.stack([d0, d1, d2], axis=-1).reshape(M, k).astype(jnp.int8)


def tl2k_split_k(k: int, g_tile: int = TL2K_GTILE) -> tuple[int, int]:
    """Block-fitting split for the kernel layout: ThreeK % (3·g_tile) == 0."""
    if k % 4 != 0:
        raise ValueError(f"tl2k_split_k needs K % 4 == 0, got K={k}")
    bk3 = 3 * g_tile
    three_k = (k // bk3) * bk3
    return three_k, k - three_k


# ---------------------------------------------------------------------------
# TQ1-like — 5 trits per byte (idealized llama.cpp TQ1_0 baseline, 1.6 bpw)
# ---------------------------------------------------------------------------

def tq1_pack(w: jax.Array) -> jax.Array:
    """[M, K] ternary -> [M, ceil(K/5)] uint8 base-3 (zero padded)."""
    w = _check_ternary(w)
    M, K = w.shape
    pad = (-K) % 5
    t = jnp.pad((w + 1).astype(jnp.int32), ((0, 0), (0, pad)), constant_values=1)
    t = t.reshape(M, -1, 5)
    v = t[..., 0]
    for i in range(1, 5):
        v = v * 3 + t[..., i]
    return v.astype(jnp.uint8)


def tq1_unpack(p: jax.Array, k: int) -> jax.Array:
    v = p.astype(jnp.int32)
    digits = []
    for _ in range(5):
        digits.append(v % 3 - 1)
        v = v // 3
    w = jnp.stack(digits[::-1], axis=-1).reshape(p.shape[0], -1)
    return w[:, :k].astype(jnp.int8)


# ---------------------------------------------------------------------------
# eLUT construction (paper Eq. 3 / Algorithms 3–4)
# ---------------------------------------------------------------------------

def tl1_build_lut(a_q: jax.Array) -> jax.Array:
    """int8 activations [..., K] (K%2==0) -> eLUT [..., K//2, 9] int32.

    Entry c of group k is dot(a[2k:2k+2], digits(c)) where digits(c) enumerate
    the 3^2 ternary pairs — the element-wise LUT of Algorithm 3.
    """
    k = a_q.shape[-1]
    a = a_q.astype(jnp.int32).reshape(*a_q.shape[:-1], k // 2, 2)
    codes = jnp.arange(9, dtype=jnp.int32)
    d0 = codes // 3 - 1
    d1 = codes % 3 - 1
    return a[..., 0:1] * d0 + a[..., 1:2] * d1


def tl2_build_lut(a_q: jax.Array) -> jax.Array:
    """int8 activations [..., K] (K%3==0) -> unsigned eLUT [..., K//3, 14] int32.

    14 entries via element-wise mirror consolidation (3^3/2 rounded up to the
    self-mirrored center); the sign bit is applied after lookup (Eq. 5).
    """
    k = a_q.shape[-1]
    a = a_q.astype(jnp.int32).reshape(*a_q.shape[:-1], k // 3, 3)
    v = jnp.arange(14, dtype=jnp.int32)  # unsigned half: 0..13
    d0 = v // 9 - 1
    d1 = (v // 3) % 3 - 1
    d2 = v % 3 - 1
    return a[..., 0:1] * d0 + a[..., 1:2] * d1 + a[..., 2:3] * d2
