"""Quantization schemes for ternary LLMs (BitNet b1.58 alignment).

The paper's losslessness argument (§2.1, Figure 2) is that BitNet b1.58 is
trained with QAT under two exact constraints:

  * weights:     per-tensor absmean ternary  w_q = clip(round(w/s_w), -1, 1),
                 s_w = mean(|w|)
  * activations: per-tensor absmax int8      x_q = clip(round(x/s_x), -128, 127),
                 s_x = max(|x|) / 127

If inference reproduces exactly this quantized forward (integer accumulation,
same scale granularity), the inference logits are bit-identical to the QAT
training forward — "lossless" in the paper's sense.  llama.cpp's TQ kernels
break the activation constraint (per-256-block Q8_K quantization); we
implement that scheme too (``q8_block``) as the lossy baseline.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# BitNet b1.58 uses the symmetric int8 range for activations.
ACT_QMAX = 127.0
EPS = 1e-6


# ---------------------------------------------------------------------------
# Weight quantization: per-tensor absmean ternary (BitNet b1.58 training rule)
# ---------------------------------------------------------------------------

def absmean_scale(w: jax.Array) -> jax.Array:
    """Per-tensor weight scale: mean of absolute values (scalar, fp32)."""
    return jnp.maximum(jnp.mean(jnp.abs(w.astype(jnp.float32))), EPS)


def absmean_lowbit(w: jax.Array, lo: int, hi: int) -> tuple[jax.Array, jax.Array]:
    """Quantize weights to integer levels [lo, hi] with a per-tensor absmean
    scale — the b1.58 rule generalized to arbitrary low-bit alphabets (ELUT
    formats: int2 -> [-2, 1], int3 -> [-4, 3]).

    Returns (w_q int8, scale fp32 scalar).  Dequant: w ≈ w_q * s.
    """
    s = absmean_scale(w)
    w_q = jnp.clip(jnp.round(w.astype(jnp.float32) / s), float(lo), float(hi))
    return w_q.astype(jnp.int8), s


def absmean_lowbit_grouped(
    w: jax.Array, lo: int, hi: int, group_cols: int
) -> tuple[jax.Array, jax.Array]:
    """Per-group absmean quantization: one scale per ``group_cols``-column
    group along K (the contraction axis) per output row — the granularity of
    GPTQ/AWQ-style group-quantized checkpoints, applied to the b1.58 absmean
    rule.

    w: fp [M, K] with K % group_cols == 0.  Returns
    (w_q int8 [M, K], scale fp32 [K//group_cols, M]) — the scale layout is
    group-major (``packing`` module docstring): dequant is
    ``w[m, k] ≈ w_q[m, k] · s[k // group_cols, m]``.
    """
    M, K = w.shape
    if K % group_cols != 0:
        raise ValueError(
            f"grouped absmean needs K % {group_cols} == 0, got K={K}")
    w32 = w.astype(jnp.float32).reshape(M, K // group_cols, group_cols)
    s = jnp.maximum(jnp.mean(jnp.abs(w32), axis=-1), EPS)     # [M, K/G]
    w_q = jnp.clip(jnp.round(w32 / s[..., None]), float(lo), float(hi))
    return w_q.reshape(M, K).astype(jnp.int8), s.T.astype(jnp.float32)


def ternary_quant(w: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Quantize weights to ternary {-1, 0, 1} with a per-tensor absmean scale.

    Returns (w_t int8 in {-1,0,1}, scale fp32 scalar).  Dequant: w ≈ w_t * s.
    """
    return absmean_lowbit(w, -1, 1)


def ternary_fake_quant(w: jax.Array) -> jax.Array:
    """Straight-through-estimator fake quant used during QAT training.

    Forward: dequantized ternary weights.  Backward: identity (STE).
    """
    w_t, s = ternary_quant(w)
    w_dq = w_t.astype(w.dtype) * s.astype(w.dtype)
    return w + jax.lax.stop_gradient(w_dq - w)


# ---------------------------------------------------------------------------
# Activation quantization
# ---------------------------------------------------------------------------

def absmax_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-tensor absmax int8 quantization (the lossless scheme).

    Returns (x_q int8, scale fp32 scalar) with x ≈ x_q * scale.
    """
    x32 = x.astype(jnp.float32)
    s = jnp.maximum(jnp.max(jnp.abs(x32)), EPS) / ACT_QMAX
    x_q = jnp.clip(jnp.round(x32 / s), -ACT_QMAX, ACT_QMAX)
    return x_q.astype(jnp.int8), s


def absmax_int8_per_token(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-token (last-dim-grouped) absmax int8 quantization.

    Not the b1.58 training scheme — provided for the throughput/quality
    trade-off study; scale has shape x.shape[:-1] + (1,).
    """
    x32 = x.astype(jnp.float32)
    s = jnp.maximum(jnp.max(jnp.abs(x32), axis=-1, keepdims=True), EPS) / ACT_QMAX
    x_q = jnp.clip(jnp.round(x32 / s), -ACT_QMAX, ACT_QMAX)
    return x_q.astype(jnp.int8), s


def q8_block(x: jax.Array, block: int = 256) -> tuple[jax.Array, jax.Array]:
    """llama.cpp Q8_K-style per-block activation quantization (lossy baseline).

    The last dim is split into ``block``-sized groups, each with its own
    absmax scale.  This is the scheme that prevents TQ1_0/TQ2_0 from being
    lossless for BitNet b1.58 (paper §2.3).  Requires last dim % block == 0.
    """
    if x.shape[-1] % block != 0:
        raise ValueError(f"q8_block needs last dim % {block} == 0, got {x.shape}")
    x32 = x.astype(jnp.float32)
    g = x32.reshape(*x32.shape[:-1], x32.shape[-1] // block, block)
    s = jnp.maximum(jnp.max(jnp.abs(g), axis=-1, keepdims=True), EPS) / ACT_QMAX
    q = jnp.clip(jnp.round(g / s), -ACT_QMAX, ACT_QMAX).astype(jnp.int8)
    return q.reshape(x.shape), s.squeeze(-1)


def act_fake_quant(x: jax.Array) -> jax.Array:
    """STE fake quant of activations (per-tensor absmax), for QAT training.

    Preserves x.dtype (bf16 at scale) so the backward residuals stay compact.
    """
    x_q, s = absmax_int8(x)
    x_dq = (x_q.astype(jnp.float32) * s).astype(x.dtype)
    return x + jax.lax.stop_gradient(x_dq - x)
