"""mpGEMM: int8 activations × packed low-bit weights (paper §3).

Canonical semantics (all formats): y = (x_q @ W_q^T) · (s_x · s_w), with the
contraction accumulated in int32 (the TPU MXU's native int8×int8→int32 path).
This module holds the pure-XLA implementations; the Pallas TPU kernels in
``repro.kernels`` implement the same contracts with fused in-VMEM decode and
are validated against these references.

Kernel selection lives in ``repro.core.dispatch`` (DESIGN.md §5): every
implementation here and in ``repro.kernels`` registers its (fmt, regime,
backend) capabilities there, and ``dispatch.mpgemm`` picks per shape.  The
XLA implementations:
  * ``mpgemm_xla`` — unpack packed codes to int8 [M, K] then dot (canonical
    reference; materializes the unpacked operand at HLO level), or the
    XLA-native int4 dot (no unpack intermediate; 4 bpw HBM traffic).
  * ``repro.core.elut.elut_mpgemm`` — the parametric element-wise-LUT path
    (Algorithm 3 generalized to any (b, g); tl1 = (3, 2), int2 = (4, 2),
    int3 = (8, 2)); ``tl1_lut`` here is its ternary alias.
  * ``tl2_lut`` — the mirror-consolidated variant (Algorithm 4): folded
    14-entry unsigned table + 1-bit sign plane, TL1 tail via block-fitting.

The lossy ``_0`` variants requantize the LUT to int8 (the T-MAC scheme
§3.2.1); lossless ``_1`` variants accumulate the int32 table exactly (the
int16 pack-and-unpack technique at its natural XLA precision).

All call sites route through ``repro.core.dispatch.mpgemm`` with a
``KernelPlan``; the pre-registry ``impl=``/``lut=`` string shim is gone.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import elut, packing
from repro.core.qtensor import PackedWeight, unpack_weight


def _int_dot(x_q: jax.Array, w_t: jax.Array) -> jax.Array:
    """int8 [..., K] × int8 [M, K] -> int32 [..., M]."""
    return jax.lax.dot_general(
        x_q,
        w_t,
        (((x_q.ndim - 1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32,
    )


def grouped_int_dot(x_q: jax.Array, w_t: jax.Array, scale: jax.Array) -> jax.Array:
    """Segment-sum contraction for per-group weight scales (DESIGN.md §2).

    The K reduction is split at group boundaries: [..., S, G] × [M, S, G]
    per-group int32 partials (exact), each scaled by its fp32 group scale
    ``scale[s, m]``, then summed — scale application at ACCUMULATOR
    granularity, never per element.  Returns fp32 [..., M] (weight scales
    applied; the caller multiplies the activation scale).
    """
    s_groups, m = scale.shape
    k = x_q.shape[-1]
    g = k // s_groups
    xs = x_q.astype(jnp.int32).reshape(*x_q.shape[:-1], s_groups, g)
    ws = w_t.astype(jnp.int32).reshape(m, s_groups, g)
    p32 = jnp.einsum("...sk,msk->...sm", xs, ws)
    return (p32.astype(jnp.float32) * scale).sum(axis=-2)


def mpgemm_xla(x_q: jax.Array, s_x: jax.Array, pw: PackedWeight) -> jax.Array:
    """Canonical reference: unpack + int dot + rescale.  Returns fp32 [..., M]."""
    if pw.fmt == "fp":
        return jnp.dot(x_q.astype(jnp.float32) * s_x, pw.planes["w"].T.astype(jnp.float32))
    if pw.fmt == "int4":
        # XLA-native sub-byte dtype: the dot consumes int4 directly.
        y32 = jax.lax.dot_general(
            x_q,
            pw.planes["w4"],
            (((x_q.ndim - 1,), (1,)), ((), ())),
            preferred_element_type=jnp.int32,
        )
    elif pw.scale.ndim:  # grouped weight scales: split K at group boundaries
        y = grouped_int_dot(x_q, unpack_weight(pw), pw.scale)
        return y * jnp.asarray(s_x, jnp.float32)
    else:
        y32 = _int_dot(x_q, unpack_weight(pw))
    return y32.astype(jnp.float32) * (jnp.asarray(s_x, jnp.float32) * pw.scale)


# ---------------------------------------------------------------------------
# LUT-semantics references (Algorithms 3 & 4)
# ---------------------------------------------------------------------------

def tl1_lut(x_q: jax.Array, s_x: jax.Array, pw: PackedWeight, lossless: bool = True) -> jax.Array:
    """TL1 mpGEMM via element-wise LUT (Algorithm 3) — the ternary (3, 2)
    instance of :func:`repro.core.elut.elut_mpgemm`.

    lossless=True  -> TL1_1 (int16/int32 pack-and-unpack accumulation)
    lossless=False -> TL1_0 (LUT requantized to int8; T-MAC-style, lossy)
    """
    if pw.fmt != "tl1":
        raise ValueError(f"tl1_lut needs tl1 weights, got {pw.fmt}")
    return elut.elut_mpgemm(x_q, s_x, pw, lossless=lossless)


def tl2_lut(x_q: jax.Array, s_x: jax.Array, pw: PackedWeight, lossless: bool = True) -> jax.Array:
    """TL2 mpGEMM via mirror-consolidated LUT + 1-bit sign op (Algorithm 4).

    The ThreeK prefix uses the 14-entry unsigned LUT with the sign applied via
    ``x = sign XOR (sign + x)`` (Eq. 5 — here expressed as a select, which is
    what the XOR-ADD trick computes); the TwoK tail falls back to TL1
    (block-fitting weight splitting).
    """
    if pw.fmt != "tl2":
        raise ValueError(f"tl2_lut needs tl2 weights, got {pw.fmt}")
    s_x = jnp.asarray(s_x, jnp.float32)
    out = None
    if pw.three_k:
        x3 = x_q[..., : pw.three_k]
        lut = packing.tl2_build_lut(x3)            # [..., G, 14] int32 (unsigned half)
        idx, sign = packing.tl2_unpack_planes(pw.planes["idx"], pw.planes["sign"])
        y32, s_lut = _lut_accumulate_signed(lut, idx.astype(jnp.int32), sign, lossless)
        out = y32.astype(jnp.float32) * (s_lut * s_x * pw.scale)
    if pw.three_k < pw.k:
        x2 = x_q[..., pw.three_k:]
        tail = PackedWeight({"p": pw.planes["tail"]}, pw.scale, "tl1", (pw.m, pw.k - pw.three_k))
        y_tail = tl1_lut(x2, s_x, tail, lossless)
        out = y_tail if out is None else out + y_tail
    return out


def _lut_accumulate_signed(
    lut: jax.Array, idx: jax.Array, sign: jax.Array, lossless: bool
) -> tuple[jax.Array, jax.Array]:
    if not lossless:
        lut, s_lut = elut.quantize_lut(lut)
    else:
        s_lut = jnp.float32(1.0)
    onehot = jax.nn.one_hot(idx, lut.shape[-1], dtype=jnp.int8).astype(jnp.int32)
    # Fold the 1-bit sign into the one-hot (equivalent to Eq. 5 post-lookup).
    signed = onehot * (1 - 2 * sign.astype(jnp.int32))[..., None]
    y32 = jnp.einsum("...gc,mgc->...m", lut.astype(jnp.int32), signed)
    return y32, s_lut


# ---------------------------------------------------------------------------
# Per-block (Q8_K-style) activation variant — the lossy llama.cpp scheme
# ---------------------------------------------------------------------------

def mpgemm_q8_block(
    x_q: jax.Array, s_x_blocks: jax.Array, pw: PackedWeight, block: int = 256
) -> jax.Array:
    """mpGEMM with per-256-block activation scales (TQ-kernel semantics).

    x_q: int8 [..., K]; s_x_blocks: fp32 [..., K/block].  The per-block scale
    must multiply each block's partial sum — this is what breaks bit-exact
    alignment with the b1.58 per-tensor training scheme (paper §2.3).

    Grouped-weight-scale formats compose: the reduction splits at the
    FINEST common boundary seg = gcd(act block, weight group) so both the
    activation block scale and the weight group scale multiply exact int32
    partials.
    """
    import math

    w_t = unpack_weight(pw).astype(jnp.int8)
    K = x_q.shape[-1]
    if pw.scale.ndim:
        g_w = K // pw.scale.shape[0]
        seg = math.gcd(block, g_w)
        ns = K // seg
        xb = x_q.reshape(*x_q.shape[:-1], ns, seg)
        wb = w_t.reshape(w_t.shape[0], ns, seg)
        p32 = jnp.einsum("...nk,mnk->...nm",
                         xb.astype(jnp.int32), wb.astype(jnp.int32))
        s_act = jnp.repeat(s_x_blocks, block // seg, axis=-1)     # [..., ns]
        s_w = jnp.repeat(pw.scale, g_w // seg, axis=0)            # [ns, M]
        return (p32.astype(jnp.float32) * s_act[..., None] * s_w).sum(axis=-2)
    nb = K // block
    xb = x_q.reshape(*x_q.shape[:-1], nb, block)
    wb = w_t.reshape(w_t.shape[0], nb, block)
    # [..., nb, M] int32 partials, scaled per block, then summed.
    p32 = jnp.einsum("...nk,mnk->...nm", xb.astype(jnp.int32), wb.astype(jnp.int32))
    y = (p32.astype(jnp.float32) * s_x_blocks[..., None]).sum(axis=-2)
    return y * pw.scale
