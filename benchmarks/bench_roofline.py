"""Roofline aggregation: turn results/dryrun/*.json into the §Roofline table.

One row per (arch × shape × mesh): three terms, dominant bound, model-flops
ratio, and the step-time estimate.  Writes results/roofline.md for
EXPERIMENTS.md inclusion and returns CSV rows for the bench harness.
"""

from __future__ import annotations

import glob
import json
import os


def load(out_dir: str = "results/dryrun") -> list:
    recs = []
    for f in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def fraction(rec: dict) -> float:
    """Roofline fraction: useful model-flops time / modeled step time."""
    ideal = rec["model"]["model_flops_per_device"] / 197e12
    return ideal / max(rec["terms"]["step_s"], 1e-12)


def markdown(recs: list) -> str:
    lines = [
        "| arch | shape | mesh | compute s | memory s | collective s | bound "
        "| step s | useful-flop frac | roofline frac |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"], len(r["mesh"]))):
        t = r["terms"]
        tag = f" [{r['tag']}]" if r.get("tag") else ""
        lines.append(
            f"| {r['arch']}{tag} | {r['shape']} | {'×'.join(map(str, r['mesh']))} "
            f"| {t['compute_s']:.3g} | {t['memory_s']:.3g} | {t['collective_s']:.3g} "
            f"| {t['bound'].replace('_s','')} | {t['step_s']:.3g} "
            f"| {r['model']['useful_flop_frac']:.2f} | {fraction(r):.3f} |"
        )
    return "\n".join(lines)


def run() -> list:
    recs = load()
    os.makedirs("results", exist_ok=True)
    with open("results/roofline.md", "w") as f:
        f.write(markdown(recs) + "\n")
    rows = []
    for r in recs:
        mesh = "pod2" if len(r["mesh"]) == 3 else "pod1"
        tag = f"_{r['tag']}" if r.get("tag") else ""
        rows.append((
            f"roofline_{r['arch']}_{r['shape']}_{mesh}{tag}",
            r["terms"]["step_s"] * 1e6,
            f"bound={r['terms']['bound'].replace('_s','')}_frac{fraction(r):.3f}",
        ))
    return rows
