"""Shared CLI scaffolding for the benchmark smoke gates (CI).

Both gates (``bench_mpgemm --smoke``, ``bench_serve --smoke``) follow the
same protocol: run a reduced sweep into a gitignored ``*.smoke.new.json``
scratch artifact (committed artifacts are never clobbered), compare it
against a committed ``*.smoke.json`` baseline with a suite-specific
``check_regression(old_blob, new_blob)``, and — because single-pass
timings jitter well past any sane factor under CI-runner contention —
confirm TIMING failures on one independent re-sweep before tripping,
while schema/identity failures always fail.  This module is the ONE home
of that protocol; the suites supply only their sweep and their checker.

It is also the home of the OBSERVABILITY schema checks (DESIGN.md §9):
``python -m benchmarks.smoke_gate --check-obs --trace trace.json
--metrics metrics.json`` validates the launcher's ``--trace-out`` /
``--metrics-json`` artifacts — CI runs it after the serving smoke so a
drifted trace-event or metrics-snapshot shape fails the build instead of
silently shipping files Perfetto or a scraper cannot read.
"""

from __future__ import annotations

import json
import os


def share_of_total(pairs: list) -> dict:
    """(key, value) pairs → key → value / total.

    The gates compare each cell's *share* of the sweep, not raw time:
    normalizing by the whole sweep's aggregate cancels machine speed and
    load, which raw microseconds — and small single-cell denominators —
    do not.  Empty / all-zero input → {} (nothing gateable)."""
    total = sum(v for _, v in pairs)
    if not total:
        return {}
    return {k: v / total for k, v in pairs}


def check_cells(old_blob: dict, new_blob: dict, *, cell_key, cell_keys: set,
                normalized, factor: float, extra_cell_checks=(),
                timing_keys=None) -> list:
    """The shared gate checks; returns (kind, key, message) failures.

    * cell-schema drift — a cell missing expected keys OR carrying unknown
      ones (renames look like one of each) always fails;
    * baseline coverage — every baseline cell must still be swept: a cell
      silently dropping out of the sweep is the headline regression these
      gates exist to catch;
    * timing — share-normalized ratios (see :func:`share_of_total`) beyond
      ``factor``, compared only when backends match (cross-backend timings
      are not comparable).  Callers re-sweep to confirm these
      (:func:`gate_main`) because single-pass timings jitter.

    ``extra_cell_checks``: suite-specific callables ``cell -> [failures]``
    (e.g. the serving gate's token-identity check).
    """
    failures = []
    for c in new_blob.get("cells", []):
        missing = cell_keys - set(c)
        extra = set(c) - cell_keys
        if missing:
            failures.append(("schema", cell_key(c),
                             f"cell {cell_key(c)} missing keys {sorted(missing)}"))
        if extra:  # update the suite's CELL_KEYS with any schema change so
            #        the gate validates the new shape
            failures.append(("schema", cell_key(c),
                             f"cell {cell_key(c)} has unknown keys {sorted(extra)}"))
        for chk in extra_cell_checks:
            failures.extend(chk(c))
    new_keys = {cell_key(c) for c in new_blob.get("cells", [])}
    for c in old_blob.get("cells", []):
        if cell_key(c) not in new_keys:
            failures.append(("schema", cell_key(c),
                             f"baseline cell {cell_key(c)} missing from the "
                             "fresh sweep (cell dropped?)"))
    if old_blob.get("backend") != new_blob.get("backend"):
        return failures
    old_ratios = normalized(old_blob)
    new_ratios = normalized(new_blob)
    for key, new_r in new_ratios.items():
        old_r = old_ratios.get(key)
        if old_r and new_r > factor * old_r:
            failures.append(
                ("timing", key,
                 f"cell {key}: {100 * new_r:.2f}% of sweep vs "
                 f"{100 * old_r:.2f}% committed (> {factor}x regression)"))
    # a baseline timing key vanishing from a still-present cell (e.g. a
    # kernel dropping out of a cell's candidate set) is a coverage
    # regression the cell-level check cannot see.  Presence is judged on
    # the RAW sweep (``timing_keys(blob)``), not the noise-filtered
    # ``normalized`` view: a cell drifting under a suite's noise-floor
    # cutoff as machines change speed must not read as a dropped kernel.
    # Classified "timing" so gate_main's re-sweep confirms it.
    present = (set(new_ratios) if timing_keys is None
               else timing_keys(new_blob))
    for key in old_ratios:
        if key not in present:
            failures.append(
                ("timing", key,
                 f"baseline timing key {key} missing from the fresh sweep "
                 "(kernel dropped from the cell's candidate set?)"))
    return failures


def gate_main(argv: list | None, *, tag: str, run, check_regression,
              baseline: str, out: str, factor: float,
              smoke_help: str) -> int:
    """The gate CLI: ``--smoke`` (sweep + gate) / ``--update-baseline``.

    ``run(smoke, artifact=None)`` performs the sweep and yields CSV rows;
    ``check_regression(old, new)`` returns (kind, key, message) failures
    where only ``kind == "timing"`` entries need re-sweep confirmation.
    """
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help=f"{smoke_help} (written to the gitignored {out}; "
                         "committed artifacts are never overwritten) + "
                         f"gate vs the committed {baseline} (CI)")
    ap.add_argument("--update-baseline", action="store_true",
                    help=f"write the smoke sweep to {baseline} (refreshing "
                         "the committed gate baseline) instead of gating; "
                         "implies --smoke")
    args = ap.parse_args(argv)

    if args.update_baseline:
        for name, us, derived in run(smoke=True, artifact=baseline):
            print(f"{name},{us:.1f},{derived}")
        return 0
    old_blob = None
    if args.smoke:
        if not os.path.exists(baseline):
            # the baseline's absence in CI is itself a defect — a green
            # step that checked nothing is worse than a red one
            print(f"[{tag}] FAIL: committed {baseline} not found; run "
                  "--update-baseline on an idle machine and commit the "
                  "result")
            return 1
        with open(baseline) as f:
            old_blob = json.load(f)
    for name, us, derived in run(smoke=args.smoke):
        print(f"{name},{us:.1f},{derived}")
    if old_blob is None:
        return 0
    with open(out) as f:
        new_blob = json.load(f)
    failures = check_regression(old_blob, new_blob)
    if any(kind == "timing" for kind, _, _ in failures):
        print(f"[{tag}] {len(failures)} candidate failure(s); re-sweeping "
              "to filter measurement noise")
        run(smoke=True)
        with open(out) as f:
            second_blob = json.load(f)
        confirmed = {key for kind, key, _ in
                     check_regression(old_blob, second_blob)
                     if kind == "timing"}
        failures = [f for f in failures
                    if f[0] != "timing" or f[1] in confirmed]
    for _, _, msg in failures:
        print(f"[{tag}] REGRESSION: {msg}")
    if failures:
        return 1
    print(f"[{tag}] smoke gate ok ({len(new_blob['cells'])} cells, no "
          f"schema drift, no reproducible >{factor}x cell regression)")
    return 0


# ---------------------------------------------------------------------------
# Observability artifact schema checks (DESIGN.md §9)
# ---------------------------------------------------------------------------

# Chrome trace-event format: what Perfetto/chrome://tracing require per event
TRACE_EVENT_KEYS = {"name", "ph", "ts", "pid", "tid"}
TRACE_PHASES = {"X", "i", "M"}      # complete | instant | metadata
TRACE_REQUIRED_SPANS = {"tick", "decode"}  # every serve trace has these
MVP_ROW_KEYS = {  # measured_vs_predicted rows (repro.obs.kernels.report)
    "kernel", "fmt", "M", "K", "N_bucket", "calls", "compile_calls",
    "compile_s", "execute_s", "measured_us_per_call",
    "predicted_us_per_call", "measured_over_predicted",
    "predicted_hbm_bytes_per_call", "measured_gb_s",
    "predicted_mxu_inflation"}
DECISION_KEYS = {"fmt", "regime", "n", "k", "m", "kernel", "source", "seq"}


def check_trace_blob(blob: dict) -> list:
    """Validate a ``--trace-out`` file; returns message strings."""
    failures = []
    events = blob.get("traceEvents")
    if not isinstance(events, list) or not events:
        return [f"traceEvents missing or empty (got {type(events).__name__})"]
    names = set()
    for i, e in enumerate(events):
        missing = TRACE_EVENT_KEYS - set(e)
        if missing:
            failures.append(f"event {i} missing keys {sorted(missing)}")
            continue
        if e["ph"] not in TRACE_PHASES:
            failures.append(f"event {i} has unknown phase {e['ph']!r}")
        if e["ph"] == "X" and not (isinstance(e.get("dur"), (int, float))
                                   and e["dur"] >= 0):
            failures.append(f"span event {i} ({e['name']!r}) needs dur >= 0")
        names.add(e["name"])
    for want in TRACE_REQUIRED_SPANS - names:
        failures.append(f"required span {want!r} absent from the trace "
                        f"(saw {sorted(names)})")
    return failures


def check_metrics_blob(blob: dict) -> list:
    """Validate a ``--metrics-json`` file; returns message strings."""
    failures = []
    m = blob.get("metrics")
    if not isinstance(m, dict):
        failures.append("metrics section missing")
    else:
        for kind in ("counters", "gauges", "histograms"):
            if not isinstance(m.get(kind), dict):
                failures.append(f"metrics.{kind} missing or not a mapping")
    d = blob.get("dispatch")
    if not isinstance(d, dict):
        failures.append("dispatch section missing")
    else:
        dropped = d.get("decisions_dropped")
        if not (isinstance(dropped, int) and dropped >= 0):
            failures.append(
                f"dispatch.decisions_dropped must be an int >= 0, "
                f"got {dropped!r}")
        decs = d.get("decisions")
        if not isinstance(decs, list):
            failures.append("dispatch.decisions missing or not a list")
        else:
            for i, dec in enumerate(decs):
                missing = DECISION_KEYS - set(dec)
                if missing:
                    failures.append(
                        f"decision {i} missing keys {sorted(missing)}")
                    break  # one schema message per shape of drift
    mvp = blob.get("measured_vs_predicted")
    if not isinstance(mvp, dict) or not isinstance(mvp.get("rows"), list):
        failures.append("measured_vs_predicted.rows missing")
    else:
        for i, row in enumerate(mvp["rows"]):
            missing = MVP_ROW_KEYS - set(row)
            if missing:
                failures.append(
                    f"measured_vs_predicted row {i} missing keys "
                    f"{sorted(missing)}")
                break
    return failures


def obs_check_main(trace_path: str | None, metrics_path: str | None) -> int:
    failures = []
    for path, checker in ((trace_path, check_trace_blob),
                          (metrics_path, check_metrics_blob)):
        if not path:
            continue
        if not os.path.exists(path):
            failures.append(f"{path}: file not found")
            continue
        try:
            with open(path) as f:
                blob = json.load(f)
        except ValueError as e:
            failures.append(f"{path}: not valid JSON ({e})")
            continue
        failures.extend(f"{path}: {msg}" for msg in checker(blob))
    for msg in failures:
        print(f"[obs-check] FAIL: {msg}")
    if failures:
        return 1
    print("[obs-check] ok: trace/metrics artifacts match the DESIGN.md §9 "
          "schemas")
    return 0


def main(argv: list | None = None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        description="observability artifact schema check (see module doc)")
    ap.add_argument("--check-obs", action="store_true", required=True,
                    help="validate --trace/--metrics artifact schemas")
    ap.add_argument("--trace", default="",
                    help="a --trace-out Chrome trace-event JSON to validate")
    ap.add_argument("--metrics", default="",
                    help="a --metrics-json snapshot to validate")
    args = ap.parse_args(argv)
    if not (args.trace or args.metrics):
        ap.error("nothing to check: pass --trace and/or --metrics")
    return obs_check_main(args.trace, args.metrics)


if __name__ == "__main__":
    raise SystemExit(main())
