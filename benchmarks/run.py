"""Benchmark harness: one module per paper table/figure.

  bench_mpgemm   — Table 7 / Fig 7: format speed ladder + TPU projections
  bench_quality  — Table 2: lossless / lossy inference quality
  bench_tradeoff — Fig 8 / Appendix A-B: compute-memory trade-off vs batch
  bench_roofline — §Roofline: aggregated dry-run terms per (arch × shape)
  bench_serve    — serving matrix: dense/paged × token/chunked, TTFT vs load

Prints ``name,us_per_call,derived`` CSV.
"""

import sys
import traceback


def main() -> None:
    from benchmarks import (bench_mpgemm, bench_quality, bench_roofline,
                            bench_serve, bench_tradeoff)

    print("name,us_per_call,derived")
    for mod in (bench_mpgemm, bench_quality, bench_tradeoff, bench_roofline,
                bench_serve):
        try:
            for name, us, derived in mod.run():
                print(f"{name},{us:.1f},{derived}")
        except Exception:
            traceback.print_exc()
            print(f"{mod.__name__},-1,FAILED", file=sys.stdout)


if __name__ == '__main__':
    main()
