"""Benchmark harness: one module per paper table/figure.

  bench_mpgemm   — Table 7 / Fig 7: format speed ladder + TPU projections
  bench_quality  — Table 2: lossless / lossy inference quality
  bench_tradeoff — Fig 8 / Appendix A-B: compute-memory trade-off vs batch
  bench_roofline — §Roofline: aggregated dry-run terms per (arch × shape)
  bench_serve    — serving matrix: dense/paged × token/chunked/batched,
                   TTFT + throughput vs load

Prints ``name,us_per_call,derived`` CSV.  ``--only NAME`` (repeatable)
restricts the run to the named suites — e.g. ``--only serve`` regenerates
``BENCH_serve.json`` without paying for the mpGEMM sweep (what the CI
serving gate wants).
"""

import argparse
import sys
import traceback


def _suites() -> dict:
    from benchmarks import (bench_mpgemm, bench_quality, bench_roofline,
                            bench_serve, bench_tradeoff)

    return {
        "mpgemm": bench_mpgemm,
        "quality": bench_quality,
        "tradeoff": bench_tradeoff,
        "roofline": bench_roofline,
        "serve": bench_serve,
    }


def main(argv=None) -> None:
    suites = _suites()
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", action="append", choices=sorted(suites),
                    metavar="SUITE",
                    help="run only this suite (repeatable); default: all of "
                         + ", ".join(suites))
    args = ap.parse_args(argv)
    picked = args.only or list(suites)

    print("name,us_per_call,derived")
    for name in suites:  # registry order, filtered — stable output order
        if name not in picked:
            continue
        mod = suites[name]
        try:
            for row_name, us, derived in mod.run():
                print(f"{row_name},{us:.1f},{derived}")
        except Exception:
            traceback.print_exc()
            print(f"{mod.__name__},-1,FAILED", file=sys.stdout)


if __name__ == '__main__':
    main()
