"""Paper Figure 8 / Appendix A-B analogue: the compute-memory trade-off.

The paper sweeps thread counts; the TPU-relevant axis is ARITHMETIC
INTENSITY: we sweep the GEMM batch N (decode→prefill transition) and report
per-token cost per format.  Expected shape (and what validates the analysis
in Appendix A): at N=1 everything is memory-bound and sub-2-bpw formats win
by bytes; as N grows the MAD/MXU paths flatten to compute-bound while the
LUT path's extra lookup arithmetic shows up — the ELUT C^g/g overhead the
paper bounds against register length.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mpgemm, quant
from repro.core.qtensor import pack_ternary


def _time(fn, *args, reps=3) -> float:
    fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    out.block_until_ready()
    return (time.perf_counter() - t0) / reps * 1e6


def run() -> list:
    rows = []
    rng = np.random.default_rng(0)
    k, m = 2048, 2048
    w = jnp.asarray(rng.integers(-1, 2, size=(m, k)), jnp.int8)
    pw_i2s = pack_ternary(w, jnp.float32(1.0), "i2s")
    pw_tl1 = pack_ternary(w, jnp.float32(1.0), "tl1")
    for n in (1, 8, 64, 256):
        x = jnp.asarray(rng.normal(size=(n, k)), jnp.float32)
        x_q, sx = quant.absmax_int8(x)
        mad = jax.jit(lambda xq, s: mpgemm.mpgemm_xla(xq, s, pw_i2s))
        lut = jax.jit(lambda xq, s: mpgemm.tl1_lut(xq, s, pw_tl1, lossless=True))
        us_mad = _time(mad, x_q, sx)
        us_lut = _time(lut, x_q, sx)
        rows.append((f"tradeoff_mad_N{n}", us_mad, f"per_tok{us_mad/n:.1f}us"))
        rows.append((f"tradeoff_lut_N{n}", us_lut, f"per_tok{us_lut/n:.1f}us"))
    return rows
