"""Serving-subsystem benchmark (DESIGN.md §7): throughput + TTFT vs load.

Sweeps the serving matrix — dense vs paged KV × token-by-token vs chunked
vs BATCHED-concurrent prefill (``prefill_budget`` = slots · chunk: one
[S, C] call per tick at mpGEMM N = S·C) — at two offered loads on the
smoke config, measuring per cell:

  * wall throughput (generated tok/s),
  * TTFT mean / p95 (submit → first generated token; the chunked-prefill
    headline: one [1, C] GEMM-regime call replaces C decode ticks, so TTFT
    at prompt length ≥ 64 must beat token-by-token prefill),
  * queue wait p95 and KV-block occupancy (paged cells).

All cells run in the composition-invariant ``act="token"`` quant mode so
generated tokens are comparable across cells (recorded as
``tokens_match_dense``).  Emits ``BENCH_serve.json``.

CI smoke: ``python -m benchmarks.bench_serve --smoke`` runs the tiny 2×2
(dense/paged × sequential/batched chunked prefill) sweep into the
gitignored ``BENCH_serve.smoke.new.json`` and exits non-zero if the cell
schema drifted, a baseline cell dropped out of the sweep, tokens stopped
matching the dense reference, or any cell's wall time regressed
reproducibly > 2× against the committed ``BENCH_serve.smoke.json``
(sweep-share-normalized, confirmed by one re-sweep; refresh the baseline
with ``--smoke --update-baseline`` on an idle machine).
"""

from __future__ import annotations

import json
import time

import jax
import numpy as np

from benchmarks import smoke_gate
from repro import configs
from repro.core.bitlinear import QuantConfig
from repro.models import lm
from repro.serve import Request, ServeConfig, ServeEngine

ARTIFACT = "BENCH_serve.json"
SMOKE_BASELINE = "BENCH_serve.smoke.json"
SMOKE_OUT = "BENCH_serve.smoke.new.json"
PROMPT_LEN = 64          # the acceptance point: chunked must win TTFT here
MAX_NEW = 8
SLOTS = 3
MAX_SEQ = 128
CHUNK = 32
BLOCK = 16
BUDGET = SLOTS * CHUNK   # batched cells: every prefilling slot packs a row
MODES = [  # (label, paged, prefill_chunk, prefill_budget)
    ("dense_token", False, 1, 0),
    ("dense_chunked", False, CHUNK, 0),
    ("dense_batched", False, CHUNK, BUDGET),
    ("paged_token", True, 1, 0),
    ("paged_chunked", True, CHUNK, 0),
    ("paged_batched", True, CHUNK, BUDGET),
]
LOADS = [3, 6]           # offered requests (≤ slots: unqueued; > slots: queued)

# smoke gate: the 2×2 dense/paged × sequential/batched matrix at one
# prompt-heavy load (every slot prefilling concurrently), reduced shapes
SMOKE_PROMPT_LEN = 24
SMOKE_MAX_NEW = 4
SMOKE_CHUNK = 8
SMOKE_MODES = [
    ("dense_chunked", False, SMOKE_CHUNK, 0),
    ("dense_batched", False, SMOKE_CHUNK, SLOTS * SMOKE_CHUNK),
    ("paged_chunked", True, SMOKE_CHUNK, 0),
    ("paged_batched", True, SMOKE_CHUNK, SLOTS * SMOKE_CHUNK),
]
SMOKE_LOADS = [3]
REGRESSION_FACTOR = 2.0
CELL_KEYS = {"mode", "paged", "prefill_chunk", "prefill_budget",
             "load_requests", "prompt_len", "slots", "tokens_match_dense",
             "wall_s", "throughput_tok_s", "ttft_mean_s", "ttft_p95_s",
             "queue_wait_p95_s", "preemptions"}


def _prompts(cfg, n, prompt_len):
    rng = np.random.default_rng(0)
    return [rng.integers(0, cfg.vocab, size=prompt_len).tolist() for _ in range(n)]


def _run_cell(params, cfg, paged, chunk, budget, prompts, max_new):
    eng = ServeEngine(params, cfg, ServeConfig(
        batch_slots=SLOTS, max_seq=MAX_SEQ, paged=paged,
        block_size=BLOCK, prefill_chunk=chunk, prefill_budget=budget))
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=max_new))
    t0 = time.perf_counter()
    done = eng.run()
    wall = time.perf_counter() - t0
    s = eng.metrics_summary()
    toks = sum(len(r.out_tokens) for r in done)
    return {
        "wall_s": round(wall, 3),
        "throughput_tok_s": round(toks / wall, 2),
        "ttft_mean_s": round(s["ttft_mean"], 6),
        "ttft_p95_s": round(s["ttft_p95"], 6),
        "queue_wait_p95_s": round(s["queue_wait_p95"], 6),
        "preemptions": s["preemptions"],
    }, {r.rid: r.out_tokens for r in done}


def run(smoke: bool = False, artifact: str | None = None) -> list:
    artifact = artifact or (SMOKE_OUT if smoke else ARTIFACT)
    modes, loads = (SMOKE_MODES, SMOKE_LOADS) if smoke else (MODES, LOADS)
    prompt_len = SMOKE_PROMPT_LEN if smoke else PROMPT_LEN
    max_new = SMOKE_MAX_NEW if smoke else MAX_NEW
    rows = []
    cfg = configs.smoke("qwen1.5-0.5b").replace(
        dtype="float32",
        quant=QuantConfig(mode="quant", fmt="i2s", act="token"))
    params = lm.init(jax.random.PRNGKey(0), cfg)
    cells = []
    for load in loads:
        prompts = _prompts(cfg, load, prompt_len)
        ref_tokens = None
        for label, paged, chunk, budget in modes:
            # warm the jit caches AT THE MEASURED LOAD so TTFT measures
            # serving, not tracing — a 1-request warmup misses the shapes
            # only multi-slot runs hit (scrub sizes, queueing), and the
            # leftover compiles land on whichever cell runs them first
            _run_cell(params, cfg, paged, chunk, budget, prompts, max_new)
            m, toks = _run_cell(params, cfg, paged, chunk, budget, prompts,
                                max_new)
            if ref_tokens is None:  # first mode of the load = the reference
                ref_tokens = toks
            cell = {
                "mode": label, "paged": paged, "prefill_chunk": chunk,
                "prefill_budget": budget,
                "load_requests": load, "prompt_len": prompt_len,
                "slots": SLOTS, "tokens_match_dense": toks == ref_tokens,
                **m,
            }
            cells.append(cell)
            rows.append((
                f"serve_{label}_load{load}", m["ttft_mean_s"] * 1e6,
                f"ttft_p95={m['ttft_p95_s']}s_thru={m['throughput_tok_s']}tok/s"
                f"_match={toks == ref_tokens}"))
    by = {(c["mode"], c["load_requests"]): c for c in cells}
    for load in loads:
        # the acceptance comparisons: chunked vs token TTFT at prompt_len
        # >= 64, and batched vs sequential chunked throughput at a
        # prompt-heavy load (>= 2 slots prefilling concurrently)
        if ("paged_token", load) in by:
            tok_t = by[("paged_token", load)]["ttft_mean_s"]
            chk_t = by[("paged_chunked", load)]["ttft_mean_s"]
            speedup = round(tok_t / max(chk_t, 1e-9), 2)  # fast backends → ~0
            rows.append((f"serve_chunked_speedup_load{load}", 0.0,
                         f"ttft_token={tok_t}s_chunked={chk_t}s_x{speedup}"))
        for kv in ("dense", "paged"):
            seqc = by.get((f"{kv}_chunked", load))
            batc = by.get((f"{kv}_batched", load))
            if seqc and batc:
                win = round(batc["throughput_tok_s"]
                            / max(seqc["throughput_tok_s"], 1e-9), 2)
                rows.append((
                    f"serve_batched_speedup_{kv}_load{load}", 0.0,
                    f"thru_seq={seqc['throughput_tok_s']}"
                    f"_batched={batc['throughput_tok_s']}tok/s_x{win}"))
    blob = {
        "backend": jax.default_backend(),
        "arch": "qwen1.5-0.5b(smoke)",
        "smoke": smoke,
        "prompt_len": prompt_len, "max_new": max_new, "slots": SLOTS,
        "block_size": BLOCK,
        "prefill_chunk": SMOKE_CHUNK if smoke else CHUNK,
        "prefill_budget": (SLOTS * SMOKE_CHUNK) if smoke else BUDGET,
        "act_quant": "token (composition-invariant; see DESIGN.md §7)",
        "cells": cells,
    }
    with open(artifact, "w") as f:
        json.dump(blob, f, indent=1)
    rows.append((f"artifact_{artifact}", 0.0, f"{len(cells)}cells"))
    return rows


# ---------------------------------------------------------------------------
# CI smoke: schema + token-identity + per-cell regression gate
# ---------------------------------------------------------------------------


def _cell_key(c: dict) -> tuple:
    return (c.get("mode"), c.get("load_requests"))


def _normalized(blob: dict) -> dict:
    """Per-cell wall-time shares of the sweep total (see smoke_gate)."""
    return smoke_gate.share_of_total(
        [(_cell_key(c), c["wall_s"]) for c in blob.get("cells", [])
         if c.get("wall_s")])


def _identity_check(c: dict) -> list:
    """Serving-specific gate check: every cell's greedy tokens must match
    the load's reference cell (act=token serving is composition-invariant,
    so divergence means a real numerics break, not noise)."""
    if c.get("tokens_match_dense", False):
        return []
    return [("identity", _cell_key(c),
             f"cell {_cell_key(c)} tokens diverged from the reference cell "
             "(batched/sequential/paged must be token-identical at "
             "act=token)")]


def check_regression(old_blob: dict, new_blob: dict,
                     factor: float = REGRESSION_FACTOR) -> list:
    """Shared gate checks (schema drift, dropped cells, >factor
    share-normalized wall regressions — see smoke_gate.check_cells) plus
    the serving-only token-identity check."""
    return smoke_gate.check_cells(
        old_blob, new_blob, cell_key=_cell_key, cell_keys=CELL_KEYS,
        normalized=_normalized, factor=factor,
        extra_cell_checks=(_identity_check,))


def main(argv: list | None = None) -> int:
    return smoke_gate.gate_main(
        argv, tag="bench_serve", run=run, check_regression=check_regression,
        baseline=SMOKE_BASELINE, out=SMOKE_OUT, factor=REGRESSION_FACTOR,
        smoke_help="tiny 2x2 dense/paged x sequential/batched sweep with "
                   "schema + token-identity checks")


if __name__ == "__main__":
    raise SystemExit(main())
