"""Serving-subsystem benchmark (DESIGN.md §7): throughput + TTFT vs load.

Sweeps the 2×2 serving matrix — dense vs paged KV, token-by-token vs
chunked prefill — at two offered loads on the smoke config, measuring per
cell:

  * wall throughput (generated tok/s),
  * TTFT mean / p95 (submit → first generated token; the chunked-prefill
    headline: one [1, C] GEMM-regime call replaces C decode ticks, so TTFT
    at prompt length ≥ 64 must beat token-by-token prefill),
  * queue wait p95 and KV-block occupancy (paged cells).

All four cells run in the composition-invariant ``act="token"`` quant mode
so generated tokens are comparable across cells (recorded as
``tokens_match_dense``).  Emits ``BENCH_serve.json``.
"""

from __future__ import annotations

import json
import time

import jax
import numpy as np

from repro import configs
from repro.core.bitlinear import QuantConfig
from repro.models import lm
from repro.serve import Request, ServeConfig, ServeEngine

ARTIFACT = "BENCH_serve.json"
PROMPT_LEN = 64          # the acceptance point: chunked must win TTFT here
MAX_NEW = 8
SLOTS = 3
MAX_SEQ = 128
CHUNK = 32
BLOCK = 16
MODES = [  # (label, paged, prefill_chunk)
    ("dense_token", False, 1),
    ("dense_chunked", False, CHUNK),
    ("paged_token", True, 1),
    ("paged_chunked", True, CHUNK),
]
LOADS = [3, 6]           # offered requests (≤ slots: unqueued; > slots: queued)


def _prompts(cfg, n):
    rng = np.random.default_rng(0)
    return [rng.integers(0, cfg.vocab, size=PROMPT_LEN).tolist() for _ in range(n)]


def _run_cell(params, cfg, paged, chunk, prompts):
    eng = ServeEngine(params, cfg, ServeConfig(
        batch_slots=SLOTS, max_seq=MAX_SEQ, paged=paged,
        block_size=BLOCK, prefill_chunk=chunk))
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=MAX_NEW))
    t0 = time.perf_counter()
    done = eng.run()
    wall = time.perf_counter() - t0
    s = eng.metrics_summary()
    toks = sum(len(r.out_tokens) for r in done)
    return {
        "wall_s": round(wall, 3),
        "throughput_tok_s": round(toks / wall, 2),
        "ttft_mean_s": round(s["ttft_mean"], 6),
        "ttft_p95_s": round(s["ttft_p95"], 6),
        "queue_wait_p95_s": round(s["queue_wait_p95"], 6),
        "preemptions": s["preemptions"],
    }, {r.rid: r.out_tokens for r in done}


def run() -> list:
    rows = []
    cfg = configs.smoke("qwen1.5-0.5b").replace(
        dtype="float32",
        quant=QuantConfig(mode="quant", fmt="i2s", act="token"))
    params = lm.init(jax.random.PRNGKey(0), cfg)
    cells = []
    for load in LOADS:
        prompts = _prompts(cfg, load)
        ref_tokens = None
        for label, paged, chunk in MODES:
            # warm the jit caches so TTFT measures serving, not tracing
            _run_cell(params, cfg, paged, chunk, [prompts[0][:PROMPT_LEN]])
            m, toks = _run_cell(params, cfg, paged, chunk, prompts)
            if label == "dense_token":
                ref_tokens = toks
            cell = {
                "mode": label, "paged": paged, "prefill_chunk": chunk,
                "load_requests": load, "prompt_len": PROMPT_LEN,
                "slots": SLOTS, "tokens_match_dense": toks == ref_tokens,
                **m,
            }
            cells.append(cell)
            rows.append((
                f"serve_{label}_load{load}", m["ttft_mean_s"] * 1e6,
                f"ttft_p95={m['ttft_p95_s']}s_thru={m['throughput_tok_s']}tok/s"
                f"_match={toks == ref_tokens}"))
    # the acceptance comparison: chunked vs token TTFT at prompt_len >= 64
    by = {(c["mode"], c["load_requests"]): c for c in cells}
    for load in LOADS:
        tok_t = by[("paged_token", load)]["ttft_mean_s"]
        chk_t = by[("paged_chunked", load)]["ttft_mean_s"]
        speedup = round(tok_t / max(chk_t, 1e-9), 2)  # fast backends round→~0
        rows.append((f"serve_chunked_speedup_load{load}", 0.0,
                     f"ttft_token={tok_t}s_chunked={chk_t}s_x{speedup}"))
    blob = {
        "backend": jax.default_backend(),
        "arch": "qwen1.5-0.5b(smoke)",
        "prompt_len": PROMPT_LEN, "max_new": MAX_NEW, "slots": SLOTS,
        "block_size": BLOCK, "prefill_chunk": CHUNK,
        "act_quant": "token (composition-invariant; see DESIGN.md §7)",
        "cells": cells,
    }
    with open(ARTIFACT, "w") as f:
        json.dump(blob, f, indent=1)
    rows.append((f"artifact_{ARTIFACT}", 0.0, f"{len(cells)}cells"))
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
