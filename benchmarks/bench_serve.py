"""Serving-subsystem benchmark (DESIGN.md §7): throughput + TTFT vs load.

Two sweeps share one artifact (``BENCH_serve.json``):

* the serving MATRIX — dense vs paged KV × token-by-token vs chunked vs
  BATCHED-concurrent prefill (``prefill_budget`` = slots · chunk: one
  [S, C] call per tick at mpGEMM N = S·C) — at two offered loads, plus a
  SPECULATIVE cell per KV layout (self-draft, ``speculate_k`` tokens per
  tick; verify rides the GEMM regime at N = slots·(k+1), DESIGN.md §10);
* BURSTY WORKLOADS at production shape — hundreds of requests arriving in
  bursts against 8 slots, in a shared-prefix mix (few-shot templates:
  4 templates × ~150 requests) and a long-context mix (half template +
  long tail, half unique long prompts), each run with the prefix cache
  OFF and ON.  The ON cell must decode bit-identical tokens (act=token is
  composition-invariant) while skipping the shared prefill — the headline
  ≥2× TTFT win with a nonzero prefix-hit rate in telemetry.  A third
  DECODE-HEAVY mix (short prompts, long generations) runs speculation OFF
  and ON with the model-free prompt-lookup draft (``LookupDraft``:
  proposals off each slot's own history, so the [B, k+1] verify is the
  whole speculative cost): the ON cell must commit > 1 token per verify
  step, decode bit-identical tokens (greedy acceptance is exact for ANY
  draft), and show the decode tok/s win the GEMV→GEMM batching predicts.
  The workload generator is deterministic under ``--seed``.

Per cell: wall throughput (generated tok/s), TTFT mean / p50 / p95
(submit → first generated token), queue wait p95, preemptions, and the
prefix telemetry (hit rate, prefill tokens skipped, blocks reused).  The
blob additionally carries a ``kernel_attribution`` table from one
instrumented run (``repro.obs``, DESIGN.md §9): jit-fenced wall per
(kernel, fmt, M, K, N-bucket) key next to the dispatch cost model's
prediction, run after the timed sweep so the fences never touch gated
cells.

CI smoke: ``python -m benchmarks.bench_serve --smoke`` runs the tiny
dense/paged × sequential/batched sweep PLUS a shared-prefix cell
(6 shared-template requests over 3 slots — the queued second wave hits
the index) PLUS a speculative cell (self-draft, k=2) into the gitignored
``BENCH_serve.smoke.new.json`` and exits non-zero if the cell schema
drifted, a baseline cell dropped out, tokens stopped matching the dense
reference, the prefix cell stopped hitting, its TTFT win disappeared
reproducibly, the speculative cell stopped committing > 1 token per
verify step, or any cell's wall time regressed
reproducibly > 2× against the committed ``BENCH_serve.smoke.json``
(sweep-share-normalized, confirmed by one re-sweep; refresh the baseline
with ``--smoke --update-baseline`` on an idle machine).
"""

from __future__ import annotations

import json
import time

import jax
import numpy as np

from benchmarks import smoke_gate
from repro import configs
from repro import obs as obs_mod
from repro.core.bitlinear import QuantConfig
from repro.models import lm
from repro.serve import LookupDraft, Request, ServeConfig, ServeEngine

ARTIFACT = "BENCH_serve.json"
SMOKE_BASELINE = "BENCH_serve.smoke.json"
SMOKE_OUT = "BENCH_serve.smoke.new.json"
PROMPT_LEN = 64          # the acceptance point: chunked must win TTFT here
MAX_NEW = 8
SLOTS = 3
MAX_SEQ = 128
CHUNK = 32
BLOCK = 16
BUDGET = SLOTS * CHUNK   # batched cells: every prefilling slot packs a row
SPEC_K = 3               # spec cells: self-draft, verify at N = SLOTS*(k+1)
MODES = [  # (label, paged, prefill_chunk, prefill_budget, prefix_cache, spec_k)
    ("dense_token", False, 1, 0, False, 0),
    ("dense_chunked", False, CHUNK, 0, False, 0),
    ("dense_batched", False, CHUNK, BUDGET, False, 0),
    ("dense_spec", False, CHUNK, 0, False, SPEC_K),
    ("paged_token", True, 1, 0, False, 0),
    ("paged_chunked", True, CHUNK, 0, False, 0),
    ("paged_batched", True, CHUNK, BUDGET, False, 0),
    ("paged_spec", True, CHUNK, 0, False, SPEC_K),
]
LOADS = [3, 6]           # offered requests (≤ slots: unqueued; > slots: queued)

# bursty workloads: production shape — many requests, bursts, 8 slots
WORK_SLOTS = 8
WORK_MAX_SEQ = 224
WORK_CHUNK = 32
WORK_BUDGET = 4 * WORK_CHUNK
WORK_BURST = 16          # requests per arrival burst
WORK_DRAIN = 4           # engine ticks between bursts (partial drain)
WORK_MAX_NEW = 4
WORKLOADS = ("shared_prefix", "longctx_mix")
WORK_SPEC_K = 8          # decode-heavy bursty cells: spec OFF vs ON
WORK_SPEC_MAX_NEW = 32   # long generations so decode dominates the wall
WORK_SPEC_NGRAM = 1      # prompt-lookup draft order for the bursty cell

# smoke gate: dense/paged × sequential/batched at one prompt-heavy load,
# plus the shared-prefix cell.  Load EXCEEDS the slot count on purpose:
# prefix insertion happens at prompt completion, so a simultaneous
# admission of every request would see an empty index — the queued second
# wave is what hits.
SMOKE_PROMPT_LEN = 24    # BLOCK-sized shared template + 8 private tokens
SMOKE_SHARED = BLOCK
SMOKE_MAX_NEW = 4
SMOKE_CHUNK = 8
SMOKE_SPEC_K = 2         # max_new 4 → full self-accept commits 4 in 2 steps
SMOKE_MODES = [
    ("dense_chunked", False, SMOKE_CHUNK, 0, False, 0),
    ("dense_batched", False, SMOKE_CHUNK, SLOTS * SMOKE_CHUNK, False, 0),
    ("paged_chunked", True, SMOKE_CHUNK, 0, False, 0),
    ("paged_batched", True, SMOKE_CHUNK, SLOTS * SMOKE_CHUNK, False, 0),
    ("paged_prefix", True, SMOKE_CHUNK, SLOTS * SMOKE_CHUNK, True, 0),
    ("paged_spec", True, SMOKE_CHUNK, SLOTS * SMOKE_CHUNK, False,
     SMOKE_SPEC_K),
]
SMOKE_LOADS = [6]
REGRESSION_FACTOR = 2.0
CELL_KEYS = {"mode", "workload", "paged", "prefill_chunk", "prefill_budget",
             "prefix_cache", "load_requests", "prompt_len", "slots",
             "tokens_match_dense", "wall_s", "throughput_tok_s",
             "ttft_mean_s", "ttft_p50_s", "ttft_p95_s", "queue_wait_p95_s",
             "preemptions", "prefix_hit_rate", "prefill_tokens_skipped",
             "blocks_reused", "speculate_k", "spec_accepted_per_step",
             "spec_acceptance_rate", "spec_draft", "decode_tok_s"}


def _prompts(cfg, n, prompt_len, shared=0, seed=0):
    """``n`` prompts of ``prompt_len`` tokens; the first ``shared`` tokens
    are one common template (what the prefix cell reuses)."""
    rng = np.random.default_rng(seed)
    tpl = rng.integers(0, cfg.vocab, size=shared).tolist()
    return [tpl + rng.integers(0, cfg.vocab,
                               size=prompt_len - shared).tolist()
            for _ in range(n)]


def bursty_workload(cfg, workload, seed):
    """Deterministic production-shaped prompt mixes (the --seed surface).

    ``shared_prefix``: ~150 requests over 4 few-shot templates (192 tokens
    = 12 full blocks: system prompt + examples) plus short private suffixes
    — the prefix cache's best case, where prefill dominates cold TTFT.
    ``longctx_mix``: 64 requests, half template + LONG private tail, half
    fully unique long prompts — partial hits under real KV pressure.
    ``decode_heavy``: 48 requests with SHORT unique prompts — generation
    dominates the wall, so the decode path's regime (GEMV at N = B vs the
    speculative verify's GEMM at N = B·(k+1)) is what the cell measures.
    """
    rng = np.random.default_rng(seed)
    if workload == "shared_prefix":
        tpls = [rng.integers(0, cfg.vocab, size=192).tolist()
                for _ in range(4)]
        return [tpls[int(rng.integers(0, len(tpls)))]
                + rng.integers(0, cfg.vocab,
                               size=int(rng.integers(8, 17))).tolist()
                for _ in range(144)]
    if workload == "longctx_mix":
        tpl = rng.integers(0, cfg.vocab, size=96).tolist()
        out = []
        for i in range(64):
            if i % 2 == 0:
                out.append(tpl + rng.integers(
                    0, cfg.vocab, size=int(rng.integers(32, 65))).tolist())
            else:
                out.append(rng.integers(
                    0, cfg.vocab, size=int(rng.integers(128, 177))).tolist())
        return out
    if workload == "decode_heavy":
        return [rng.integers(0, cfg.vocab,
                             size=int(rng.integers(16, 33))).tolist()
                for _ in range(48)]
    raise ValueError(f"unknown workload {workload!r}")


def _metrics_cell(eng, done, wall):
    s = eng.metrics_summary()
    toks = sum(len(r.out_tokens) for r in done)
    return {
        "wall_s": round(wall, 3),
        "throughput_tok_s": round(toks / wall, 2),
        "ttft_mean_s": round(s["ttft_mean"], 6),
        "ttft_p50_s": round(s["ttft_p50"], 6),
        "ttft_p95_s": round(s["ttft_p95"], 6),
        "queue_wait_p95_s": round(s["queue_wait_p95"], 6),
        "preemptions": s["preemptions"],
        "prefix_hit_rate": round(s["prefix_hit_rate"], 4),
        "prefill_tokens_skipped": s["prefill_tokens_skipped"],
        "blocks_reused": s["blocks_reused"],
        # decode_tok_s is the number speculation moves (throughput_tok_s
        # folds queueing + prefill in); spec_* keys are None when the cell
        # serves without speculation (speculate_k == 0)
        "decode_tok_s": (round(s["decode_tok_s_mean"], 2)
                         if s["decode_tok_s_mean"] is not None else None),
        "speculate_k": s.get("speculate_k", 0),
        "spec_accepted_per_step": (
            round(s["spec_accepted_per_step"], 3)
            if s.get("spec_accepted_per_step") is not None else None),
        "spec_acceptance_rate": (
            round(s["spec_acceptance_rate"], 4)
            if s.get("spec_acceptance_rate") is not None else None),
        "spec_draft": s.get("spec_draft"),
    }


def _run_cell(params, cfg, paged, chunk, budget, prompts, max_new, *,
              prefix=False, slots=SLOTS, max_seq=MAX_SEQ, speculate=0):
    eng = ServeEngine(params, cfg, ServeConfig(
        batch_slots=slots, max_seq=max_seq, paged=paged,
        block_size=BLOCK, prefill_chunk=chunk, prefill_budget=budget,
        prefix_cache=prefix, speculate_k=speculate))
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=max_new))
    t0 = time.perf_counter()
    done = eng.run()
    wall = time.perf_counter() - t0
    return _metrics_cell(eng, done, wall), {r.rid: r.out_tokens for r in done}


def _attribution_run(params, cfg, prompts, max_new, chunk, budget):
    """One jit-fenced instrumented run (repro.obs, DESIGN.md §9) at the
    paged-batched sweep point: every mpGEMM dispatched during serving gets
    measured wall attributed against the dispatch cost model.  Runs AFTER
    the timed sweep so its per-call fences never pollute the gated cells;
    the sweep's earlier compiles were keyset-captured, so this run
    attributes warm executes (plus any shape it compiles itself)."""
    obs = obs_mod.make(tracing=False, metrics_on=False)
    eng = ServeEngine(params, cfg, ServeConfig(
        batch_slots=SLOTS, max_seq=MAX_SEQ, paged=True, block_size=BLOCK,
        prefill_chunk=chunk, prefill_budget=budget), obs=obs)
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=max_new))
    eng.run()
    return eng.measured_vs_predicted()


def _run_bursty_cell(params, cfg, prompts, *, prefix=False,
                     max_new=WORK_MAX_NEW, speculate=0, draft=None):
    """Bursty arrivals: WORK_BURST requests per burst, WORK_DRAIN ticks of
    partial drain between bursts, then run to completion."""
    eng = ServeEngine(params, cfg, ServeConfig(
        batch_slots=WORK_SLOTS, max_seq=WORK_MAX_SEQ, paged=True,
        block_size=BLOCK, prefill_chunk=WORK_CHUNK,
        prefill_budget=WORK_BUDGET, prefix_cache=prefix,
        speculate_k=speculate), draft=draft)
    done = []
    t0 = time.perf_counter()
    for b0 in range(0, len(prompts), WORK_BURST):
        for i, p in enumerate(prompts[b0:b0 + WORK_BURST]):
            eng.submit(Request(rid=b0 + i, prompt=p,
                               max_new_tokens=max_new))
        for _ in range(WORK_DRAIN):
            done.extend(eng.step())
    while eng.sched.pending or any(s is not None for s in eng.slots):
        done.extend(eng.step())
    wall = time.perf_counter() - t0
    return _metrics_cell(eng, done, wall), {r.rid: r.out_tokens for r in done}


def run(smoke: bool = False, artifact: str | None = None, seed: int = 0) -> list:
    artifact = artifact or (SMOKE_OUT if smoke else ARTIFACT)
    modes, loads = (SMOKE_MODES, SMOKE_LOADS) if smoke else (MODES, LOADS)
    prompt_len = SMOKE_PROMPT_LEN if smoke else PROMPT_LEN
    shared = SMOKE_SHARED if smoke else 0
    max_new = SMOKE_MAX_NEW if smoke else MAX_NEW
    rows = []
    cfg = configs.smoke("qwen1.5-0.5b").replace(
        dtype="float32",
        quant=QuantConfig(mode="quant", fmt="i2s", act="token"))
    params = lm.init(jax.random.PRNGKey(0), cfg)
    cells = []
    for load in loads:
        prompts = _prompts(cfg, load, prompt_len, shared=shared, seed=seed)
        ref_tokens = None
        for label, paged, chunk, budget, prefix, spec in modes:
            # warm the jit caches AT THE MEASURED LOAD so TTFT measures
            # serving, not tracing — a 1-request warmup misses the shapes
            # only multi-slot runs hit (scrub sizes, queueing), and the
            # leftover compiles land on whichever cell runs them first
            _run_cell(params, cfg, paged, chunk, budget, prompts, max_new,
                      prefix=prefix, speculate=spec)
            m, toks = _run_cell(params, cfg, paged, chunk, budget, prompts,
                                max_new, prefix=prefix, speculate=spec)
            if ref_tokens is None:  # first mode of the load = the reference
                ref_tokens = toks
            cell = {
                "mode": label, "workload": "uniform", "paged": paged,
                "prefill_chunk": chunk, "prefill_budget": budget,
                "prefix_cache": prefix,
                "load_requests": load, "prompt_len": prompt_len,
                "slots": SLOTS, "tokens_match_dense": toks == ref_tokens,
                **m,
            }
            cells.append(cell)
            rows.append((
                f"serve_{label}_load{load}", m["ttft_mean_s"] * 1e6,
                f"ttft_p95={m['ttft_p95_s']}s_thru={m['throughput_tok_s']}tok/s"
                f"_match={toks == ref_tokens}"
                + (f"_hit={m['prefix_hit_rate']}" if prefix else "")
                + (f"_acc={m['spec_accepted_per_step']}" if spec else "")))
    if not smoke:
        for workload in WORKLOADS:
            prompts = bursty_workload(cfg, workload, seed)
            # shape warmup only (the [S, C] / [B, 1] traces at workload
            # geometry); a full duplicate run of 100+ requests would double
            # the sweep for no extra coverage
            _run_bursty_cell(params, cfg, prompts[:2 * WORK_SLOTS],
                             prefix=False)
            ref_tokens = None
            for prefix in (False, True):
                m, toks = _run_bursty_cell(params, cfg, prompts,
                                           prefix=prefix)
                if ref_tokens is None:
                    ref_tokens = toks
                label = workload + ("_prefix" if prefix else "")
                cells.append({
                    "mode": label, "workload": workload, "paged": True,
                    "prefill_chunk": WORK_CHUNK,
                    "prefill_budget": WORK_BUDGET, "prefix_cache": prefix,
                    "load_requests": len(prompts),
                    "prompt_len": int(np.mean([len(p) for p in prompts])),
                    "slots": WORK_SLOTS,
                    "tokens_match_dense": toks == ref_tokens,
                    **m,
                })
                rows.append((
                    f"serve_{label}", m["ttft_mean_s"] * 1e6,
                    f"ttft_p50={m['ttft_p50_s']}s_p95={m['ttft_p95_s']}s"
                    f"_hit={m['prefix_hit_rate']}_match={toks == ref_tokens}"))
        # decode-heavy bursty pair: speculation OFF (the reference) vs ON
        # with the prompt-lookup draft — zero draft-model cost, so the ON
        # cell's only overhead is the [B, k+1] verify.  Greedy acceptance
        # is exact for any draft, so the ON cell must be token-identical
        # while committing > 1 token per verify step
        prompts = bursty_workload(cfg, "decode_heavy", seed)
        draft = LookupDraft(n=WORK_SPEC_NGRAM)
        for spec in (0, WORK_SPEC_K):  # warm both shape sets
            _run_bursty_cell(params, cfg, prompts[:2 * WORK_SLOTS],
                             max_new=WORK_SPEC_MAX_NEW, speculate=spec,
                             draft=draft if spec else None)
        ref_tokens = None
        for spec in (0, WORK_SPEC_K):
            m, toks = _run_bursty_cell(params, cfg, prompts,
                                       max_new=WORK_SPEC_MAX_NEW,
                                       speculate=spec,
                                       draft=draft if spec else None)
            if ref_tokens is None:
                ref_tokens = toks
            label = "decode_heavy" + ("_spec" if spec else "")
            cells.append({
                "mode": label, "workload": "decode_heavy", "paged": True,
                "prefill_chunk": WORK_CHUNK, "prefill_budget": WORK_BUDGET,
                "prefix_cache": False, "load_requests": len(prompts),
                "prompt_len": int(np.mean([len(p) for p in prompts])),
                "slots": WORK_SLOTS,
                "tokens_match_dense": toks == ref_tokens,
                **m,
            })
            rows.append((
                f"serve_{label}", m["ttft_mean_s"] * 1e6,
                f"decode={m['decode_tok_s']}tok/s"
                f"_thru={m['throughput_tok_s']}tok/s"
                f"_match={toks == ref_tokens}"
                + (f"_acc={m['spec_accepted_per_step']}" if spec else "")))
    by = {(c["mode"], c["load_requests"]): c for c in cells}
    prefix_speedups = {}
    spec_decode_speedups = {}
    for load in loads:
        # the acceptance comparisons: chunked vs token TTFT at prompt_len
        # >= 64, and batched vs sequential chunked throughput at a
        # prompt-heavy load (>= 2 slots prefilling concurrently)
        if ("paged_token", load) in by:
            tok_t = by[("paged_token", load)]["ttft_mean_s"]
            chk_t = by[("paged_chunked", load)]["ttft_mean_s"]
            speedup = round(tok_t / max(chk_t, 1e-9), 2)  # fast backends → ~0
            rows.append((f"serve_chunked_speedup_load{load}", 0.0,
                         f"ttft_token={tok_t}s_chunked={chk_t}s_x{speedup}"))
        for kv in ("dense", "paged"):
            seqc = by.get((f"{kv}_chunked", load))
            batc = by.get((f"{kv}_batched", load))
            if seqc and batc:
                win = round(batc["throughput_tok_s"]
                            / max(seqc["throughput_tok_s"], 1e-9), 2)
                rows.append((
                    f"serve_batched_speedup_{kv}_load{load}", 0.0,
                    f"thru_seq={seqc['throughput_tok_s']}"
                    f"_batched={batc['throughput_tok_s']}tok/s_x{win}"))
            # spec vs plain decode at the same KV layout + chunk: the
            # speculative acceptance comparison (decode tok/s, not wall
            # throughput — prefill and queueing are identical twins here)
            spc = by.get((f"{kv}_spec", load))
            if seqc and spc and seqc.get("decode_tok_s"):
                win = round((spc["decode_tok_s"] or 0.0)
                            / max(seqc["decode_tok_s"], 1e-9), 2)
                spec_decode_speedups[f"{kv}_load{load}"] = win
                rows.append((
                    f"serve_spec_decode_speedup_{kv}_load{load}", 0.0,
                    f"decode_plain={seqc['decode_tok_s']}"
                    f"_spec={spc['decode_tok_s']}tok/s_x{win}"
                    f"_acc={spc['spec_accepted_per_step']}"))
    # the prefix-cache acceptance comparison: OFF vs ON TTFT per pair
    for off_c, on_c in _prefix_pairs({"cells": cells}):
        speedup = round(off_c["ttft_mean_s"] / max(on_c["ttft_mean_s"], 1e-9), 2)
        prefix_speedups[on_c["mode"]] = speedup
        rows.append((
            f"serve_prefix_ttft_speedup_{on_c['mode']}", 0.0,
            f"ttft_off={off_c['ttft_mean_s']}s_on={on_c['ttft_mean_s']}s"
            f"_x{speedup}_hit={on_c['prefix_hit_rate']}"))
    # the speculative acceptance comparison on the bursty decode-heavy mix
    by_mode = {c["mode"]: c for c in cells}
    off_c, on_c = by_mode.get("decode_heavy"), by_mode.get("decode_heavy_spec")
    if off_c and on_c and off_c.get("decode_tok_s"):
        win = round((on_c["decode_tok_s"] or 0.0)
                    / max(off_c["decode_tok_s"], 1e-9), 2)
        spec_decode_speedups["decode_heavy"] = win
        rows.append((
            "serve_spec_decode_speedup_bursty", 0.0,
            f"decode_plain={off_c['decode_tok_s']}"
            f"_spec={on_c['decode_tok_s']}tok/s_x{win}"
            f"_acc={on_c['spec_accepted_per_step']}"))
    chunk = SMOKE_CHUNK if smoke else CHUNK
    attribution = _attribution_run(
        params, cfg, _prompts(cfg, SLOTS, prompt_len, seed=seed), max_new,
        chunk, SLOTS * chunk)
    rows.append(("serve_kernel_attribution", 0.0,
                 f"{len(attribution['rows'])}kernel_keys"
                 f"_unattr={attribution['unattributed_s']}s"))
    blob = {
        "backend": jax.default_backend(),
        "arch": "qwen1.5-0.5b(smoke)",
        "smoke": smoke, "seed": seed,
        "prompt_len": prompt_len, "max_new": max_new, "slots": SLOTS,
        "block_size": BLOCK,
        "prefill_chunk": SMOKE_CHUNK if smoke else CHUNK,
        "prefill_budget": (SLOTS * SMOKE_CHUNK) if smoke else BUDGET,
        "act_quant": "token (composition-invariant; see DESIGN.md §7)",
        "prefix_ttft_speedup": prefix_speedups,
        "spec_decode_speedup": spec_decode_speedups,
        "cells": cells,
        "kernel_attribution": attribution,
    }
    with open(artifact, "w") as f:
        json.dump(blob, f, indent=1)
    rows.append((f"artifact_{artifact}", 0.0, f"{len(cells)}cells"))
    return rows


# ---------------------------------------------------------------------------
# CI smoke: schema + token-identity + prefix-hit + per-cell regression gate
# ---------------------------------------------------------------------------


def _cell_key(c: dict) -> tuple:
    return (c.get("mode"), c.get("load_requests"))


def _normalized(blob: dict) -> dict:
    """Per-cell wall-time shares of the sweep total (see smoke_gate)."""
    return smoke_gate.share_of_total(
        [(_cell_key(c), c["wall_s"]) for c in blob.get("cells", [])
         if c.get("wall_s")])


def _identity_check(c: dict) -> list:
    """Serving-specific gate check: every cell's greedy tokens must match
    the load's reference cell (act=token serving is composition-invariant,
    so divergence means a real numerics break, not noise) — including the
    prefix-cache cell, whose reuse must be bit-identical to recompute."""
    if c.get("tokens_match_dense", False):
        return []
    return [("identity", _cell_key(c),
             f"cell {_cell_key(c)} tokens diverged from the reference cell "
             "(batched/sequential/paged/prefix-cached must be "
             "token-identical at act=token)")]


def _prefix_hit_check(c: dict) -> list:
    """A prefix-cache cell that stops hitting is a silent feature loss: the
    smoke workload is built so the queued second wave MUST hit the index
    (deterministic — not a timing check)."""
    if not c.get("prefix_cache") or c.get("prefix_hit_rate", 0) > 0:
        return []
    return [("identity", _cell_key(c),
             f"prefix-cache cell {_cell_key(c)} reports a zero hit rate "
             "(shared-template second wave must reuse the index)")]


def _spec_check(c: dict) -> list:
    """A speculative cell must actually speculate: greedy self-drafting
    accepts every proposal by construction, so committing <= 1 token per
    verify step means the draft/verify/rollback path silently degraded to
    plain decode (deterministic — not a timing check).  Token identity vs
    the non-speculative reference is _identity_check's job and covers the
    spec cells too."""
    if not c.get("speculate_k"):
        return []
    aps = c.get("spec_accepted_per_step") or 0.0
    if aps > 1.0:
        return []
    return [("identity", _cell_key(c),
             f"speculative cell {_cell_key(c)} commits {aps} tokens per "
             "verify step (<= 1.0 means speculation degraded to plain "
             "decode)")]


def _prefix_pairs(blob: dict):
    """(off_cell, on_cell) twins: same sweep point, prefix cache toggled."""
    def twin_key(c):
        return (c["workload"], c["paged"], c["prefill_chunk"],
                c["prefill_budget"], c["load_requests"])
    offs = {twin_key(c): c for c in blob.get("cells", [])
            if not c.get("prefix_cache")}
    return [(offs[twin_key(c)], c) for c in blob.get("cells", [])
            if c.get("prefix_cache") and twin_key(c) in offs]


def _prefix_win_check(new_blob: dict) -> list:
    """The reproducible-TTFT-win gate: each prefix-ON cell must beat its
    OFF twin's mean TTFT.  Classified "timing" so gate_main confirms a
    failure on an independent re-sweep before tripping."""
    failures = []
    for off_c, on_c in _prefix_pairs(new_blob):
        if on_c["ttft_mean_s"] >= off_c["ttft_mean_s"]:
            failures.append(
                ("timing", _cell_key(on_c),
                 f"prefix cell {_cell_key(on_c)}: ttft {on_c['ttft_mean_s']}s "
                 f"not better than cache-off {off_c['ttft_mean_s']}s "
                 "(prefill skip stopped paying for itself)"))
    return failures


def check_regression(old_blob: dict, new_blob: dict,
                     factor: float = REGRESSION_FACTOR) -> list:
    """Shared gate checks (schema drift, dropped cells, >factor
    share-normalized wall regressions — see smoke_gate.check_cells) plus
    the serving-only token-identity, prefix-hit, speculative
    accepted-per-step and TTFT-win checks."""
    return smoke_gate.check_cells(
        old_blob, new_blob, cell_key=_cell_key, cell_keys=CELL_KEYS,
        normalized=_normalized, factor=factor,
        extra_cell_checks=(_identity_check, _prefix_hit_check, _spec_check),
    ) + _prefix_win_check(new_blob)


def main(argv: list | None = None) -> int:
    import argparse
    from functools import partial

    # --seed is this suite's own knob (workload generator determinism);
    # everything else is the shared gate CLI
    ap = argparse.ArgumentParser(add_help=False)
    ap.add_argument("--seed", type=int, default=0)
    args, rest = ap.parse_known_args(argv)
    return smoke_gate.gate_main(
        rest, tag="bench_serve", run=partial(run, seed=args.seed),
        check_regression=check_regression,
        baseline=SMOKE_BASELINE, out=SMOKE_OUT, factor=REGRESSION_FACTOR,
        smoke_help="tiny dense/paged x sequential/batched sweep plus "
                   "shared-prefix and speculative cells, with schema + "
                   "token-identity + prefix-hit + accepted-per-step checks")


if __name__ == "__main__":
    raise SystemExit(main())
