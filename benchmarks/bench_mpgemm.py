"""Paper Table 7 / Figure 7 analogue: mpGEMM throughput ladder by format.

The paper's headline is tokens/s vs bits-per-weight on CPUs.  On this
container we (a) measure the XLA mpGEMM wall time per format at decode
GEMV shapes, and (b) derive the TPU v5e roofline projection: decode is
HBM-bound, so projected tokens/s = HBM_bw / bytes_per_token(format) — the
exact mechanism behind the paper's Figure 7 ordering (b1.67 TL2 > b2 I2_S ≈
TQ2 > b4 Q4 > b16 fp16).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mpgemm, quant
from repro.core.qtensor import FORMAT_BPW, pack_ternary
from repro.launch.roofline import HBM_BW, model_numbers
from repro import configs

FORMATS = ["fp", "int4", "i2s", "tl1", "tl2", "tq1"]
SHAPES = [(3072, 8192), (4096, 11008)]  # (K, M): 3.8B / 7B FFN-ish layers


def _time(fn, *args, reps=5) -> float:
    fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    out.block_until_ready()
    return (time.perf_counter() - t0) / reps * 1e6  # µs


def projected_tokens_per_s(arch: str, fmt: str) -> float:
    """TPU v5e single-chip decode roofline: HBM_bw / model bytes per token."""
    cfg = configs.get(arch)
    n = model_numbers(cfg)["n_active"]
    bpw = FORMAT_BPW[fmt]
    weight_bytes = n * bpw / 8.0
    return HBM_BW / weight_bytes


def run() -> list:
    rows = []
    rng = np.random.default_rng(0)
    for k, m in SHAPES:
        w = jnp.asarray(rng.integers(-1, 2, size=(m, k)), jnp.int8)
        x = jnp.asarray(rng.normal(size=(1, k)), jnp.float32)
        x_q, sx = quant.absmax_int8(x)
        for fmt in FORMATS:
            if fmt == "fp":
                pw = pack_ternary(w, jnp.float32(1.0), "int4")
                pwf = jax.jit(lambda xq, s: mpgemm.mpgemm_xla(
                    xq.astype(jnp.float32), s,
                    type(pw)({"w": w.astype(jnp.bfloat16)}, jnp.float32(1.0), "fp", (m, k))))
                us = _time(pwf, x_q, sx)
            else:
                pw = pack_ternary(w, jnp.float32(1.0), fmt)
                f = jax.jit(lambda xq, s, pw=pw: mpgemm.mpgemm_xla(xq, s, pw))
                us = _time(f, x_q, sx)
            proj = projected_tokens_per_s("bitnet-b1.58-3.8b", fmt)
            rows.append((f"mpgemm_gemv_{fmt}_K{k}_M{m}", us,
                         f"b{FORMAT_BPW[fmt]:.2f}bpw_proj{proj:.0f}tok/s"))
    return rows
