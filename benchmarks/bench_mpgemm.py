"""Paper Table 7 / Figure 7 analogue: mpGEMM regime sweep through the registry.

For each (format × layer shape × regime N) cell we ask the dispatch registry
for its capable lossless kernels, measure each (XLA kernels everywhere;
Pallas kernels only on a real TPU — off-TPU they run in interpret mode,
which benchmarks Python, not the kernel), and record:

  * the registry's *selected* kernel (plan override → autotune → heuristic),
  * the measured winner among benchable candidates,
  * the TPU v5e roofline projection (decode is HBM-bound, so projected
    tokens/s = HBM_bw / bytes_per_token — the mechanism behind the paper's
    Figure 7 ordering b1.67 TL2 > b2 I2_S ≈ TQ2 > b4 Q4 > b16 fp16),
  * a measured tokens/s-equivalent (calls/s scaled to the model's active
    parameter count) so later PRs have a perf trajectory.

Emits ``BENCH_mpgemm.json`` next to the CWD in addition to the CSV rows.
"""

from __future__ import annotations

import json

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core import dispatch, quant
from repro.core.dispatch import _time_call as _time
from repro.core.qtensor import FORMAT_BPW, PackedWeight, pack_ternary
from repro.launch.roofline import HBM_BW, model_numbers

FORMATS = ["fp", "int4", "i2s", "tl1", "tl2", "tl2k", "tq1"]
# (K, M) layer shapes: paper-scale FFN layers on TPU, a same-structure
# reduced sweep on hosts (the XLA LUT one-hot at batched N is CPU-hostile).
SHAPES_TPU = [(3072, 8192), (4096, 11008)]  # 3.8B / 7B FFN-ish layers
SHAPES_HOST = [(768, 2048), (1536, 4096)]
BATCHES = [1, 16, 128]                  # flattened N: decode GEMV → prefill GEMM
ARTIFACT = "BENCH_mpgemm.json"
PROJ_ARCH = "bitnet-b1.58-3.8b"


def projected_tokens_per_s(arch: str, fmt: str) -> float:
    """TPU v5e single-chip decode roofline: HBM_bw / model bytes per token."""
    cfg = configs.get(arch)
    n = model_numbers(cfg)["n_active"]
    bpw = FORMAT_BPW[fmt]
    weight_bytes = n * bpw / 8.0
    return HBM_BW / weight_bytes


def _benchable(spec, hw: str) -> bool:
    # Off-TPU the Pallas kernels execute in interpret mode: correctness
    # vehicles, meaningless (and extremely slow) as timings at these shapes.
    return spec.backend != "pallas" or hw == "tpu"


def run() -> list:
    rows = []
    cells = []
    rng = np.random.default_rng(0)
    hw = jax.default_backend()
    shapes = SHAPES_TPU if hw == "tpu" else SHAPES_HOST
    n_active = model_numbers(configs.get(PROJ_ARCH))["n_active"]
    for k, m in shapes:
        w = jnp.asarray(rng.integers(-1, 2, size=(m, k)), jnp.int8)
        for n in BATCHES:
            x = jnp.asarray(rng.normal(size=(n, k)), jnp.float32)
            x_q, sx = quant.absmax_int8(x)
            regime = "gemv" if n == 1 else "gemm"
            for fmt in FORMATS:
                if fmt == "fp":
                    pw = PackedWeight({"w": w.astype(jnp.bfloat16)},
                                      jnp.float32(1.0), "fp", (m, k))
                else:
                    pw = pack_ternary(w, jnp.float32(1.0), fmt)
                selected = dispatch.explain(fmt, n, k, m)
                cands = dispatch.candidates(fmt, regime, n, k, m)
                timings = {}
                for spec in cands:
                    if not _benchable(spec, hw):
                        continue
                    fn = jax.jit(lambda xq, s, spec=spec: spec.fn(xq, s, pw, None))
                    timings[spec.name] = _time(fn, x_q, sx)
                winner = min(timings, key=timings.get) if timings else None
                us = timings.get(winner, float("nan")) if winner else float("nan")
                # tokens/s-equivalent: this layer scaled to the whole model's
                # active params (how many such GEMM-bytes one token costs).
                tok_s = (1e6 / us) * (k * m / n_active) * n if timings else None
                proj = projected_tokens_per_s(PROJ_ARCH, fmt)
                cells.append({
                    "fmt": fmt, "K": k, "M": m, "N": n, "regime": regime,
                    "selected": selected["kernel"],
                    "selected_source": selected["source"],
                    "measured_us": {kk: round(v, 2) for kk, v in timings.items()},
                    "measured_winner": winner,
                    "tokens_per_s_equiv": round(tok_s, 2) if tok_s else None,
                    "projected_tokens_per_s_v5e": round(proj, 1),
                })
                rows.append((
                    f"mpgemm_{regime}_{fmt}_N{n}_K{k}_M{m}", us,
                    f"sel={selected['kernel']}_win={winner}"
                    f"_b{FORMAT_BPW[fmt]:.2f}bpw_proj{proj:.0f}tok/s"))
    blob = {
        "backend": hw,
        "shapes": shapes,
        "batches": BATCHES,
        "proj_arch": PROJ_ARCH,
        "registry": sorted(dispatch.REGISTRY),
        "cells": cells,
    }
    with open(ARTIFACT, "w") as f:
        json.dump(blob, f, indent=1)
    rows.append((f"artifact_{ARTIFACT}", 0.0, f"{len(cells)}cells"))
    return rows
