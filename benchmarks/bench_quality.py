"""Paper Table 2 analogue: end-to-end inference quality per kernel/format.

Trains a small model with the b1.58 QAT scheme on the synthetic pipeline,
then evaluates held-out NLL (perplexity proxy) under every serving format.
The paper's claim pattern must reproduce exactly:
    Float16(=QAT forward) == I2_S == TL1_1 == TL2_1   (lossless)
    TL1_0 / TL2_0 ≈ but not == (negligible loss)
    Q8_K-block activations != (llama.cpp TQ semantics, not lossless)
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core import dispatch
from repro.core.bitlinear import QuantConfig
from repro.data.pipeline import DataConfig, DataIterator
from repro.models import lm
from repro.train import loop as train_loop

VARIANTS = [
    ("float16_qat", None),  # the QAT forward itself (paper's Float16 row)
    ("i2s", QuantConfig(mode="quant", fmt="i2s")),
    ("tl1_1", QuantConfig(mode="quant", fmt="tl1", plan=dispatch.lut_plan("tl1"))),
    ("tl2_1", QuantConfig(mode="quant", fmt="tl2", plan=dispatch.lut_plan("tl2"))),
    ("tl1_0", QuantConfig(mode="quant", fmt="tl1",
                          plan=dispatch.lut_plan("tl1", lossless=False))),
    ("tl2_0", QuantConfig(mode="quant", fmt="tl2",
                          plan=dispatch.lut_plan("tl2", lossless=False))),
    ("q8_block(TQ-like)", QuantConfig(mode="quant", fmt="i2s", act="block", act_block=48)),
]


def _nll(cfg, params, batches) -> float:
    tot, n = 0.0, 0
    for b in batches:
        loss, _ = lm.loss_fn(params, b, cfg)
        tot += float(loss)
        n += 1
    return tot / n


def run() -> list:
    cfg = configs.smoke("qwen1.5-0.5b").replace(dtype="float32")
    tcfg = train_loop.TrainConfig(
        opt=train_loop.opt.OptConfig(lr=3e-3, warmup_steps=5, total_steps=80))
    dc = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=8)
    state, _ = train_loop.train(cfg, tcfg, DataIterator(dc), n_steps=40)
    held = [next(DataIterator(DataConfig(vocab=cfg.vocab, seq_len=32,
                                         global_batch=8, seed=99))) for _ in range(4)]
    rows = []
    base = None
    for name, qcfg in VARIANTS:
        t0 = time.perf_counter()
        if qcfg is None:
            nll = _nll(cfg, state["params"], held)
        else:
            c = cfg.replace(quant=qcfg)
            nll = _nll(c, lm.pack(state["params"], c), held)
        us = (time.perf_counter() - t0) * 1e6
        if base is None:
            base = nll
        rows.append((f"quality_{name}", us, f"nll{nll:.6f}_delta{nll-base:+.2e}"))
    return rows
