"""Docs-consistency gate: DESIGN.md §2 + README format tables vs the registry.

DESIGN.md §2 and the README's format table are the de-facto format contract
readers (and the conformance harness's prose) rely on — so they must not
drift from the live ``repro.core.formats`` registry.  This check parses the
markdown tables and demands:

  * every registered format name appears as a table row in BOTH documents
    (a registered format undocumented is drift, a documented format that
    was never registered — or got renamed — is worse);
  * each row's bits-per-weight matches ``FormatSpec.bpw`` to 2 decimals.

Table rows are recognized by a first cell holding backticked format names
(``` `tl1` ``` — multiple names per row allowed, e.g. ``` `tl2`/`tl2k` ```)
and a second cell starting with the bpw number.  Keeping the tables literal
— one row per registered variant, no ``{f}_g128``-style pattern rows — is
exactly the point: the registry is enumerable, so the docs can be too.

CI runs ``python -m benchmarks.check_docs`` on every matrix leg (both
hypothesis legs included); run it locally after touching formats.py,
DESIGN.md §2, or the README table.
"""

from __future__ import annotations

import re

from repro.core import formats

DESIGN = "DESIGN.md"
README = "README.md"
_ROW = re.compile(r"^\|\s*(`[^|]+?)\s*\|\s*([0-9.+]+)\s*\|")
_NAME = re.compile(r"`([A-Za-z0-9_]+)`")


def parse_format_rows(text: str) -> dict:
    """{format name: documented bpw} from every markdown table row whose
    first cell is backticked name(s) and second cell a number."""
    out = {}
    for line in text.splitlines():
        mrow = _ROW.match(line)
        if not mrow:
            continue
        try:
            bpw = float(mrow.group(2))
        except ValueError:
            continue
        for name in _NAME.findall(mrow.group(1)):
            out[name] = bpw
    return out


def section(text: str, header: str) -> str:
    """The markdown section starting at ``header`` up to the next ##."""
    start = text.find(header)
    if start < 0:
        return ""
    end = text.find("\n## ", start + len(header))
    return text[start:end] if end > 0 else text[start:]


def check_doc(path: str, scope: str | None = None) -> list:
    with open(path) as f:
        text = f.read()
    if scope:
        text = section(text, scope)
        if not text:
            return [f"{path}: section {scope!r} not found"]
    documented = parse_format_rows(text)
    registered = {f: formats.get(f).bpw for f in formats.names()}
    failures = []
    for name in sorted(set(registered) - set(documented)):
        failures.append(f"{path}: registered format `{name}` "
                        f"({registered[name]:.2f} bpw) missing from the table")
    for name in sorted(set(documented) - set(registered)):
        failures.append(f"{path}: documented format `{name}` is not in the "
                        "registry (renamed or removed?)")
    for name in sorted(set(documented) & set(registered)):
        if abs(documented[name] - registered[name]) > 0.005:
            failures.append(
                f"{path}: `{name}` documented at {documented[name]} bpw, "
                f"registry says {registered[name]:.4g}")
    return failures


_SHARD_ROW = re.compile(
    r"^\|\s*(`[^|]+?)\s*\|\s*(\d+)\s*\|\s*([\d—-]+)\s*\|\s*([\d—-]+)\s*"
    r"\|\s*(\d+)\s*\|\s*(yes|no[^|]*?)\s*\|")


def parse_shard_rows(text: str) -> dict:
    """{format: (k_align, weights_per_unit, occ_block, quantum, k_shardable)}
    from the §12 alignment table ('—' cells parse as 0 / not-applicable)."""

    def cell(s: str) -> int:
        return int(s) if s.isdigit() else 0

    out = {}
    for line in text.splitlines():
        m = _SHARD_ROW.match(line)
        if not m:
            continue
        for name in _NAME.findall(m.group(1)):
            out[name] = (int(m.group(2)), cell(m.group(3)), cell(m.group(4)),
                         int(m.group(5)), m.group(6).strip().startswith("yes"))
    return out


def check_shard_table(path: str) -> list:
    """DESIGN.md §12: the shard-geometry table must match the live registry —
    `k_align`, decode-unit width, occupancy block, `shard_k_quantum`, and
    K-shardability per format (every packable format present)."""
    with open(path) as f:
        text = f.read()
    sec = section(text, "## §12")
    if not sec:
        return [f"{path}: section '## §12' not found"]
    documented = parse_shard_rows(sec)
    failures = []
    packable = [f for f in formats.names() if f != "fp"]
    for name in sorted(set(packable) - set(documented)):
        failures.append(f"{path} §12: format `{name}` missing from the "
                        "shard-geometry table")
    for name in sorted(set(documented) - set(packable)):
        failures.append(f"{path} §12: documented format `{name}` is not in "
                        "the registry")
    for name in sorted(set(documented) & set(packable)):
        spec = formats.get(name)
        live = (max(spec.k_align, 1), spec.weights_per_unit or 0,
                spec.occ_block or 0, spec.shard_k_quantum, spec.k_shardable)
        if documented[name] != live:
            failures.append(
                f"{path} §12: `{name}` table row {documented[name]} != "
                f"registry (k_align, weights/unit, occ_block, quantum, "
                f"k_shardable) = {live}")
    return failures


def main() -> int:
    failures = (check_doc(DESIGN, scope="## §2") + check_doc(README)
                + check_shard_table(DESIGN))
    for msg in failures:
        print(f"[check-docs] FAIL: {msg}")
    if failures:
        print(f"[check-docs] {len(failures)} drift(s) between the docs "
              "tables and the live format registry")
        return 1
    print(f"[check-docs] ok: DESIGN.md §2, the README table, and the §12 "
          f"shard-geometry table match the registry "
          f"({len(formats.names())} formats)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
