"""Benchmark harness package (see run.py for the per-paper-table modules)."""
