"""Sharded-equivalence conformance tier (DESIGN.md §12).

The registry×registry conformance harness (test_conformance.py), extended
over tensor parallelism.  The claims, as executable assertions:

  * SLICING IS EXACT AND SELF-CONTAINED (no mesh needed): for every
    packable format, ``shard_m`` / ``shard_k`` cut each shard as a smaller
    PackedWeight whose planes concatenate back to the unsharded planes
    byte-for-byte — no repack, scale columns travelling with their code
    rows, occupancy bitmaps sliced at block boundaries.  Property-based
    over random (format, M, K, shards); misaligned requests RAISE.

  * THE CONTRACT SURVIVES SHARDING (forced host mesh): for every lossless
    format, M-shard and K-shard mpGEMM over ``shard_map`` equal the
    unsharded dispatch AND the fp64 dequantized-weight oracle at atol=0 on
    2- and 4-device meshes.  K-shard reduces with ONE psum at
    int32-accumulator granularity — per-tensor scales are applied only
    AFTER the reduction.

  * THE GRANULARITY IS LOAD-BEARING: a deliberate wrong-granularity
    K-shard (scale applied per shard BEFORE the psum) with a non-dyadic
    scale MUST diverge from the unsharded output, while the
    accumulator-granularity path stays bit-identical for the same scale —
    pinning WHY the contract holds, not just that it does.

Mesh tests self-skip below 2/4 devices; the tier-1 single-device run covers
them through a subprocess with ``XLA_FLAGS=--xla_force_host_platform_
device_count=4`` executing this file's ``__main__`` sweep (the CI
``tp-host-mesh`` leg runs everything in-process on 4 forced devices).
"""

import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypo import given, settings, st
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import dispatch, formats, packing
from repro.core.dispatch import KernelPlan
from repro.core.qtensor import (PackedWeight, check_shard_k, check_shard_m,
                                pack_quantized, shard_k, shard_m,
                                unpack_weight)
from repro.distributed import tp

INTERPRET = True
PLAN = KernelPlan(interpret=INTERPRET)
M, N = 64, 4
S_X = np.float32(0.25)
PACKABLE = [f for f in formats.names() if f != "fp"]
KSHARDABLE = [f for f in PACKABLE if formats.get(f).k_shardable]
NDEV = len(jax.devices())

needs_mesh2 = pytest.mark.skipif(
    NDEV < 2, reason="needs >=2 devices "
    "(XLA_FLAGS=--xla_force_host_platform_device_count=4)")
needs_mesh4 = pytest.mark.skipif(
    NDEV < 4, reason="needs >=4 devices "
    "(XLA_FLAGS=--xla_force_host_platform_device_count=4)")


def aligned_k(fmt: str, n_shards: int, target: int = 256) -> int:
    """Smallest valid K near ``target`` for this format at this shard count
    (n_shards whole shard_k_quantum granules; k_align for m-only formats)."""
    spec = formats.get(fmt)
    unit = (spec.shard_k_quantum * n_shards if spec.k_shardable
            else max(spec.k_align, 1))
    return unit * max(1, target // unit)


def fixture(fmt: str, k: int, seed: int = 0, m: int = M):
    rng = np.random.default_rng(seed)
    spec = formats.get(fmt)
    lo, hi = spec.levels if spec.base else (-1, 1)
    w = jnp.asarray(rng.integers(lo, hi + 1, size=(m, k)), jnp.int8)
    if spec.group_scale_cols:
        shape = packing.group_scale_shape(m, k, spec.group_scale_cols)
        scale = jnp.asarray(2.0 ** rng.integers(-4, -1, size=shape), jnp.float32)
    else:
        scale = jnp.float32(2.0 ** float(rng.integers(-4, -1)))
    pw = pack_quantized(w, scale, fmt)
    x = jnp.asarray(rng.integers(-127, 128, size=(N, k)), jnp.int8)
    return pw, x


def oracle(x_q, pw) -> np.ndarray:
    w_q = np.asarray(unpack_weight(pw), np.float64)
    if pw.scale.ndim:
        s = np.asarray(packing.expand_group_scales(pw.scale, pw.k), np.float64)
    else:
        s = float(pw.scale)
    return (np.asarray(x_q, np.float64) * float(S_X)) @ (w_q * s).T


def _mesh(n_shards: int) -> Mesh:
    return Mesh(np.array(jax.devices()[:n_shards]), ("model",))


# ---------------------------------------------------------------------------
# Slicing: concat reconstructs exactly; shards are self-contained
# ---------------------------------------------------------------------------


def _assert_concat_reconstructs(pw, shards, axis_of):
    for name, plane in pw.planes.items():
        cat = np.concatenate([np.asarray(s.planes[name]) for s in shards],
                             axis=axis_of(name))
        np.testing.assert_array_equal(cat, np.asarray(plane),
                                      err_msg=f"{pw.fmt} plane {name!r}")


@pytest.mark.parametrize("fmt", PACKABLE)
@pytest.mark.parametrize("n_shards", [2, 4])
def test_shard_m_concat_reconstructs(fmt, n_shards):
    """M-shard = pure row slice of every plane + column slice of the grouped
    scale plane; concatenation is the identity."""
    pw, _ = fixture(fmt, aligned_k(fmt, 1))
    shards = shard_m(pw, n_shards)
    assert all(s.m == M // n_shards and s.k == pw.k for s in shards)
    _assert_concat_reconstructs(pw, shards, lambda name: 0)
    if pw.scale.ndim:
        cat = np.concatenate([np.asarray(s.scale) for s in shards], axis=1)
        np.testing.assert_array_equal(cat, np.asarray(pw.scale))
    else:
        assert all(float(s.scale) == float(pw.scale) for s in shards)


@pytest.mark.parametrize("fmt", KSHARDABLE)
@pytest.mark.parametrize("n_shards", [2, 4])
def test_shard_k_concat_reconstructs_and_is_self_contained(fmt, n_shards):
    """K-shard = contiguous byte slice per plane (occ at block granularity,
    scale at group rows); each shard is byte-identical to independently
    repacking its weight slice — fully self-contained."""
    k = aligned_k(fmt, n_shards)
    pw, _ = fixture(fmt, k)
    w = np.asarray(unpack_weight(pw), np.int8)
    shards = shard_k(pw, n_shards)
    k_loc = k // n_shards
    assert all(s.m == M and s.k == k_loc for s in shards)
    _assert_concat_reconstructs(pw, shards, lambda name: 1)
    for i, s in enumerate(shards):
        # the shard unpacks to exactly its weight-column slice...
        np.testing.assert_array_equal(np.asarray(unpack_weight(s), np.int8),
                                      w[:, i * k_loc:(i + 1) * k_loc])
        # ...and equals an independent repack of that slice (no hidden
        # dependence on neighbouring shards' bytes)
        ref = pack_quantized(
            jnp.asarray(w[:, i * k_loc:(i + 1) * k_loc]),
            s.scale if pw.scale.ndim else pw.scale, fmt)
        for name in pw.planes:
            np.testing.assert_array_equal(np.asarray(s.planes[name]),
                                          np.asarray(ref.planes[name]),
                                          err_msg=f"{fmt} shard {i} {name!r}")


@settings(max_examples=40, deadline=None)
@given(
    fmt=st.sampled_from(PACKABLE),
    m_units=st.integers(1, 8),
    k_units=st.integers(1, 4),
    n_shards=st.sampled_from([2, 3, 4]),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_shard_slicing_reconstructs(fmt, m_units, k_units, n_shards,
                                             seed):
    """Satellite property: ANY validly-aligned (format, M, K, shards) slices
    losslessly — concat of per-shard packed bytes / scale planes / occupancy
    maps is the unsharded tensor, exactly."""
    spec = formats.get(fmt)
    m = n_shards * m_units
    k = (spec.shard_k_quantum if spec.k_shardable
         else max(spec.k_align, 1)) * n_shards * k_units
    pw, _ = fixture(fmt, k, seed=seed, m=m)
    ms = shard_m(pw, n_shards)
    _assert_concat_reconstructs(pw, ms, lambda name: 0)
    if pw.scale.ndim:
        np.testing.assert_array_equal(
            np.concatenate([np.asarray(s.scale) for s in ms], axis=1),
            np.asarray(pw.scale))
    if spec.k_shardable:
        ks = shard_k(pw, n_shards)
        _assert_concat_reconstructs(pw, ks, lambda name: 1)
        if pw.scale.ndim:
            np.testing.assert_array_equal(
                np.concatenate([np.asarray(s.scale) for s in ks], axis=0),
                np.asarray(pw.scale))


@settings(max_examples=20, deadline=None)
@given(fmt=st.sampled_from([f for f in KSHARDABLE
                            if formats.get(f).shard_k_quantum > 1]),
       n_shards=st.sampled_from([2, 4]))
def test_property_misaligned_shard_raises(fmt, n_shards):
    """A shard boundary inside a decode unit / scale group / occupancy block
    RAISES — it is never silently repacked."""
    spec = formats.get(fmt)
    q = spec.shard_k_quantum
    # K divides by n_shards but each shard is a HALF-quantum off
    k = q * n_shards * 2 + n_shards * (q // 2 if q % 2 == 0 else 1)
    if (k // n_shards) % q == 0:  # (q=1 can't misalign; filtered above)
        return
    pw, _ = fixture(fmt, q * n_shards * 2)
    with pytest.raises(ValueError, match="shard quantum"):
        check_shard_k(spec, k, n_shards)
    with pytest.raises(ValueError):
        shard_m(pw, 7)  # M=64 % 7 != 0


def test_split_k_formats_refuse_k_shard():
    """tl2/tl2k: the ThreeK/TwoK split is a function of the FULL K — a
    row-parallel shard would need a repack, so they refuse (shard M)."""
    for fmt in ("tl2", "tl2k"):
        assert not formats.get(fmt).k_shardable
        pw, _ = fixture(fmt, aligned_k(fmt, 1))
        with pytest.raises(ValueError, match="split-K"):
            shard_k(pw, 2)
        shard_m(pw, 2)  # M-shard still fine


def test_occupancy_block_misalignment_raises():
    """_z formats: a boundary inside a 64-column occupancy block raises."""
    spec = formats.get("tl1_z")
    assert spec.shard_k_quantum % spec.occ_block == 0
    with pytest.raises(ValueError, match="shard quantum"):
        check_shard_k(spec, 96, 2)  # 48 per shard: inside an occ block


def test_check_shard_m_rejects_indivisible():
    with pytest.raises(ValueError, match="column-parallel"):
        check_shard_m(63, 2)
    assert check_shard_m(64, 4) == 16


# ---------------------------------------------------------------------------
# Sequential equivalence (no mesh): the accumulator-granularity argument
# holds shard by shard on one device
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("fmt", PACKABLE)
def test_sequential_mshard_equivalence(fmt):
    """Concat of per-shard mpGEMM outputs == unsharded == fp64 oracle at
    atol=0 — each M shard is a complete smaller problem."""
    pw, x = fixture(fmt, aligned_k(fmt, 1))
    ref = oracle(x, pw)
    y_un = np.asarray(dispatch.mpgemm(x, S_X, pw, PLAN), np.float64)
    np.testing.assert_array_equal(y_un, ref)
    parts = [np.asarray(dispatch.mpgemm(x, S_X, s, PLAN), np.float64)
             for s in shard_m(pw, 2)]
    np.testing.assert_array_equal(np.concatenate(parts, axis=1), ref,
                                  err_msg=f"{fmt} sequential M-shard")


@pytest.mark.parametrize("fmt", KSHARDABLE)
def test_sequential_kshard_accumulator_granularity(fmt):
    """Host-side emulation of the ONE-psum contract: per-tensor formats sum
    UNIT-SCALE shard outputs (exact int32 accumulators in fp32) and scale
    once after; grouped formats sum in-kernel-scaled shard outputs (group
    boundaries never straddle shards).  Equals the oracle at atol=0."""
    k = aligned_k(fmt, 2)
    pw, x = fixture(fmt, k)
    ref = oracle(x, pw)
    k_loc = k // 2
    acc = np.zeros((N, M), np.float64)
    for i, s in enumerate(shard_k(pw, 2)):
        xl = x[:, i * k_loc:(i + 1) * k_loc]
        if pw.scale.ndim:  # grouped: kernel applies group scales + S_X
            acc += np.asarray(dispatch.mpgemm(xl, S_X, s, PLAN), np.float64)
        else:  # per-tensor: unit scales -> raw accumulator
            raw = dispatch.mpgemm(
                xl, jnp.float32(1.0),
                dataclasses.replace(s, scale=jnp.float32(1.0)), PLAN)
            acc += np.asarray(raw, np.float64)
    if not pw.scale.ndim:
        acc *= float(S_X) * float(pw.scale)
    np.testing.assert_array_equal(acc, ref,
                                  err_msg=f"{fmt} sequential K-shard")


# ---------------------------------------------------------------------------
# Mesh execution: registry × registry on forced host devices
# ---------------------------------------------------------------------------


def run_mesh_sweep(fmt: str, n_shards: int) -> None:
    """M-shard and K-shard shard_map mpGEMM ≡ unsharded ≡ fp64 oracle at
    atol=0 on an ``n_shards``-device mesh (also exercised by __main__)."""
    spec = formats.get(fmt)
    mesh = _mesh(n_shards)
    k = aligned_k(fmt, n_shards)
    pw, x = fixture(fmt, k)
    ref = oracle(x, pw)
    y_un = np.asarray(dispatch.mpgemm(x, S_X, pw, PLAN), np.float64)
    np.testing.assert_array_equal(y_un, ref, err_msg=f"{fmt} unsharded")
    y_m = np.asarray(tp.mpgemm_mshard(x, S_X, pw, mesh, plan=PLAN), np.float64)
    np.testing.assert_array_equal(y_m, ref, err_msg=f"{fmt} mshard x{n_shards}")
    if spec.k_shardable:
        y_k = np.asarray(tp.mpgemm_kshard(x, S_X, pw, mesh, plan=PLAN),
                         np.float64)
        np.testing.assert_array_equal(y_k, ref,
                                      err_msg=f"{fmt} kshard x{n_shards}")
    else:
        with pytest.raises(ValueError, match="split-K"):
            tp.mpgemm_kshard(x, S_X, pw, mesh, plan=PLAN)


@needs_mesh2
@pytest.mark.parametrize("fmt", PACKABLE)
def test_mesh2_conformance(fmt):
    run_mesh_sweep(fmt, 2)


@needs_mesh4
@pytest.mark.parametrize("fmt", PACKABLE)
def test_mesh4_conformance(fmt):
    run_mesh_sweep(fmt, 4)


def run_witness(n_shards: int = 2) -> float:
    """The wrong-granularity witness.  With a NON-dyadic per-tensor scale:

      psum(unit-scale accumulators) * scale   == unsharded, bit for bit;
      psum(scale * shard partials)            DIVERGES,

    because fp32 rounds scale*partial per shard and the rounding errors do
    not cancel.  Returns the witnessed max |delta| (must be > 0)."""
    fmt = "i2s"
    spec = formats.get(fmt)
    mesh = _mesh(n_shards)
    k = spec.shard_k_quantum * n_shards * 32
    rng = np.random.default_rng(99)
    w = jnp.asarray(rng.integers(-1, 2, size=(M, k)), jnp.int8)
    pw = pack_quantized(w, jnp.float32(0.3), fmt)  # 0.3: not a power of two
    x = jnp.asarray(rng.integers(-127, 128, size=(N, k)), jnp.int8)
    y_un = np.asarray(dispatch.mpgemm(x, S_X, pw, PLAN))
    # the RIGHT granularity stays bit-identical even for non-dyadic scales
    y_k = np.asarray(tp.mpgemm_kshard(x, S_X, pw, mesh, plan=PLAN))
    np.testing.assert_array_equal(y_k, y_un)
    k_loc = k // n_shards

    def scale_before_psum(xl, planes, scale, sx):
        lpw = PackedWeight(planes, scale, fmt, (M, k_loc))
        return jax.lax.psum(dispatch.mpgemm(xl, sx, lpw, PLAN), "model")

    y_wrong = np.asarray(shard_map(
        scale_before_psum, mesh=mesh,
        in_specs=(P(None, "model"),
                  {n: P(None, "model") for n in pw.planes}, P(), P()),
        out_specs=P(None, None))(x, pw.planes, pw.scale, jnp.float32(S_X)))
    assert not np.array_equal(y_wrong, y_un), (
        "scale-before-psum failed to diverge: the witness no longer "
        "witnesses (did scales become dyadic?)")
    return float(np.abs(y_wrong - y_un).max())


@needs_mesh2
def test_wrong_granularity_witness_diverges():
    assert run_witness(2) > 0


@needs_mesh2
def test_decisions_record_shard_local_shapes():
    """Dispatch decisions made inside shard_map carry the SHARD-LOCAL M/K —
    the shapes each device actually runs, hence what autotune keys see."""
    fmt = "int2"
    n_shards = 2
    k = aligned_k(fmt, n_shards, target=512)  # unique K: forces a fresh trace
    pw, x = fixture(fmt, k)
    mark = dispatch.decision_count()
    tp.mpgemm_kshard(x, S_X, pw, _mesh(n_shards), plan=PLAN)
    ks = {d.k for d in dispatch.decisions_since(mark)}
    assert k // n_shards in ks and k not in ks
    mark = dispatch.decision_count()
    tp.mpgemm_mshard(x, S_X, pw, _mesh(n_shards), plan=PLAN)
    ms = {d.m for d in dispatch.decisions_since(mark)}
    assert M // n_shards in ms and M not in ms
    # and the explain/autotune preview maps global -> shard-local the same way
    assert dispatch.shard_shapes([(N, k, M)], tp=n_shards, tp_dim="k") == \
        [(N, k // n_shards, M)]
    assert dispatch.shard_shapes([(N, k, M)], tp=n_shards, tp_dim="m") == \
        [(N, k, M // n_shards)]


@needs_mesh2
@pytest.mark.parametrize("fmt", ["i2s", "int3_g128", "tl1_z", "int3_bc"])
def test_packed_sharding_places_exact_shard_bytes(fmt):
    """device_put under packed_sharding puts on device i EXACTLY the bytes
    shard_k/shard_m would cut — sharded placement is a layout no-op."""
    n_shards = 2
    k = aligned_k(fmt, n_shards)
    pw, _ = fixture(fmt, k)
    mesh = _mesh(n_shards)
    for dim, cut in (("m", shard_m), ("k", shard_k)):
        pw_dev = jax.device_put(pw, tp.packed_sharding(pw, mesh, dim=dim))
        cuts = cut(pw, n_shards)
        for name, plane in pw_dev.planes.items():
            for sh in plane.addressable_shards:
                np.testing.assert_array_equal(
                    np.asarray(sh.data),
                    np.asarray(cuts[sh.device.id % n_shards].planes[name]),
                    err_msg=f"{fmt} {dim}-shard plane {name!r}")


# ---------------------------------------------------------------------------
# Single-device fallback: the mesh sweep runs in a forced-4-device subprocess
# ---------------------------------------------------------------------------


@pytest.mark.skipif(NDEV >= 2, reason="mesh tests already ran in-process")
def test_mesh_sweep_subprocess():
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = {**os.environ,
           "XLA_FLAGS": "--xla_force_host_platform_device_count=4",
           "PYTHONPATH": "src" + os.pathsep + "tests"}
    r = subprocess.run([sys.executable, os.path.abspath(__file__)],
                       capture_output=True, text=True, env=env, cwd=repo)
    assert r.returncode == 0, (r.stdout[-2000:], r.stderr[-2000:])
    assert "SHARDED MESH SWEEP OK" in r.stdout


if __name__ == "__main__":
    # the forced-mesh sweep the subprocess fallback (and hand smoke) runs:
    # every format × {2, 4} devices × {M, K} shard vs the fp64 oracle,
    # plus the wrong-granularity witness
    assert NDEV >= 4, f"run with XLA_FLAGS forcing >=4 host devices, got {NDEV}"
    for _fmt in PACKABLE:
        for _n in (2, 4):
            run_mesh_sweep(_fmt, _n)
        print(f"{_fmt}: mesh 2+4 conform", flush=True)
    delta = run_witness(2)
    print(f"witness diverges: max |delta| = {delta:g}")
    print("SHARDED MESH SWEEP OK")
