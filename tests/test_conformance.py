"""Format-conformance harness (ISSUE 5): every registered FormatSpec is
gated at registration, not by hand-written per-format tests.

For EVERY format in ``repro.core.formats.REGISTRY`` this suite asserts:

  (a) pack -> unpack is a bijection on full-code-range matrices, property-
      based over K-aligned shapes (hypothesis, or the _hypo stub sweep);
  (b) every REGISTERED KERNEL capable of the format (XLA unpack dot, XLA
      one-hot LUT, fused Pallas MAD, true-LUT GEMV — whatever the dispatch
      registry enumerates) reproduces the fp64 oracle on the dequantized
      weights EXACTLY (atol=0) when ``FormatSpec.lossless``;
  (c) the GEMV (N=1) and GEMM (N>1) regimes agree row-for-row under the
      default dispatch plan.

Exactness methodology: scales are DYADIC (powers of two) and shapes small
enough that every intermediate is an integer times a power of two with
magnitude < 2^24 — then every fp32 multiply/add is exact, the result is
independent of summation order, and the fp64 oracle equals the fp32 kernel
output bit for bit.  Real absmean scales introduce only fp32 rounding in
the final per-group scale application (covered at tight rtol by
test_real_scales_tight_rtol); the INTEGER accumulation is exact either way.

A new ``formats.register(...)`` call lands in every one of these tests
automatically — including the grouped-scale variants (G=128), whose
[K//G, M] scale planes ride the same oracle.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypo import given, settings, st

from repro.core import dispatch, formats, packing
from repro.core.dispatch import KernelPlan
from repro.core.qtensor import pack_quantized, pack_weight, unpack_weight

INTERPRET = True  # CPU container: Pallas kernel bodies execute via interpret

PACKABLE = [f for f in formats.names() if f != "fp"]
LOSSLESS = [f for f in formats.names() if formats.get(f).lossless]
M, K, N_GEMM = 64, 256, 4
S_X = np.float32(0.25)  # dyadic activation scale


def random_codes(rng: np.random.Generator, fmt: str, m: int, k: int) -> jnp.ndarray:
    spec = formats.get(fmt)
    lo, hi = spec.levels if spec.base else (-1, 1)
    return jnp.asarray(rng.integers(lo, hi + 1, size=(m, k)), jnp.int8)


def dyadic_scale(rng: np.random.Generator, fmt: str, m: int, k: int):
    """Power-of-two scale (plane for grouped formats, scalar otherwise) with
    a small exponent spread, keeping every partial sum < 2^24 in units of
    the smallest scale — the order-independence bound."""
    spec = formats.get(fmt)
    if spec.group_scale_cols:
        shape = packing.group_scale_shape(m, k, spec.group_scale_cols)
        return jnp.asarray(2.0 ** rng.integers(-4, -1, size=shape), jnp.float32)
    return jnp.float32(2.0 ** float(rng.integers(-4, -1)))


def packed_fixture(fmt: str, seed: int = 0):
    rng = np.random.default_rng(seed)
    w = random_codes(rng, fmt, M, K)
    scale = dyadic_scale(rng, fmt, M, K)
    pw = pack_quantized(w, scale, fmt)
    x1 = jnp.asarray(rng.integers(-127, 128, size=(1, K)), jnp.int8)
    xn = jnp.asarray(rng.integers(-127, 128, size=(N_GEMM, K)), jnp.int8)
    return w, pw, x1, xn


def oracle(x_q, pw) -> np.ndarray:
    """fp64 reference on the DEQUANTIZED weights — exact rational arithmetic
    at these shapes, equal bit-for-bit to a lossless kernel's fp32 output
    under dyadic scales."""
    w_q = np.asarray(unpack_weight(pw), np.float64)
    if pw.scale.ndim:
        s = np.asarray(packing.expand_group_scales(pw.scale, pw.k), np.float64)
    else:
        s = float(pw.scale)
    return (np.asarray(x_q, np.float64) * float(S_X)) @ (w_q * s).T


# ---------------------------------------------------------------------------
# (a) pack -> unpack bijection, property-based over K-aligned shapes
# ---------------------------------------------------------------------------


@settings(max_examples=40, deadline=None)
@given(
    m=st.integers(1, 9),
    units=st.integers(1, 5),
    seed=st.integers(0, 2**31 - 1),
    fmt=st.sampled_from(PACKABLE),
)
def test_conformance_roundtrip(m, units, seed, fmt):
    spec = formats.get(fmt)
    k = spec.k_align * units
    rng = np.random.default_rng(seed)
    w = random_codes(rng, fmt, m, k)
    scale = dyadic_scale(rng, fmt, m, k)
    pw = pack_quantized(w, scale, fmt)
    np.testing.assert_array_equal(np.asarray(unpack_weight(pw), np.int8),
                                  np.asarray(w))
    np.testing.assert_array_equal(np.asarray(pw.scale), np.asarray(scale))
    if spec.group_scale_cols:
        assert pw.scale.shape == (k // spec.group_scale_cols, m)


# ---------------------------------------------------------------------------
# (b) every capable registered kernel == the fp64 dequantized-weight oracle
# ---------------------------------------------------------------------------


def expected_candidates(fmt: str, regime: str) -> set:
    """The lossless kernel set a format's spec flags promise — mirrors the
    dispatch enumeration so a capability list silently shedding a format
    (the kernel still registered, the format gone from its fmts) fails
    here instead of shrinking the sweep unnoticed."""
    spec = formats.get(fmt)
    names = {"xla"}
    if fmt == "int4":
        names.add("int4")
    if spec.supports_lut_gemv() or fmt == "tl2":
        names.add(f"{fmt}_lut")
    if spec.pallas:
        names.add("pallas")
    if regime == "gemv" and spec.supports_lut_gemv():
        names.add("lut_gemv")
    return names


@pytest.mark.parametrize("regime_n", [1, N_GEMM], ids=["gemv", "gemm"])
@pytest.mark.parametrize("fmt", formats.names())
def test_conformance_kernels_vs_oracle(fmt, regime_n):
    """Registry × registry: run EVERY lossless-capable KernelSpec on the
    format and demand exact agreement with the oracle (atol=0).  The
    candidate set is asserted against the spec's own capability flags —
    a kernel silently dropping a format fails the set equality, not just
    a non-emptiness check."""
    spec = formats.get(fmt)
    if fmt == "fp":
        pytest.skip("fp baseline: no integer semantics (lossless=False)")
    assert spec.lossless, f"non-fp format {fmt!r} must be lossless"
    _, pw, x1, xn = packed_fixture(fmt)
    x_q = x1 if regime_n == 1 else xn
    regime = "gemv" if regime_n == 1 else "gemm"
    cands = dispatch.candidates(fmt, regime, regime_n, K, M)
    assert {s.name for s in cands} == expected_candidates(fmt, regime)
    ref = oracle(x_q, pw)
    for kspec in cands:
        y = np.asarray(kspec.fn(x_q, S_X, pw, INTERPRET), np.float64)
        np.testing.assert_array_equal(
            y, ref, err_msg=f"{kspec.name} not exact on {fmt}")


def test_conformance_fp_baseline_close():
    """fp is exempt from atol=0 (bf16 storage) but must stay close."""
    rng = np.random.default_rng(3)
    w = jnp.asarray(rng.integers(-1, 2, size=(M, K)), jnp.float32) * 0.5
    pw = pack_weight(w, "fp")
    x_q = jnp.asarray(rng.integers(-127, 128, size=(2, K)), jnp.int8)
    y = np.asarray(dispatch.mpgemm(x_q, S_X, pw, KernelPlan(gemv="xla", gemm="xla")))
    ref = (np.asarray(x_q, np.float64) * float(S_X)) @ np.asarray(w, np.float64).T
    np.testing.assert_allclose(y, ref, rtol=2e-2, atol=1e-2)


# ---------------------------------------------------------------------------
# (c) GEMV and GEMM regimes agree under the default dispatch plan
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("fmt", PACKABLE)
def test_conformance_regimes_agree(fmt):
    """The N=1 decode path (lut_gemv / fused Pallas / XLA — whatever the
    heuristic picks) and the batched GEMM path must produce identical rows
    for identical inputs; exact for lossless formats under dyadic scales."""
    spec = formats.get(fmt)
    _, pw, _, xn = packed_fixture(fmt, seed=11)
    assert spec.lossless  # every packable format carries the exact contract
    plan = KernelPlan(interpret=INTERPRET)
    y_gemm = np.asarray(dispatch.mpgemm(xn, S_X, pw, plan), np.float64)
    for i in range(N_GEMM):
        y_row = np.asarray(dispatch.mpgemm(xn[i : i + 1], S_X, pw, plan),
                           np.float64)[0]
        np.testing.assert_array_equal(
            y_row, y_gemm[i], err_msg=f"{fmt} row {i} regime mismatch")


# ---------------------------------------------------------------------------
# Grouped <-> per-tensor consistency and real-scale sanity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("fmt", formats.grouped_formats())
def test_grouped_broadcast_matches_per_tensor_base(fmt):
    """A grouped format with every group sharing one dyadic scale computes
    exactly what the per-tensor base format computes — grouping is a pure
    generalization of the numeric contract."""
    base = fmt.rsplit("_g", 1)[0]
    rng = np.random.default_rng(7)
    w = random_codes(rng, fmt, M, K)
    s = jnp.float32(0.5)
    pw_g = pack_quantized(w, s, fmt)      # scalar broadcast to [K//G, M]
    pw_b = pack_quantized(w, s, base)
    x_q = jnp.asarray(rng.integers(-127, 128, size=(3, K)), jnp.int8)
    plan = KernelPlan(gemv="xla", gemm="xla")
    y_g = np.asarray(dispatch.mpgemm(x_q, S_X, pw_g, plan))
    y_b = np.asarray(dispatch.mpgemm(x_q, S_X, pw_b, plan))
    np.testing.assert_array_equal(y_g, y_b)


@pytest.mark.parametrize("fmt", formats.grouped_formats())
def test_real_scales_tight_rtol(fmt):
    """Real (non-dyadic) per-group absmean scales: the integer accumulation
    is still exact, so every kernel stays within fp32 rounding of the
    oracle."""
    rng = np.random.default_rng(13)
    w_fp = jnp.asarray(rng.normal(size=(M, K)), jnp.float32)
    pw = pack_weight(w_fp, fmt)
    assert pw.scale.shape == (K // formats.get(fmt).group_scale_cols, M)
    x_q = jnp.asarray(rng.integers(-127, 128, size=(2, K)), jnp.int8)
    ref = oracle(x_q, pw)
    y = np.asarray(dispatch.mpgemm(x_q, S_X, pw, KernelPlan(interpret=INTERPRET)))
    np.testing.assert_allclose(y, ref, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("fmt", [f for f in formats.grouped_formats()
                                 if formats.get(f).supports_lut_gemv()])
def test_grouped_lossy_kernels_bounded(fmt):
    """The T-MAC int8-requantized-table (lossy) paths on GROUPED formats:
    the global table scale must compose with the per-group weight scales
    (applied once, outside the group sum) — bounded nonzero deviation in
    both the XLA one-hot path and the true-LUT GEMV kernel."""
    from repro.core import elut
    from repro.kernels import ops

    _, pw, x1, xn = packed_fixture(fmt, seed=23)
    ref = oracle(xn, pw)
    y0 = np.asarray(elut.elut_mpgemm(xn, S_X, pw, lossless=False))
    rel = np.abs(y0 - ref).max() / np.abs(ref).max()
    assert 0 < rel < 0.05, rel
    ref1 = oracle(x1, pw)[0]
    y1 = np.asarray(ops.lut_gemv(x1.reshape(-1), S_X, pw, lossless=False,
                                 interpret=INTERPRET))
    rel1 = np.abs(y1 - ref1).max() / np.abs(ref1).max()
    assert 0 < rel1 < 0.05, rel1


def test_grouped_quantize_per_group_granularity():
    """The per-group absmean rule actually varies scales across groups and
    beats the per-tensor rule on a weight with heterogeneous column-group
    magnitudes (the GPTQ/AWQ checkpoint shape this feature exists for)."""
    rng = np.random.default_rng(5)
    w = np.ones((4, 256), np.float32) * 0.01
    w[:, 128:] = rng.normal(size=(4, 128)).astype(np.float32)  # hot tail group
    w = jnp.asarray(w)
    pw_g = pack_weight(w, "int2_g128")
    pw_t = pack_weight(w, "int2")
    s = np.asarray(pw_g.scale)
    assert s.shape == (2, 4) and (s[0] < s[1]).all()
    codes_g = np.asarray(unpack_weight(pw_g), np.float64)
    codes_t = np.asarray(unpack_weight(pw_t), np.float64)
    err_g = np.abs(codes_g * np.asarray(
        packing.expand_group_scales(pw_g.scale, 256)) - np.asarray(w)).mean()
    err_t = np.abs(codes_t * float(pw_t.scale) - np.asarray(w)).mean()
    assert err_g < err_t


def test_grouped_dispatch_cost_accounts_scale_read():
    """The [K//G, M] fp32 scale plane shows up in the cost hints of kernels
    whose HBM traffic is kernel-specified (unpacked/one-hot operands)."""
    base = dispatch.REGISTRY["xla"].cost("int2", 16, 512, 256)
    grouped = dispatch.REGISTRY["xla"].cost("int2_g128", 16, 512, 256)
    assert grouped > base
    # and the autotune key distinguishes the group size
    k_base = dispatch.AutotuneCache.key("cpu", "int2", 16, 512, 256)
    k_grp = dispatch.AutotuneCache.key("cpu", "int2_g128", 16, 512, 256)
    assert "G128" in k_grp and "G128" not in k_base
