"""Format registry + parametric ELUT engine (DESIGN.md §2, paper Appendix).

The refactor's acceptance claims, as executable assertions:
  * every REGISTERED format round-trips pack/unpack over its full code
    range, property-based, including tl2 split-K with K not divisible by 24
    and the non-ternary int2/int3 formats;
  * the ternary ELUT instances are bit-identical to the legacy tl1/tl2/
    lut_gemv kernels on matched shapes (the legacy kernels' contract was
    exact int32 equality with the MAD oracle and the XLA LUT references —
    asserted here against both, so equality is transitive and exact);
  * int2/int3 pass mpGEMM-vs-fp32-reference through the same
    registry-driven dispatch, GEMV and GEMM regimes;
  * the serve-facing engine routes non-ternary ELUT decode through the
    true-LUT GEMV kernel exactly like tl1.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypo import given, settings, st

from repro import configs
from repro.core import dispatch, elut, formats, mpgemm, packing
from repro.core.bitlinear import QuantConfig
from repro.core.dispatch import KernelPlan
from repro.core.qtensor import pack_quantized, pack_weight, unpack_weight
from repro.infer.engine import Engine, Request
from repro.models import lm

INTERPRET = True  # CPU container: Pallas kernel bodies execute via interpret

PACKABLE = [f for f in formats.names() if f != "fp"]


def random_codes(rng: np.random.Generator, fmt: str, m: int, k: int) -> jnp.ndarray:
    """Full-range code matrix for a format (ternary for native int4)."""
    spec = formats.get(fmt)
    lo, hi = spec.levels if spec.base else (-1, 1)
    return jnp.asarray(rng.integers(lo, hi + 1, size=(m, k)), jnp.int8)


# ---------------------------------------------------------------------------
# Registry round-trips (property-based, full code range)
# ---------------------------------------------------------------------------


@settings(max_examples=30, deadline=None)
@given(
    m=st.integers(1, 9),
    k_units=st.sampled_from([4, 13, 25, 192, 250]),  # K = 4·u: 16, 52, 100, 768, 1000
    seed=st.integers(0, 2**31 - 1),
    fmt=st.sampled_from(PACKABLE),
)
def test_registry_roundtrip_property(m, k_units, seed, fmt):
    """Pack/unpack is a bijection on valid code matrices for EVERY registered
    format.  K = 4·k_units deliberately includes values not divisible by 24
    (52, 100, 1000): tl2/tl2k exercise block-fitting split-K with a tl1 tail.
    Formats with a stricter k_align (the grouped-scale variants: 128) round
    K up to their alignment."""
    spec = formats.get(fmt)
    k = 4 * k_units
    if k % spec.k_align:
        k = -(-k // spec.k_align) * spec.k_align
    rng = np.random.default_rng(seed)
    w = random_codes(rng, fmt, m, k)
    pw = pack_quantized(w, jnp.float32(1.0), fmt)
    rt = unpack_weight(pw)
    np.testing.assert_array_equal(np.asarray(rt, np.int8), np.asarray(w))


def test_tl2_split_k_not_multiple_of_24():
    """K=1000: ThreeK=984 (tl2 planes) + TwoK=16 (tl1 tail), exact."""
    rng = np.random.default_rng(24)
    w = random_codes(rng, "tl2", 8, 1000)
    pw = pack_quantized(w, jnp.float32(1.0), "tl2")
    assert pw.three_k == 984 and set(pw.planes) == {"idx", "sign", "tail"}
    np.testing.assert_array_equal(np.asarray(unpack_weight(pw)), np.asarray(w))


def test_format_spec_derived_quantities():
    """The napkin math the cost hints are built from, per spec."""
    tl1 = formats.get("tl1")
    assert (tl1.base, tl1.group, tl1.lut_size) == (3, 2, 9)
    assert tl1.mxu_inflation == pytest.approx(4.5)      # C/g = 9/2
    assert tl1.lut_hbm_bpw == pytest.approx(36.0)       # 8·C/g
    int2 = formats.get("int2")
    assert (int2.base, int2.group, int2.lut_size) == (4, 2, 16)
    assert int2.levels == (-2, 1) and int2.bpw == 2.0
    assert int2.mxu_inflation == pytest.approx(8.0)
    int3 = formats.get("int3")
    assert (int3.base, int3.group, int3.lut_size) == (8, 2, 64)
    assert int3.levels == (-4, 3) and int3.bpw == 4.0
    assert int3.mxu_inflation == pytest.approx(32.0)
    tl2 = formats.get("tl2")
    assert tl2.lut_size == 14                            # folded mirror table
    assert tl2.mxu_inflation == pytest.approx(14 / 3)
    assert formats.lut_gemv_formats() == (
        "tl1", "int2", "int3", "tl1_g128", "int2_g128", "int3_g128",
        "int3_bc", "tl1_z", "int3_bc_z")
    assert not formats.get("i2s").supports_lut_gemv()    # g=1: no table win
    assert not formats.get("i2s_g128").supports_lut_gemv()
    # grouped variants: same (b, g) napkin math, +32/G bpw for the scale plane
    int2g = formats.get("int2_g128")
    assert int2g.group_scale_cols == 128 and int2g.k_align == 128
    assert int2g.bpw == pytest.approx(2.25)
    assert int2g.mxu_inflation == pytest.approx(8.0)
    assert formats.grouped_formats() == (
        "i2s_g128", "tl1_g128", "tq1_g128", "int2_g128", "int3_g128")


def test_unknown_format_rejected():
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.integers(-1, 2, size=(4, 16)), jnp.int8)
    with pytest.raises(ValueError, match="unknown format"):
        pack_quantized(w, jnp.float32(1.0), "int5")


# ---------------------------------------------------------------------------
# Ternary ELUT instances == legacy tl1/tl2/lut_gemv behaviour (bit-identical)
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(1, 4))
def test_elut_ternary_matches_legacy_tl1_lut(seed, n):
    """elut_mpgemm at (3, 2) == the legacy tl1_lut one-hot reference ==
    the MAD oracle, exactly (int32 accumulation)."""
    rng = np.random.default_rng(seed)
    k, m = 768, 32
    w = random_codes(rng, "tl1", m, k)
    x_q = jnp.asarray(rng.integers(-127, 128, size=(n, k)), jnp.int8)
    pw = pack_quantized(w, jnp.float32(1.0), "tl1")
    ref = np.asarray(mpgemm.mpgemm_xla(x_q, jnp.float32(1.0), pw))
    y_elut = np.asarray(elut.elut_mpgemm(x_q, jnp.float32(1.0), pw, lossless=True))
    y_tl1 = np.asarray(mpgemm.tl1_lut(x_q, jnp.float32(1.0), pw, lossless=True))
    np.testing.assert_array_equal(y_elut, ref)
    np.testing.assert_array_equal(y_elut, y_tl1)


def test_elut_pack_bit_identical_to_legacy_layouts():
    """The parametric packer reproduces the exact legacy byte layouts:
    tl1 = (3,2,4) nibble codes, i2s = (3,1,2) 2-bit fields, tq1 = (3,5,8)."""
    rng = np.random.default_rng(7)
    w = jnp.asarray(rng.integers(-1, 2, size=(8, 760)), jnp.int8)
    # hand-computed legacy tl1 bytes: code = 3·(w0+1) + (w1+1), lo|hi<<4
    t = (np.asarray(w, np.int32) + 1).reshape(8, -1, 2)
    code = t[..., 0] * 3 + t[..., 1]
    legacy_tl1 = (code[:, 0::2] | (code[:, 1::2] << 4)).astype(np.uint8)
    np.testing.assert_array_equal(
        np.asarray(packing.elut_pack(w, 3, 2, 4)), legacy_tl1)
    # legacy i2s bytes: 2-bit codes w+1, 4 per byte little-endian
    c = (np.asarray(w, np.int32) + 1).reshape(8, -1, 4)
    legacy_i2s = (c[..., 0] | (c[..., 1] << 2) | (c[..., 2] << 4)
                  | (c[..., 3] << 6)).astype(np.uint8)
    np.testing.assert_array_equal(
        np.asarray(packing.elut_pack(w, 3, 1, 2)), legacy_i2s)
    # legacy tq1 bytes: base-3 big-endian over 5 trits
    t5 = (np.asarray(w, np.int32) + 1).reshape(8, -1, 5)
    legacy_tq1 = (t5[..., 0] * 81 + t5[..., 1] * 27 + t5[..., 2] * 9
                  + t5[..., 3] * 3 + t5[..., 4]).astype(np.uint8)
    np.testing.assert_array_equal(
        np.asarray(packing.elut_pack(w, 3, 5, 8, pad=True)), legacy_tq1)


@pytest.mark.parametrize("lossless", [True, False])
def test_lut_gemv_ternary_matches_legacy_contract(lossless):
    """The parametric GEMV kernel at (3, 2) keeps the legacy lut_gemv
    contract on matched shapes: exact int32 equality with the MAD oracle
    when lossless, bounded deviation when lossy."""
    from repro.kernels import ops, ref

    rng = np.random.default_rng(3)
    k, m = 1024, 128
    w = random_codes(rng, "tl1", m, k)
    x_q = jnp.asarray(rng.integers(-127, 128, size=(k,)), jnp.int8)
    pw = pack_quantized(w, jnp.float32(1.0), "tl1")
    y = ops.lut_gemv(x_q, jnp.float32(1.0), pw, lossless=lossless,
                     interpret=INTERPRET)
    y_ref = np.asarray(ref.mpgemm_int32(x_q[None], w))[0]
    if lossless:
        np.testing.assert_array_equal(np.asarray(y, np.int64),
                                      y_ref.astype(np.int64))
    else:
        rel = np.abs(np.asarray(y) - y_ref).max() / max(np.abs(y_ref).max(), 1)
        assert 0 <= rel < 0.05


# ---------------------------------------------------------------------------
# Non-ternary ELUT formats through registry-driven dispatch
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [1, 5])
@pytest.mark.parametrize("fmt", ["int2", "int3"])
def test_nonternary_mpgemm_vs_fp32_reference(fmt, n):
    """dispatch.mpgemm on int2/int3 == the fp32 dequantized matmul (to fp
    rounding), both regimes, full code range."""
    rng = np.random.default_rng(17 + n)
    k, m = 768, 64
    w = random_codes(rng, fmt, m, k)
    s_w, s_x = jnp.float32(0.37), jnp.float32(0.0113)
    x_q = jnp.asarray(rng.integers(-127, 128, size=(n, k)), jnp.int8)
    pw = pack_quantized(w, s_w, fmt)
    mark = dispatch.decision_count()
    y = np.asarray(dispatch.mpgemm(x_q, s_x, pw, KernelPlan(interpret=INTERPRET)))
    ref = (np.asarray(x_q, np.float64) * float(s_x)) @ \
          (np.asarray(w, np.float64) * float(s_w)).T
    np.testing.assert_allclose(y, ref, rtol=1e-5, atol=1e-5)
    (dec,) = dispatch.decisions_since(mark)
    assert dec.fmt == fmt and dispatch.REGISTRY[dec.kernel].lossless
    if n == 1:
        assert dec.kernel == "lut_gemv"  # the ELUT decode regime


@pytest.mark.parametrize("fmt", ["int2", "int3"])
def test_nonternary_quantize_pack_weight(fmt):
    """pack_weight runs the format's own training-side rule: absmean scale,
    codes clipped to the format's levels, dequant error bounded by s/2."""
    spec = formats.get(fmt)
    w = jax.random.normal(jax.random.PRNGKey(0), (16, 256))
    pw = pack_weight(w, fmt)
    codes = np.asarray(unpack_weight(pw))
    lo, hi = spec.levels
    assert codes.min() >= lo and codes.max() <= hi
    # levels beyond ternary are actually used (non-ternary quantizer)
    assert codes.min() < -1 or codes.max() > 1
    inside = (codes > lo) & (codes < hi)  # clipped entries deviate more
    err = np.abs(np.asarray(w) - codes * float(pw.scale))
    assert err[inside].max() <= float(pw.scale) / 2 + 1e-6


@pytest.mark.parametrize("fmt", ["int2", "int3"])
def test_elut_lossy_bounded_nonternary(fmt):
    """The T-MAC int8-requantized table stays boundedly lossy at (4,2)/(8,2)."""
    rng = np.random.default_rng(5)
    k, m, n = 1536, 64, 4
    w = random_codes(rng, fmt, m, k)
    x_q = jnp.asarray(rng.integers(-127, 128, size=(n, k)), jnp.int8)
    pw = pack_quantized(w, jnp.float32(1.0), fmt)
    ref = np.asarray(mpgemm.mpgemm_xla(x_q, jnp.float32(1.0), pw))
    y0 = np.asarray(elut.elut_mpgemm(x_q, jnp.float32(1.0), pw, lossless=False))
    rel = np.abs(y0 - ref).max() / np.abs(ref).max()
    assert 0 < rel < 0.05, rel


# ---------------------------------------------------------------------------
# Serve threading: the engine's decode regime rides the ELUT GEMV kernel
# ---------------------------------------------------------------------------


def test_engine_single_slot_decode_routes_lut_gemv_int2():
    cfg = configs.smoke("qwen1.5-0.5b").replace(
        dtype="float32", quant=QuantConfig(mode="quant", fmt="int2"))
    params = lm.init(jax.random.PRNGKey(0), cfg)
    eng = Engine(params, cfg, batch_slots=1, max_seq=32)
    eng.submit(Request(rid=0, prompt=[3, 1, 4], max_new_tokens=3))
    done = eng.run()
    assert len(done) == 1 and len(done[0].out_tokens) == 3
    gemv = [d for d in eng.kernel_decisions() if d.regime == "gemv"]
    assert gemv and all(d.kernel == "lut_gemv" for d in gemv)


def test_lut_plan_generalizes_to_elut_formats():
    plan = dispatch.lut_plan("int3", lossless=False)
    assert plan.gemv == "lut_gemv_lossy" and plan.gemm == "int3_lut_lossy"
    spec, src = dispatch.select("int3", 1, 768, 64, plan)
    assert spec.name == "lut_gemv_lossy" and src == "override"
