"""Per-architecture smoke tests (deliverable f) + serving engine behaviour.

Every assigned arch instantiates a REDUCED same-family config and runs a
forward + one train step on CPU, asserting output shapes and finiteness.
Decode-vs-teacher-forced consistency and the continuous-batching engine are
covered for representative archs of each family.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core.bitlinear import QuantConfig
from repro.infer.engine import Engine, Request, generate
from repro.models import lm
from repro.train import loop as train_loop

ALL_ARCHS = configs.ASSIGNED
KEY = jax.random.PRNGKey(0)


def _batch(cfg, b=2, s=24):
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    if cfg.frontend:
        batch["frontend_emb"] = jnp.ones((b, cfg.frontend_tokens, cfg.d_model)) * 0.1
    if cfg.is_encdec():
        batch["enc_emb"] = jnp.ones((b, cfg.enc_seq, cfg.d_model)) * 0.1
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_arch_smoke_forward_and_shapes(arch):
    cfg = configs.smoke(arch).replace(dtype="float32")
    params = lm.init(KEY, cfg)
    batch = _batch(cfg)
    logits, aux = lm.forward(params, batch, cfg)
    n_front = cfg.frontend_tokens if cfg.frontend else 0
    assert logits.shape == (2, 24 + n_front, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_arch_smoke_train_step(arch):
    cfg = configs.smoke(arch).replace(dtype="float32")
    tcfg = train_loop.TrainConfig()
    state = train_loop.init_train_state(KEY, cfg, tcfg)
    step = jax.jit(train_loop.make_train_step(cfg, tcfg))
    state, metrics = step(state, _batch(cfg))
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_arch_smoke_quantized_decode(arch):
    """Pack to i2s and run prefill + 2 decode steps (the serve path)."""
    cfg = configs.smoke(arch).replace(
        dtype="float32", quant=QuantConfig(mode="quant", fmt="i2s"))
    params = lm.pack(lm.init(KEY, cfg), cfg)
    b = 2
    state = lm.init_state(cfg, b, max_seq=32)
    batch = _batch(cfg, b=b, s=8)
    batch.pop("labels")
    logits, state = lm.prefill(params, batch, cfg, state)
    assert logits.shape == (b, 1, cfg.padded_vocab)
    for t in (8, 9):
        logits, state = lm.decode_step(
            params, jnp.ones((b, 1), jnp.int32), jnp.int32(t), cfg, state)
        assert bool(jnp.isfinite(logits).all())


def test_pattern_scan_equals_unrolled():
    """gemma3's (5 local + 1 global) pattern-scan == explicit unrolled stack.

    Tested in fp mode: scan-vs-unrolled differs at reassociation level, and
    QAT fake-quant amplifies any fp noise discretely across rounding
    boundaries (an inherent property of quantized forwards, not a bug).
    """
    cfg = configs.smoke("gemma3-4b").replace(dtype="float32",
                                             quant=QuantConfig(mode="fp"))
    assert cfg.n_layers % len(cfg.block_pattern) != 0  # remainder covered
    params = lm.init(KEY, cfg)
    batch = _batch(cfg)
    logits, _ = lm.forward(params, batch, cfg)

    # manual unroll with the same per-layer params (repeat-major order)
    x = lm._embed(params, batch["tokens"], cfg)
    reps, rem = cfg.pattern_layers()
    for rep_i in range(reps):
        for pos_i, kind in enumerate(cfg.block_pattern):
            p = jax.tree_util.tree_map(lambda a: a[rep_i], params["stack"]["scan"][pos_i])
            x, _, _ = lm.block_apply(kind, p, x, cfg)
    for i in range(rem):
        x, _, _ = lm.block_apply(cfg.block_pattern[i], params["stack"]["rest"][i], x, cfg)
    ref = lm._head(params, x, cfg)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(ref), rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("arch", ["qwen3-4b", "recurrentgemma-2b", "mamba2-1.3b"])
def test_decode_matches_teacher_forced(arch):
    cfg = configs.smoke(arch).replace(dtype="float32", kv_dtype="bf16",
                                      quant=QuantConfig(mode="fp"))
    params = lm.init(KEY, cfg)
    b, s, p = 2, 20, 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab)
    logits_tf, _ = lm.forward(params, {"tokens": toks, "labels": toks}, cfg)
    state = lm.init_state(cfg, b, max_seq=s + 2)
    lg, state = lm.prefill(params, {"tokens": toks[:, :p]}, cfg, state)
    outs = [lg[:, 0]]
    for t in range(p, s - 1):
        lg, state = lm.decode_step(params, toks[:, t:t + 1], jnp.int32(t), cfg, state)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    tf = logits_tf[:, p - 1:s - 1]
    rel = float(jnp.abs(dec - tf).max() / jnp.abs(tf).max())
    assert rel < 2e-2  # bf16 KV rounding only


def test_local_ring_cache_bounded():
    """gemma3 local layers allocate window-sized (not seq-sized) caches."""
    cfg = configs.smoke("gemma3-4b")
    state = lm.init_state(cfg, 1, max_seq=4096)
    local_cache = state["scan"][0]  # first pattern position is 'local'
    # ring + trash slot, padded to a 256 multiple for seq sharding
    assert local_cache["k"].shape[2] == 256
    global_cache = state["scan"][5]
    assert global_cache["k"].shape[2] == 4352  # ceil(4097/256)*256
    assert local_cache["k"].shape[2] < global_cache["k"].shape[2]


def test_engine_continuous_batching_matches_isolated():
    cfg = configs.smoke("qwen1.5-0.5b").replace(
        dtype="float32", kv_dtype="bf16", quant=QuantConfig(mode="fp"))
    params = lm.init(KEY, cfg)
    prompts = [[5, 7, 9, 11], [3, 1, 4, 1, 5, 9, 2], [10, 20, 30]]
    together = generate(params, cfg, prompts, max_new_tokens=5, batch_slots=2,
                        max_seq=64, pack=False)
    isolated = [generate(params, cfg, [p], max_new_tokens=5, batch_slots=1,
                         max_seq=64, pack=False)[0] for p in prompts]
    assert together == isolated


def test_engine_quantized_greedy_deterministic():
    cfg = configs.smoke("qwen1.5-0.5b").replace(
        dtype="float32", quant=QuantConfig(mode="quant", fmt="tl2k"))
    params = lm.init(KEY, cfg)
    out1 = generate(params, cfg, [[1, 2, 3]], max_new_tokens=4, max_seq=32)
    out2 = generate(params, cfg, [[1, 2, 3]], max_new_tokens=4, max_seq=32)
    assert out1 == out2 and len(out1[0]) == 4


def test_moe_capacity_drops_are_bounded():
    """With generous capacity, MoE decode == teacher-forced (no drops)."""
    cfg = configs.smoke("moonshot-v1-16b-a3b").replace(
        dtype="float32", kv_dtype="bf16", quant=QuantConfig(mode="fp"),
        capacity_factor=8.0)
    params = lm.init(KEY, cfg)
    b, s, p = 2, 16, 10
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab)
    logits_tf, _ = lm.forward(params, {"tokens": toks, "labels": toks}, cfg)
    state = lm.init_state(cfg, b, max_seq=s + 2)
    lg, state = lm.prefill(params, {"tokens": toks[:, :p]}, cfg, state)
    outs = [lg[:, 0]]
    for t in range(p, s - 1):
        lg, state = lm.decode_step(params, toks[:, t:t + 1], jnp.int32(t), cfg, state)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    tf = logits_tf[:, p - 1:s - 1]
    assert float(jnp.abs(dec - tf).max() / jnp.abs(tf).max()) < 2e-2
