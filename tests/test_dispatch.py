"""Kernel registry + shape-aware dispatch (DESIGN.md §5).

The acceptance claims, as executable assertions:
  * every registered lossless kernel matches ``mpgemm_xla`` bit-exactly for
    every (format, regime) it claims;
  * auto-selection picks a lossless kernel for every registered format and
    both regimes (so dispatch never silently changes numerics);
  * the autotune cache round-trips: write → reload → identical selections;
  * the Engine at batch-slot count 1 routes decode through ``lut_gemv``
    while the prefill path routes through the MXU MAD kernels;
  * plan overrides are validated with clear errors; legacy ``impl``/``lut``
    string flags keep their historical routing via the shim.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import dispatch, mpgemm
from repro.core.bitlinear import QuantConfig
from repro.core.dispatch import AutotuneCache, KernelPlan
from repro.core.qtensor import PackedWeight, pack_ternary
from repro.infer.engine import Engine, Request
from repro.models import lm

INTERPRET = True  # CPU container: Pallas kernel bodies execute via interpret

INT_FORMATS = [f for f in dispatch.formats() if f != "fp"]


def _data(seed, n, k, m):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.integers(-1, 2, size=(m, k)), jnp.int8)
    x_q = jnp.asarray(rng.integers(-127, 128, size=(n, k)), jnp.int8)
    return x_q, w


# ---------------------------------------------------------------------------
# Registry numerics: every capable lossless kernel == mpgemm_xla
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [1, 5])
@pytest.mark.parametrize("fmt", INT_FORMATS)
def test_registry_kernels_match_xla(fmt, n):
    k, m = 768, 64  # 768 satisfies every format's alignment (24, 4, 3·256)
    x_q, w = _data(7 + n, n, k, m)
    pw = pack_ternary(w, jnp.float32(1.0), fmt)
    ref = np.asarray(mpgemm.mpgemm_xla(x_q, jnp.float32(1.0), pw))
    regime = "gemv" if n == 1 else "gemm"
    cands = dispatch.candidates(fmt, regime, n, k, m)
    assert cands, f"no lossless kernel registered for ({fmt}, {regime})"
    for spec in cands:
        y = np.asarray(spec.fn(x_q, jnp.float32(1.0), pw, INTERPRET))
        np.testing.assert_array_equal(
            y.astype(np.int64), ref.astype(np.int64), err_msg=spec.name)


@pytest.mark.parametrize("n", [1, 5])
@pytest.mark.parametrize("fmt", INT_FORMATS)
def test_auto_selection_is_lossless(fmt, n):
    k, m = 768, 64
    x_q, w = _data(11 + n, n, k, m)
    pw = pack_ternary(w, jnp.float32(0.5), fmt)
    ref = np.asarray(mpgemm.mpgemm_xla(x_q, jnp.float32(2.0), pw))
    mark = dispatch.decision_count()
    y = np.asarray(dispatch.mpgemm(x_q, jnp.float32(2.0), pw,
                                   KernelPlan(interpret=INTERPRET)))
    np.testing.assert_array_equal(y.astype(np.int64), ref.astype(np.int64))
    (dec,) = dispatch.decisions_since(mark)
    assert dec.fmt == fmt and dec.n == n
    assert dec.regime == ("gemv" if n == 1 else "gemm")
    assert dispatch.REGISTRY[dec.kernel].lossless


def test_auto_selection_fp_format():
    k, m = 256, 32
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.integers(-1, 2, size=(m, k)), jnp.int8)
    pw = PackedWeight({"w": w.astype(jnp.bfloat16)}, jnp.float32(1.0), "fp", (m, k))
    x_q = jnp.asarray(rng.integers(-127, 128, size=(3, k)), jnp.int8)
    y = np.asarray(dispatch.mpgemm(x_q, jnp.float32(1.0), pw))
    ref = np.asarray(mpgemm.mpgemm_xla(x_q, jnp.float32(1.0), pw))
    np.testing.assert_allclose(y, ref)


def test_regime_heuristic_table():
    """Paper §3: LUT GEMV for batch-1 tl1 decode; MAD/MXU for batched GEMM."""
    assert dispatch.explain("tl1", 1, 768, 128)["kernel"] == "lut_gemv"
    assert dispatch.explain("tl1", 64, 768, 128)["kernel"] in ("xla", "pallas")
    assert dispatch.explain("int4", 1, 768, 128)["kernel"] == "int4"
    assert dispatch.explain("i2s", 64, 768, 128)["kernel"] in ("xla", "pallas")
    # backend restriction: dryrun plans stay pallas-free
    xla_only = KernelPlan(backend="xla")
    for n in (1, 64):
        spec, _ = dispatch.select("tl1", n, 768, 128, xla_only)
        assert spec.backend == "xla"


# ---------------------------------------------------------------------------
# Plan overrides + validation
# ---------------------------------------------------------------------------


def test_plan_override_and_errors():
    x_q, w = _data(3, 5, 768, 64)
    pw = pack_ternary(w, jnp.float32(1.0), "i2s")
    mark = dispatch.decision_count()
    y = dispatch.mpgemm(x_q, jnp.float32(1.0), pw,
                        KernelPlan(gemm="pallas", interpret=INTERPRET))
    np.testing.assert_array_equal(
        np.asarray(y), np.asarray(mpgemm.mpgemm_xla(x_q, jnp.float32(1.0), pw)))
    assert dispatch.decisions_since(mark)[0].source == "override"
    with pytest.raises(ValueError, match="cannot run"):
        dispatch.mpgemm(x_q, jnp.float32(1.0), pw, KernelPlan(gemm="lut_gemv"))
    with pytest.raises(ValueError, match="unknown kernel"):
        dispatch.mpgemm(x_q, jnp.float32(1.0), pw, KernelPlan(gemm="nope"))
    with pytest.raises(ValueError, match="does not match"):
        dispatch.mpgemm(x_q[:, :512], jnp.float32(1.0), pw)


def test_legacy_string_shim_removed():
    """The deprecated impl=/lut= string shim is gone: ``mpgemm`` the module
    no longer exposes a dispatching entry point, and QuantConfig rejects the
    old flags — every call site must route through dispatch.mpgemm(plan)."""
    assert not hasattr(mpgemm, "mpgemm")
    with pytest.raises(TypeError):
        QuantConfig(mode="quant", fmt="tl1", impl="pallas")
    with pytest.raises(TypeError):
        QuantConfig(mode="quant", fmt="tl1", lut="lossless")


def test_registry_enumerated_from_formats():
    """KernelSpecs are derived from the format registry: every grouped ELUT
    format (incl. the non-ternary int2/int3) has XLA LUT kernels and is
    covered by the true-LUT GEMV kernel, with cost hints derived from the
    spec's table size (hbm 8·C/g, MXU inflation C/g)."""
    from repro.core import formats as fmtreg

    for f in fmtreg.lut_gemv_formats():
        spec_f = fmtreg.get(f)
        for name in (f"{f}_lut", f"{f}_lut_lossy"):
            ks = dispatch.REGISTRY[name]
            assert ks.fmts == (f,)
            assert ks.hbm_bpw == pytest.approx(8.0 * spec_f.lut_size / spec_f.group)
            assert ks.mxu_inflation == pytest.approx(spec_f.lut_size / spec_f.group)
        assert f in dispatch.REGISTRY["lut_gemv"].fmts
        assert f in dispatch.REGISTRY["pallas"].fmts
    assert {"int2", "int3"} <= set(fmtreg.lut_gemv_formats())
    # ternary napkin math: tl1 C/g = 9/2, tl2 folded table 14/3
    assert dispatch.REGISTRY["tl1_lut"].mxu_inflation == pytest.approx(4.5)
    assert dispatch.REGISTRY["tl2_lut"].mxu_inflation == pytest.approx(14 / 3)


# ---------------------------------------------------------------------------
# Autotune cache
# ---------------------------------------------------------------------------


def test_autotune_cache_roundtrip(tmp_path):
    shapes = [(1, 512, 128), (8, 512, 128)]
    cache = AutotuneCache()
    dispatch.autotune("tl1", shapes, cache=cache, reps=1,
                      names=("xla", "tl1_lut", "lut_gemv"), interpret=INTERPRET)
    assert len(cache.entries) == 2
    for e in cache.entries.values():
        assert e["kernel"] in e["us"]

    path = str(tmp_path / "autotune.json")
    cache.save(path)
    reloaded = AutotuneCache.load(path)
    assert {k: v["kernel"] for k, v in reloaded.entries.items()} == \
           {k: v["kernel"] for k, v in cache.entries.items()}

    prev = dispatch.active_cache()
    try:
        dispatch.set_cache(cache)
        first = [dispatch.select("tl1", n, k, m) for n, k, m in shapes]
        dispatch.set_cache(reloaded)
        second = [dispatch.select("tl1", n, k, m) for n, k, m in shapes]
    finally:
        dispatch.set_cache(prev)
    assert [s.name for s, _ in first] == [s.name for s, _ in second]
    assert all(src == "autotune" for _, src in first + second)


def test_autotune_key_buckets_batch():
    assert AutotuneCache.key("cpu", "tl1", 1, 768, 64) != \
           AutotuneCache.key("cpu", "tl1", 2, 768, 64)
    # batched Ns bucket to powers of two: 17..32 share an entry
    assert AutotuneCache.key("cpu", "tl1", 20, 768, 64) == \
           AutotuneCache.key("cpu", "tl1", 32, 768, 64)


# ---------------------------------------------------------------------------
# Engine routing (the paper's serving claim, end to end on CPU interpret)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def tl1_model():
    cfg = configs.smoke("qwen1.5-0.5b").replace(
        dtype="float32", quant=QuantConfig(mode="quant", fmt="tl1"))
    params = lm.init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_engine_single_slot_decode_routes_lut_gemv(tl1_model):
    cfg, params = tl1_model
    eng = Engine(params, cfg, batch_slots=1, max_seq=32)
    eng.submit(Request(rid=0, prompt=[3, 1, 4], max_new_tokens=3))
    done = eng.run()
    assert len(done) == 1 and len(done[0].out_tokens) == 3
    decs = eng.kernel_decisions()
    gemv = [d for d in decs if d.regime == "gemv"]
    assert gemv, "single-slot decode recorded no GEMV dispatches"
    assert all(d.n == 1 and d.kernel == "lut_gemv" for d in gemv)
    assert not [d for d in decs if d.regime == "gemm"]


def test_prefill_routes_mxu_mad_kernels(tl1_model):
    cfg, params = tl1_model
    packed = lm.pack(params, cfg)
    state = lm.init_state(cfg, 1, 32)
    toks = jnp.asarray(np.arange(8, dtype=np.int32)[None, :] % cfg.vocab)
    mark = dispatch.decision_count()
    logits, state = lm.prefill(packed, {"tokens": toks}, cfg, state)
    assert np.isfinite(np.asarray(logits)).all()
    decs = dispatch.decisions_since(mark)
    assert decs and all(d.regime == "gemm" for d in decs)
    assert all(d.kernel in ("xla", "pallas", "int4") for d in decs), \
        "prefill must take the MAD/MXU kernels, not the LUT GEMV path"


def test_engine_multi_slot_takes_gemm_regime(tl1_model):
    cfg, params = tl1_model
    eng = Engine(params, cfg, batch_slots=3, max_seq=32)
    for i in range(3):
        eng.submit(Request(rid=i, prompt=[1, 2], max_new_tokens=2))
    eng.run()
    decs = eng.kernel_decisions()
    assert decs and all(d.regime == "gemm" and d.n == 3 for d in decs)
    assert all(d.kernel != "lut_gemv" for d in decs)


def test_engine_plan_override_threads_through(tl1_model):
    cfg, params = tl1_model
    eng = Engine(params, cfg, batch_slots=1, max_seq=32,
                 plan=KernelPlan(gemv="xla", gemm="xla"))
    eng.submit(Request(rid=0, prompt=[5, 6], max_new_tokens=2))
    eng.run()
    decs = eng.kernel_decisions()
    assert decs and all(d.kernel == "xla" and d.source == "override" for d in decs)
