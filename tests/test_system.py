"""End-to-end system behaviour: the paper's claims as executable assertions.

  1. QAT training converges on the synthetic pipeline (substrate works).
  2. LOSSLESS INFERENCE (paper Figure 2 / Table 2): packing the QAT model to
     i2s / tl1_1 / tl2_1 and serving reproduces the QAT forward's logits;
     the lossy variants (TL*_0, Q8_K block activations) measurably deviate.
  3. Quantized greedy generations are identical across all lossless formats.
  4. Checkpoint -> restart training continues bit-exactly.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import dispatch
from repro.core.bitlinear import QuantConfig
from repro.data.pipeline import DataConfig, DataIterator
from repro.infer.engine import generate
from repro.models import lm
from repro.train import loop as train_loop


@pytest.fixture(scope="module")
def trained():
    cfg = configs.smoke("qwen1.5-0.5b").replace(dtype="float32")
    tcfg = train_loop.TrainConfig(
        opt=train_loop.opt.OptConfig(lr=3e-3, warmup_steps=5, total_steps=60))
    dc = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=8)
    state, hist = train_loop.train(cfg, tcfg, DataIterator(dc), n_steps=30)
    return cfg, tcfg, state, hist, dc


def test_training_converges(trained):
    _, _, _, hist, _ = trained
    assert hist[-1]["loss"] < hist[0]["loss"] - 1.0


def _logits(cfg, params, toks):
    out, _ = lm.forward(params, {"tokens": toks, "labels": toks}, cfg)
    return np.asarray(out)


def test_lossless_inference_formats(trained):
    """The paper's Table 2, as a bit-level claim on our trained model."""
    cfg, _, state, _, dc = trained
    toks = next(DataIterator(dc))["tokens"][:2]
    qat = _logits(cfg, state["params"], toks)  # the QAT training forward

    # lossless: integer mpGEMM with per-tensor act quant reproduces QAT
    for fmt in ("i2s", "tl1", "tl2", "tl2k", "int4"):
        qcfg = QuantConfig(mode="quant", fmt=fmt)
        packed = lm.pack(state["params"], cfg.replace(quant=qcfg))
        got = _logits(cfg.replace(quant=qcfg), packed, toks)
        np.testing.assert_allclose(got, qat, atol=5e-4, rtol=1e-4)

    # lossless LUT variants (pack-and-unpack): TL1_1 / TL2_1
    for fmt in ("tl1", "tl2"):
        qcfg = QuantConfig(mode="quant", fmt=fmt, plan=dispatch.lut_plan(fmt))
        packed = lm.pack(state["params"], cfg.replace(quant=qcfg))
        got = _logits(cfg.replace(quant=qcfg), packed, toks)
        np.testing.assert_allclose(got, qat, atol=5e-4, rtol=1e-4)


def test_lossy_variants_deviate_boundedly(trained):
    cfg, _, state, _, dc = trained
    toks = next(DataIterator(dc))["tokens"][:2]
    qat = _logits(cfg, state["params"], toks)
    scale = np.abs(qat).max()

    # TL*_0: int8-requantized LUT (T-MAC style)
    qcfg = QuantConfig(mode="quant", fmt="tl2",
                       plan=dispatch.lut_plan("tl2", lossless=False))
    got = _logits(cfg.replace(quant=qcfg), lm.pack(state["params"], cfg.replace(quant=qcfg)), toks)
    rel0 = np.abs(got - qat).max() / scale
    assert 0 < rel0 < 0.1

    # Q8_K-style per-block activations (llama.cpp TQ semantics)
    qcfg = QuantConfig(mode="quant", fmt="i2s", act="block", act_block=48)
    got = _logits(cfg.replace(quant=qcfg), lm.pack(state["params"], cfg.replace(quant=qcfg)), toks)
    relb = np.abs(got - qat).max() / scale
    assert relb > 1e-6  # measurably NOT lossless (the paper's TQ critique)


def test_greedy_generation_identical_across_lossless_formats(trained):
    cfg, _, state, _, _ = trained
    outs = {}
    for fmt in ("i2s", "tl1", "tl2k"):
        qcfg = QuantConfig(mode="quant", fmt=fmt)
        c = cfg.replace(quant=qcfg)
        outs[fmt] = generate(lm.pack(state["params"], c), c, [[5, 6, 7, 8]],
                             max_new_tokens=8, max_seq=48)
    assert outs["i2s"] == outs["tl1"] == outs["tl2k"]


def test_checkpoint_restart_bit_exact(tmp_path, trained):
    cfg, tcfg, _, _, dc = trained
    from repro.ckpt import store

    it = DataIterator(dc)
    state = train_loop.init_train_state(jax.random.PRNGKey(1), cfg, tcfg)
    step = jax.jit(train_loop.make_train_step(cfg, tcfg))
    for _ in range(3):
        state, _ = step(state, next(it))
    store.save(state, str(tmp_path), 3, extra={"data_step": it.state.step})

    # run 2 more, then restart from the checkpoint and replay the same 2
    for _ in range(2):
        state, m = step(state, next(it))
    ref = float(m["loss"])

    restored, extra = store.restore(state, str(tmp_path), 3)
    it2 = DataIterator.restore(dc, {"step": extra["data_step"]})
    for _ in range(2):
        restored, m2 = step(restored, next(it2))
    assert float(m2["loss"]) == pytest.approx(ref, rel=1e-6)
