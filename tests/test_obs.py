"""Observability stack (DESIGN.md §9): tracing, metrics, kernel attribution.

The load-bearing claims, as executable assertions:

  * a 2-request serve run under the engine's virtual clock produces an
    EXACT, deterministic span tree (tick → admit/prefill/decode, sample
    spans where sampling actually ran);
  * instrumentation is observationally inert: tracing ON generates
    bit-identical tokens and ZERO extra jit traces vs tracing OFF
    (decision_count is the trace-time witness);
  * measured_vs_predicted attribution covers every dispatch key the run
    exercised, with compile wall booked separately from execute wall;
  * the dispatch decision log's capacity trim is no longer silent —
    decisions_dropped counts every trimmed entry and the metrics blob
    surfaces it;
  * the stall RuntimeError text is rendered from the same structured
    payload the tracer records (one home for the wording);
  * the CI schema checks accept the real artifacts and reject drift.
"""

import json
import threading

import jax
import numpy as np
import pytest

from benchmarks import smoke_gate
from repro import configs
from repro import obs as obs_mod
from repro.core import dispatch
from repro.core.bitlinear import QuantConfig
from repro.core.dispatch import Decision, KernelPlan
from repro.models import lm
from repro.obs import kernels as obs_kernels
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import NULL_SPAN, NULL_TRACER, Tracer
from repro.serve import Request, ServeConfig, ServeEngine
from repro.serve.metrics import RequestMetrics, ServeStats, percentile

KEY = jax.random.PRNGKey(0)


def _cfg(**kw):
    quant = kw.pop("quant", QuantConfig(mode="quant", fmt="i2s", act="token"))
    return configs.smoke("qwen1.5-0.5b").replace(
        dtype="float32", quant=quant, **kw)


@pytest.fixture(scope="module")
def model():
    cfg = _cfg()
    return cfg, lm.init(KEY, cfg)


def _counting_clock():
    """Deterministic virtual clock: 0.0, 1.0, 2.0, ... per call."""
    t = iter(range(10 ** 9))
    return lambda: float(next(t))


def _prompts(cfg, n, length=5):
    rng = np.random.default_rng(0)
    return [rng.integers(0, cfg.vocab, size=length).tolist()
            for _ in range(n)]


def _serve(params, cfg, obs=None, clock=None, **kw):
    kw.setdefault("batch_slots", 2)
    kw.setdefault("max_seq", 32)
    kw.setdefault("prefill_chunk", 4)
    eng_kw = {}
    if obs is not None:
        eng_kw["obs"] = obs
    if clock is not None:
        eng_kw["clock"] = clock
    return ServeEngine(params, cfg, ServeConfig(**kw), **eng_kw)


def _run(eng, prompts, max_new=2):
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=max_new))
    return {r.rid: r.out_tokens for r in eng.run()}


# ---------------------------------------------------------------------------
# Tracer unit behaviour
# ---------------------------------------------------------------------------


def test_tracer_nesting_and_span_tree():
    clk = _counting_clock()
    tr = Tracer(clock=clk)
    with tr.span("outer", a=1) as outer:
        with tr.span("inner"):
            tr.event("hit", x=3)     # nests under the CURRENT span (inner)
        outer.set(b=2)
    tree = tr.span_tree()
    assert len(tree) == 1
    root = tree[0]
    assert root["name"] == "outer" and root["args"] == {"a": 1, "b": 2}
    assert [c["name"] for c in root["children"]] == ["inner"]
    assert root["children"][0]["events"] == ["hit"]
    # counting clock, one tick per clock read: outer opens at 0, inner at 1,
    # the event stamps 2, inner closes at 3, outer at 4
    assert (root["t0"], root["t1"]) == (0.0, 4.0)
    assert (root["children"][0]["t0"], root["children"][0]["t1"]) == (1.0, 3.0)


def test_tracer_orphan_event_and_chrome_export(tmp_path):
    tr = Tracer(clock=_counting_clock())
    tr.event("orphan", why="no open span")
    with tr.span("s"):
        pass
    events = tr.chrome_events()
    phases = {e["name"]: e["ph"] for e in events}
    assert phases == {"s": "X", "orphan": "i"}
    span = next(e for e in events if e["name"] == "s")
    assert span["ts"] == 1.0 * 1e6 and span["dur"] == 1.0 * 1e6  # µs
    path = tr.save(str(tmp_path / "t.json"))
    with open(path) as f:
        blob = json.load(f)
    assert {e["name"] for e in blob["traceEvents"]} == {"s", "orphan"}
    assert smoke_gate.check_trace_blob(blob) != []  # no tick/decode spans


def test_tracer_thread_safety():
    tr = Tracer()
    n, reps = 4, 50

    def work(tid):
        for i in range(reps):
            with tr.span(f"w{tid}"):
                tr.event("e")

    threads = [threading.Thread(target=work, args=(t,)) for t in range(n)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    tree = tr.span_tree()
    # spans nest per-thread: every span is a root of its own thread's stack
    assert len(tree) == n * reps
    assert all(node["events"] == ["e"] for node in tree)


def test_null_tracer_is_shared_noop():
    assert NULL_TRACER.span("x", a=1) is NULL_SPAN
    assert NULL_SPAN.set(a=1) is NULL_SPAN
    with NULL_TRACER.span("x") as sp:
        sp.event("e")  # no-ops, no state
    assert not NULL_TRACER.enabled


# ---------------------------------------------------------------------------
# Metrics registry
# ---------------------------------------------------------------------------


def test_metrics_counter_gauge_histogram():
    reg = MetricsRegistry()
    c = reg.counter("reqs_total")
    c.inc()
    c.inc(2)
    with pytest.raises(ValueError, match=">= 0"):
        c.inc(-1)
    assert reg.counter("reqs_total") is c  # get-or-create
    reg.gauge("depth").set(7)
    h = reg.histogram("lat_s", buckets=(0.1, 1.0))
    for v in (0.05, 0.5, 5.0):
        h.observe(v)
    snap = reg.snapshot()
    assert snap["counters"]["reqs_total"] == 3
    assert snap["gauges"]["depth"] == 7
    hs = snap["histograms"]["lat_s"]
    assert hs["count"] == 3 and hs["sum"] == pytest.approx(5.55)
    # cumulative buckets: ≤0.1 → 1, ≤1.0 → 2, +Inf → 3
    assert hs["buckets"] == [["0.1", 1], ["1.0", 2], ["+Inf", 3]]


def test_metrics_labels_and_kind_conflict():
    reg = MetricsRegistry()
    a = reg.counter("hits", fmt="i2s")
    b = reg.counter("hits", fmt="tl1")
    assert a is not b
    a.inc(5)
    assert reg.snapshot()["counters"] == {'hits{fmt="i2s"}': 5,
                                          'hits{fmt="tl1"}': 0}
    with pytest.raises(ValueError, match="already registered as a counter"):
        reg.gauge("hits")


def test_metrics_prometheus_text():
    reg = MetricsRegistry()
    reg.counter("reqs_total").inc(2)
    reg.gauge("depth", queue="main").set(3)
    reg.histogram("lat_s", buckets=(1.0,)).observe(0.5)
    text = reg.to_prometheus()
    assert "# TYPE reqs_total counter\nreqs_total 2" in text
    assert 'depth{queue="main"} 3' in text
    assert 'lat_s_bucket{le="1.0"} 1' in text
    assert 'lat_s_bucket{le="+Inf"} 1' in text
    assert "lat_s_sum 0.5" in text and "lat_s_count 1" in text


# ---------------------------------------------------------------------------
# serve/metrics edge cases (the satellite coverage)
# ---------------------------------------------------------------------------


def test_percentile_nearest_rank_boundaries():
    assert percentile([], 50) is None
    assert percentile([None, None], 95) is None
    assert percentile([42.0], 0) == 42.0
    assert percentile([42.0], 100) == 42.0
    vals = [10.0, 20.0, 30.0, 40.0]
    assert percentile(vals, 0) == 10.0
    assert percentile(vals, 100) == 40.0
    assert percentile(vals, 50) == 30.0   # round(1.5) → rank 2
    assert percentile(vals, 25) == 20.0   # round(0.75) → rank 1
    # Nones are filtered BEFORE ranking ([1, 5], not [None, 1, 5]) — and
    # python's round-half-even puts the 2-sample median at rank 0
    assert percentile([None, 5.0, 1.0], 50) == 1.0


def test_serve_stats_summary_empty_and_single():
    empty = ServeStats().summary()
    assert empty["requests"] == 0
    assert empty["throughput_tok_s"] is None
    assert empty["ttft_p50"] is None and empty["ttft_mean"] is None
    assert empty["prefix_hit_rate"] == 0.0

    st = ServeStats()
    st.add(RequestMetrics(rid=0, prompt_len=3, submit_t=0.0, admit_t=1.0,
                          first_token_t=2.0, finish_t=4.0, n_generated=3))
    s = st.summary()
    assert s["requests"] == 1
    assert s["ttft_mean"] == s["ttft_p50"] == s["ttft_p95"] == 2.0
    assert s["queue_wait_p50"] == 1.0
    assert s["throughput_tok_s"] == pytest.approx(3 / 4)


def test_queue_wait_survives_preemption(model):
    """The user-visible wait is submit → FIRST admission; a preempted then
    re-admitted request must not have its queue_wait reset."""
    cfg, params = model
    clk = _counting_clock()
    eng = _serve(params, cfg, clock=clk, batch_slots=1)
    sub = eng.submit(Request(rid=0, prompt=[3, 4, 5], max_new_tokens=6))
    eng.step()
    first_wait = sub.metrics.queue_wait
    assert first_wait is not None
    eng.preempt_slot(0)
    assert sub.metrics.n_preemptions == 1
    eng.step()  # re-admitted at a later virtual time
    assert eng.slots[0] is not None
    assert sub.metrics.queue_wait == first_wait


def test_decode_tok_s_degenerate():
    m = RequestMetrics(rid=0, first_token_t=1.0, finish_t=1.0, n_generated=1)
    assert m.decode_tok_s is None          # one token: no decode interval
    assert RequestMetrics(rid=1).ttft is None
    assert RequestMetrics(rid=2).queue_wait is None


# ---------------------------------------------------------------------------
# Acceptance: exact span tree under the virtual clock
# ---------------------------------------------------------------------------


def _shape(node):
    return (node["name"], [_shape(c) for c in node["children"]])


def test_serve_span_tree_exact(model):
    cfg, params = model
    clk = _counting_clock()
    obs = obs_mod.make(clock=clk, kernel_timing=False)
    eng = _serve(params, cfg, obs=obs, clock=clk)
    toks = _run(eng, _prompts(cfg, 2), max_new=2)
    assert all(len(t) == 2 for t in toks.values())
    tree = obs.tracer.span_tree()
    # len-5 prompts, chunk 4: tick 0 prefills 4 tokens (no logits sampled),
    # tick 1 prefills the last token and samples each slot's first output,
    # tick 2 is the batched decode tick that samples the second output.
    assert [_shape(n) for n in tree] == [
        ("tick", [("admit", []), ("prefill", []), ("decode", [])]),
        ("tick", [("admit", []),
                  ("prefill", [("sample", []), ("sample", [])]),
                  ("decode", [])]),
        ("tick", [("admit", []), ("prefill", []),
                  ("decode", [("sample", [])])]),
    ]
    assert [n["args"] for n in tree] == [{"tick": 0}, {"tick": 1}, {"tick": 2}]
    # both requests admitted in tick 0; decode runs no slots until tick 2
    assert tree[0]["children"][0]["args"] == {"queued": 0}
    assert [n["children"][2]["args"]["slots"] for n in tree] == [0, 0, 2]
    # virtual timestamps: monotone, closed, integral (every stamp is a tick
    # of the counting clock — the determinism the acceptance test pins)
    def every(node):
        yield node
        for c in node["children"]:
            yield from every(c)
    stamps = [t for n in tree for s in every(n) for t in (s["t0"], s["t1"])]
    assert all(t == int(t) for t in stamps)
    for n in tree:
        for s in every(n):
            assert s.t1 >= s.t0 if hasattr(s, "t1") else s["t1"] >= s["t0"]


def test_serve_metrics_sampling(model):
    cfg, params = model
    obs = obs_mod.make(clock=_counting_clock(), kernel_timing=False)
    eng = _serve(params, cfg, obs=obs, clock=_counting_clock())
    _run(eng, _prompts(cfg, 2), max_new=2)
    snap = obs.metrics.snapshot()
    assert snap["counters"]["serve_ticks_total"] == 3
    assert snap["counters"]["serve_requests_finished_total"] == 2
    assert snap["counters"]["serve_tokens_generated_total"] == 4
    # gauges hold the LAST sample, taken at the end of the final tick —
    # after both requests finished and their slots were cleared
    assert snap["gauges"]["serve_slots_occupied"] == 0
    assert snap["gauges"]["serve_queue_depth"] == 0
    assert snap["histograms"]["serve_tick_duration_s"]["count"] == 3


# ---------------------------------------------------------------------------
# Acceptance: tracing is observationally inert (tokens + jit traces)
# ---------------------------------------------------------------------------


def test_tracing_on_vs_off_identical_tokens_zero_new_traces(model):
    cfg, params = model
    prompts = _prompts(cfg, 2)
    toks_off = _run(_serve(params, cfg), prompts)      # compiles (or warm)
    mark = dispatch.decision_count()
    obs = obs_mod.make()                               # tracing + metrics + prof
    toks_on = _run(_serve(params, cfg, obs=obs), prompts)
    assert toks_on == toks_off                         # bit-identical tokens
    assert dispatch.decision_count() == mark           # ZERO extra jit traces
    # ...and the profiler still attributed the warm executions it fenced,
    # via the keysets captured when the executables first compiled
    rows = obs.kernels.report()["rows"]
    assert rows and all(r["compile_calls"] == 0 for r in rows)
    assert sum(r["calls"] for r in rows) > 0


# ---------------------------------------------------------------------------
# Acceptance: measured-vs-predicted attribution
# ---------------------------------------------------------------------------


def test_measured_vs_predicted_complete_and_compile_separated(model):
    cfg, params = model
    # a plan override changes the cfg hash → this engine's jitted steps are
    # FRESH traces, so the profiler sees the compile calls itself (the xla
    # kernel is capable and lossless for every format)
    plan = KernelPlan(gemv="xla", gemm="xla")
    obs = obs_mod.make(tracing=False, metrics_on=False)
    eng = ServeEngine(params, cfg, ServeConfig(
        batch_slots=2, max_seq=32, prefill_chunk=4), plan=plan, obs=obs)
    _run(eng, _prompts(cfg, 2), max_new=2)
    report = eng.measured_vs_predicted()
    rows = report["rows"]
    assert rows
    # completeness: every dispatch key this engine's traces recorded has a row
    exercised = {obs_kernels.decision_key(d) for d in eng.kernel_decisions()}
    reported = {(r["kernel"], r["fmt"], r["M"], r["K"], r["N_bucket"])
                for r in rows}
    assert exercised == reported
    assert all(r["kernel"] == "xla" for r in rows)
    # compile wall is booked separately from execute wall — the engine both
    # traced (fresh cfg) and re-executed (3 ticks) these callables
    assert all(r["compile_calls"] > 0 and r["compile_s"] > 0 for r in rows)
    assert any(r["calls"] > 0 and r["execute_s"] > 0 for r in rows)
    for r in rows:
        if r["calls"]:
            assert r["measured_us_per_call"] > 0
            # the reported ratio uses unrounded operands; recomputing from
            # the 3-decimal row values only lands within rounding slack
            assert r["measured_over_predicted"] == pytest.approx(
                r["measured_us_per_call"] / r["predicted_us_per_call"],
                rel=0.1)
        assert r["predicted_us_per_call"] > 0
        assert r["predicted_hbm_bytes_per_call"] > 0


def test_measured_vs_predicted_requires_profiler(model):
    cfg, params = model
    with pytest.raises(ValueError, match="KernelProfiler"):
        _serve(params, cfg).measured_vs_predicted()


def test_profiler_cost_share_and_unattributed():
    clk = _counting_clock()
    prof = obs_kernels.KernelProfiler(clock=clk)
    key_a = ("xla", "i2s", 64, 32, 1)
    key_b = ("xla", "i2s", 64, 32, 16)
    import collections
    keys = collections.Counter({key_a: 1, key_b: 1})
    prof.record(keys, 1.0, compiled=False)
    prof.record(None, 0.5, compiled=False)   # unknown keyset
    pa, pb = obs_kernels.predicted_us(key_a), obs_kernels.predicted_us(key_b)
    sa = prof.stats[key_a].execute_s
    sb = prof.stats[key_b].execute_s
    assert sa + sb == pytest.approx(1.0)     # shares partition the wall
    assert sa / sb == pytest.approx(pa / pb)  # ...proportional to the hints
    assert prof.report()["unattributed_s"] == 0.5


# ---------------------------------------------------------------------------
# Satellite: the decision log's trim is no longer silent
# ---------------------------------------------------------------------------


def test_decisions_dropped_counter(monkeypatch):
    monkeypatch.setattr(dispatch, "_MAX_DECISIONS", 8)
    monkeypatch.setattr(dispatch, "_DECISIONS", [])
    monkeypatch.setattr(dispatch, "_DROPPED", 0)
    base = dispatch.decision_count()
    for i in range(12):
        dispatch._record(Decision(fmt="i2s", regime="gemm", n=16, k=32, m=64,
                                  kernel="xla", source="heuristic"))
    # 8 filled the log, the 9th trimmed the oldest half (4), then 3 more
    assert dispatch.decisions_dropped() == 4
    assert len(dispatch.decisions()) == 8
    assert dispatch.decision_count() == base + 12     # monotone despite trim
    # decisions_since survives the trim for still-retained seqs
    assert [d.seq for d in dispatch.decisions_since(base + 6)] == list(
        range(base + 6, base + 12))


def test_metrics_blob_surfaces_dropped(monkeypatch, model):
    monkeypatch.setattr(dispatch, "_DROPPED", 17)
    obs = obs_mod.make(kernel_timing=False)
    blob = obs_mod.metrics_blob(obs)
    assert blob["dispatch"]["decisions_dropped"] == 17
    assert blob["metrics"]["counters"]["dispatch_decisions_dropped"] == 17
    assert (blob["metrics"]["gauges"]["dispatch_decisions_retained"]
            == len(blob["dispatch"]["decisions"]))
    assert blob["measured_vs_predicted"]["note"] == "kernel profiling disabled"
    for d in blob["dispatch"]["decisions"]:
        assert set(d) == smoke_gate.DECISION_KEYS


# ---------------------------------------------------------------------------
# Satellite: structured stall diagnosis
# ---------------------------------------------------------------------------


def test_stall_event_and_message_share_one_payload(model):
    cfg, params = model
    obs = obs_mod.make(kernel_timing=False)
    # 1 slot, pool sized for ~1 request, no preemption: the queued second
    # request plus an unfinishable first stalls the engine deterministically
    eng = ServeEngine(params, cfg, ServeConfig(
        batch_slots=1, max_seq=32, paged=True, block_size=4, kv_blocks=2,
        prefill_chunk=4, preemption=False), obs=obs)
    eng.submit(Request(rid=0, prompt=[1, 2, 3, 4, 5, 6], max_new_tokens=8))
    with pytest.raises(RuntimeError, match="serving stalled") as ei:
        for _ in range(64):
            eng.step()
    # the printed text is the rendering of the traced structured payload;
    # the stall event fires outside the tick span (after it closes), so it
    # is an orphan instant in the chrome stream
    chrome = obs.tracer.chrome_events()
    stall_evts = [e for e in chrome if e["name"] == "stall"]
    assert len(stall_evts) == 1
    diag = stall_evts[0]["args"]
    assert obs_mod.format_stall(diag) == str(ei.value)
    assert diag["pool"]["kind"] == "paged"
    assert diag["slots"] and "blocks_needed" in diag["slots"][0]


def test_format_stall_dense_and_prefix_variants():
    diag = {"stall_ticks": 4, "preemption": False, "queued": 1,
            "slots": [{"slot": 0, "rid": 7, "priority": 0, "phase": "decode",
                       "cursor": 9, "n_base": 6}],
            "pool": {"kind": "dense"}}
    msg = obs_mod.format_stall(diag)
    assert "slot 0 (rid 7, decode at pos 9/6)" in msg
    assert "dense KV cache" in msg and "queued requests: 1" in msg
    diag["slots"] = []
    diag["pool"] = {"kind": "paged", "free": 0, "total": 8, "shared": 2,
                    "prefix_cached": 3, "prefix_evictable": 1}
    msg = obs_mod.format_stall(diag)
    assert "no occupied slots" in msg
    assert "0 of 8 KV blocks free, 2 refcounted/shared, 3 prefix-cached " \
           "(1 evictable)" in msg


def test_format_prefix_summary_round_trip():
    s = {"prefix_hit_requests": 3, "requests": 6, "prefix_hit_rate": 0.5,
         "prefill_tokens_skipped": 48, "blocks_reused": 9}
    line = obs_mod.format_prefix_summary(s)
    assert line == ("  prefix hits = 3/6 requests, hit rate = 0.50, "
                    "prefill tokens skipped = 48, blocks reused = 9")
    s["prefix_cached_blocks"] = 5
    s["prefix_evictable_blocks"] = 2
    assert obs_mod.format_prefix_summary(s).endswith(
        ", cached = 5 (2 evictable)")


# ---------------------------------------------------------------------------
# Satellite: CI artifact schema checks
# ---------------------------------------------------------------------------


def test_obs_schema_checks_accept_real_artifacts(model, tmp_path):
    cfg, params = model
    obs = obs_mod.make()
    eng = _serve(params, cfg, obs=obs)
    _run(eng, _prompts(cfg, 2))
    trace_path = str(tmp_path / "t.json")
    obs.tracer.save(trace_path)
    blob = obs_mod.metrics_blob(obs)
    metrics_path = str(tmp_path / "m.json")
    with open(metrics_path, "w") as f:
        json.dump(blob, f)
    with open(trace_path) as f:
        assert smoke_gate.check_trace_blob(json.load(f)) == []
    with open(metrics_path) as f:
        assert smoke_gate.check_metrics_blob(json.load(f)) == []
    assert smoke_gate.obs_check_main(trace_path, metrics_path) == 0


def test_obs_schema_checks_reject_drift():
    bad_trace = {"traceEvents": [{"name": "tick", "ph": "Q", "ts": 0,
                                  "pid": 0, "tid": 0}]}
    msgs = smoke_gate.check_trace_blob(bad_trace)
    assert any("unknown phase" in m for m in msgs)
    assert any("'decode'" in m for m in msgs)     # required span missing
    assert smoke_gate.check_trace_blob({}) != []
    bad_metrics = {"metrics": {"counters": {}},
                   "dispatch": {"decisions_dropped": -1, "decisions": {}},
                   "measured_vs_predicted": {}}
    msgs = smoke_gate.check_metrics_blob(bad_metrics)
    assert any("gauges" in m for m in msgs)
    assert any("decisions_dropped" in m for m in msgs)
    assert any("not a list" in m for m in msgs)
    assert any("rows missing" in m for m in msgs)
    assert smoke_gate.obs_check_main("/nonexistent/x.json", None) == 1
