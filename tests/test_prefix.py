"""Prefix-sharing paged KV: radix index, refcounts, copy-on-write, QoS.

The load-bearing claims, as executable assertions:

  * warm (prefix-cache) serving emits tokens BIT-IDENTICAL to cold prefill
    at act=token — on attention archs where sharing is real, AND on dense /
    recurrent / SSD configurations where the cache must go inert instead of
    corrupting state;
  * copy-on-write handles divergence mid-block: the partial block is copied,
    its tail masked, and the source stays valid for other owners;
  * eviction refuses blocks with refcount > 1 (a running request reads
    them) and reclaims LRU leaves first;
  * ``compact()`` preserves shared mappings: a block in several ownership
    lists (or held only by the index) keeps ONE identity across defrag;
  * QoS classes resolve to registry formats (latency → grouped LUT-GEMV
    code, memory → min-bpw lossless table format) and boost admission.
"""

import collections

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core.bitlinear import QuantConfig
from repro.models import lm
from repro.serve import (PagedKVConfig, PrefixIndex, Request, ServeConfig,
                         ServeEngine)
from repro.serve import qos
from repro.serve.kvcache import BlockAllocator, cow_copy_block
from repro.serve.scheduler import AdmissionScheduler, Submission

KEY = jax.random.PRNGKey(0)


def _cfg(name="qwen1.5-0.5b", **kw):
    quant = kw.pop("quant", QuantConfig(mode="quant", fmt="i2s", act="token"))
    return configs.smoke(name).replace(dtype="float32", quant=quant, **kw)


@pytest.fixture(scope="module")
def model():
    cfg = _cfg()
    return cfg, lm.init(KEY, cfg)


def _shared_prompts(cfg, n=4, prefix_len=20, lo=3, hi=8, seed=1):
    rng = np.random.default_rng(seed)
    shared = rng.integers(0, cfg.vocab, size=prefix_len).tolist()
    return [shared + rng.integers(0, cfg.vocab,
                                  size=rng.integers(lo, hi)).tolist()
            for _ in range(n)]


def _run(params, cfg, prompts, *, prefix, max_new=5, **kw):
    defaults = dict(batch_slots=2, max_seq=64, paged=True, block_size=8,
                    prefill_chunk=4)
    defaults.update(kw)
    eng = ServeEngine(params, cfg, ServeConfig(prefix_cache=prefix, **defaults),
                      pack=cfg.quant.mode == "quant")
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=max_new))
    done = eng.run()
    return {r.rid: r.out_tokens for r in done}, eng


# ---------------------------------------------------------------------------
# PrefixIndex unit behaviour (fake allocator: refcounts only)
# ---------------------------------------------------------------------------


class _FakeAlloc:
    def __init__(self):
        self.refs = collections.Counter()

    def refcount(self, b):
        return self.refs[b]

    def ref_inc(self, b):
        self.refs[b] += 1

    def ref_dec(self, b):
        self.refs[b] -= 1
        return self.refs[b] <= 0


def test_index_match_full_and_partial():
    al = _FakeAlloc()
    ix = PrefixIndex(4, al)
    toks = list(range(12))
    assert ix.insert(toks, [10, 11, 12]) == 3
    assert al.refs[10] == al.refs[11] == al.refs[12] == 1
    # full-prefix walk
    blocks, m = ix.match(toks + [99])
    assert (blocks, m) == ([10, 11, 12], 12)
    # divergence mid-block: third block matches only its first 2 tokens —
    # the partial block is returned LAST, for the caller to copy-on-write
    blocks, m = ix.match([0, 1, 2, 3, 4, 5, 6, 7, 8, 9, 77, 88])
    assert (blocks, m) == ([10, 11, 12], 10)
    # no match under a cold root
    assert ix.match([5, 5, 5, 5]) == ([], 0)
    # re-inserting the same content keeps the FIRST block (existing wins)
    assert ix.insert(toks, [20, 21, 22]) == 0
    assert ix.match(toks)[0] == [10, 11, 12]
    assert ix.size == 3


def test_index_reclaim_refuses_refcounted_blocks():
    al = _FakeAlloc()
    ix = PrefixIndex(4, al)
    ix.insert(list(range(8)), [5, 6])
    # a running request holds the chain (owners always adopt root→leaf)
    al.ref_inc(5), al.ref_inc(6)
    assert ix.evictable_count() == 0
    assert ix.reclaim(2) == 0
    assert ix.size == 2
    al.ref_dec(5), al.ref_dec(6)
    assert ix.evictable_count() == 2
    assert ix.reclaim(2) == 2          # leaf first, then the exposed parent
    assert ix.size == 0 and al.refs[5] == 0 and al.refs[6] == 0


def test_index_reclaim_lru_leaves_first():
    al = _FakeAlloc()
    ix = PrefixIndex(2, al)
    ix.insert([1, 2, 3, 4], [100, 101])    # chain A (older)
    ix.insert([7, 8], [200])               # chain B
    ix.match([1, 2, 3, 4])                 # touch A: B becomes LRU
    assert ix.reclaim(1) == 1
    assert ix.match([7, 8]) == ([], 0), "cold chain must go first"
    assert ix.match([1, 2, 3, 4])[1] == 4
    # next reclaim takes A's leaf, never the (still-linked) root before it
    assert ix.reclaim(1) == 1
    assert ix.match([1, 2, 3, 4]) == ([100], 2)


# ---------------------------------------------------------------------------
# Refcounted allocator + compaction
# ---------------------------------------------------------------------------


def test_allocator_shared_release_and_adopt():
    al = BlockAllocator(PagedKVConfig(block_size=4, num_blocks=8,
                                      max_blocks_per_seq=4))
    a = al.alloc(0, 2)
    al.adopt(1, a)                        # rid 1 shares rid 0's blocks
    assert al.refcount(a[0]) == 2 and al.shared_count() == 2
    assert al.release(0) == []            # still referenced: nothing freed
    assert al.free_count == 6
    assert sorted(al.release(1)) == sorted(a)
    assert al.free_count == 8 and al.shared_count() == 0


def test_allocator_reclaimer_invoked_on_pressure():
    al = BlockAllocator(PagedKVConfig(block_size=4, num_blocks=4,
                                      max_blocks_per_seq=4))
    held = al.alloc(0, 3)
    calls = []

    def reclaimer(n):
        calls.append(n)
        al.ref_dec(held[0])               # index drops one cached block
        al._owned[0].remove(held[0])
        return 1

    al.set_reclaimer(reclaimer)
    got = al.alloc(1, 2)
    assert calls == [1] and got is not None and len(got) == 2


def test_compact_preserves_shared_mappings():
    al = BlockAllocator(PagedKVConfig(block_size=4, num_blocks=10,
                                      max_blocks_per_seq=8))
    a = al.alloc(0, 3)
    b = al.alloc(1, 2)
    al.adopt(1, a[:2])                    # rid 1 shares rid 0's first blocks
    al.release(0)                         # rid 0 leaves; shared pair survives
    idx_only = al.alloc(2, 1)             # stand-in for an index-held block
    al._owned.pop(2)                      # owned by nobody, kept via extra_live
    src, remap = al.compact(extra_live=idx_only)
    # shared blocks keep ONE identity: rid 1's adopted tail == old a[:2]
    assert al.owned(1) == [int(remap[x]) for x in b + a[:2]]
    assert al.owned(1)[2:] == [int(remap[x]) for x in a[:2]]
    assert al.refcount(al.owned(1)[2]) == 1      # rid 1 only, post-release
    assert al.refcount(int(remap[idx_only[0]])) == 1
    live = len(set(al.owned(1))) + 1             # + the extra_live block
    assert al.free_count == 10 - live
    # src/remap are inverse over the live range and fix the trash block
    assert all(int(remap[src[i]]) == i for i in range(10))
    assert src[10] == remap[10] == 10


def test_cow_copy_block_masks_tail(model):
    cfg, params = model
    state = lm.init_paged_state(cfg, 1, num_blocks=4, block_size=8)
    table = jnp.asarray(np.array([[0, 1, 2, 3]], np.int32))
    packed = lm.pack(params, cfg)
    toks = np.array([3, 141, 59, 265, 358], np.int32)
    for t, tok in enumerate(toks):
        _, state = lm.decode_step(packed, jnp.asarray([[tok]], jnp.int32),
                                  jnp.asarray([t], jnp.int32), cfg, state,
                                  table=table)
    state = cow_copy_block(state, cfg, 0, 1, valid=3)

    def check(st, kind, stacked):
        if kind in ("attn", "local"):
            pos = np.asarray(st["pos"])
            s, d = (pos[:, 0], pos[:, 1]) if stacked else (pos[0], pos[1])
            np.testing.assert_array_equal(s[..., :5], d[..., :5] * 0 +
                                          np.arange(5), err_msg="src intact")
            np.testing.assert_array_equal(d[..., :3], s[..., :3])
            assert (d[..., 3:] == -1).all(), "copied tail must be masked"
            for name, a in st.items():
                if name == "pos":
                    continue
                arr = np.asarray(a)
                sb, db = (arr[:, 0], arr[:, 1]) if stacked else (arr[0], arr[1])
                np.testing.assert_array_equal(db[..., :3, :], sb[..., :3, :])
        return st

    from repro.serve.kvcache import map_layer_states
    map_layer_states(state, cfg, check)


# ---------------------------------------------------------------------------
# Engine-level: warm == cold, bit for bit (the acceptance claim)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("quant", [
    QuantConfig(mode="fp"),
    QuantConfig(mode="quant", fmt="i2s", act="token"),
], ids=["fp", "i2s-act-token"])
def test_shared_prefix_tokens_bitexact_attention(quant):
    cfg = _cfg(quant=quant)
    params = lm.init(KEY, cfg)
    prompts = _shared_prompts(cfg)
    cold, _ = _run(params, cfg, prompts, prefix=False)
    warm, eng = _run(params, cfg, prompts, prefix=True)
    assert warm == cold
    assert eng.prefix_inert_reason is None
    s = eng.metrics_summary()
    assert s["prefix_hit_rate"] > 0 and s["prefill_tokens_skipped"] > 0
    assert s["blocks_reused"] > 0


@pytest.mark.parametrize("arch", ["recurrentgemma-2b", "mamba2-1.3b"],
                         ids=["rg-lru", "ssd"])
def test_shared_prefix_inert_on_recurrent_archs(arch):
    """Recurrent / SSD layer state is a per-slot carry with no block
    identity: the flag must go INERT (zero hits, recorded reason), not
    corrupt state — tokens stay bit-identical to the cache-off run."""
    cfg = _cfg(arch)
    params = lm.init(KEY, cfg)
    prompts = _shared_prompts(cfg, n=3, prefix_len=12, seed=2)
    cold, _ = _run(params, cfg, prompts, prefix=False, max_new=4,
                   batch_slots=2, max_seq=48)
    warm, eng = _run(params, cfg, prompts, prefix=True, max_new=4,
                     batch_slots=2, max_seq=48)
    assert warm == cold
    assert eng.prefix is None and "per-slot hidden state" in eng.prefix_inert_reason
    assert eng.metrics_summary()["prefix_hit_rate"] == 0


def test_shared_prefix_inert_on_dense_kv(model):
    cfg, params = model
    prompts = _shared_prompts(cfg, n=2)
    cold, _ = _run(params, cfg, prompts, prefix=False, paged=False)
    warm, eng = _run(params, cfg, prompts, prefix=True, paged=False)
    assert warm == cold
    assert eng.prefix is None and "paged" in eng.prefix_inert_reason


def test_cow_divergence_mid_block(model):
    """Two prompts sharing 12 tokens at block_size 8: the second request
    reuses one full block and COWs the half-full divergence block — and
    still decodes bit-identically to a cold run."""
    cfg, params = model
    rng = np.random.default_rng(3)
    shared = rng.integers(0, cfg.vocab, size=12).tolist()
    # first prompt ends ON a block boundary (16 = 2 full blocks) so its
    # divergence block is actually published to the index
    prompts = [shared + [11, 12, 13, 14], shared + [91, 92, 93, 94]]
    cold, _ = _run(params, cfg, prompts, prefix=False, batch_slots=1)
    warm, eng = _run(params, cfg, prompts, prefix=True, batch_slots=1)
    assert warm == cold
    m2 = eng.stats.finished[-1]
    assert m2.prefix_hit_tokens == 12     # 8 shared + 4 via COW copy
    assert m2.prefix_hit_blocks == 2      # one adopted, one copied


def test_engine_defrag_preserves_prefix_hits(model):
    """compact() is a pure relabel even with an active index: cached
    blocks survive defrag (remapped, not scrubbed) and a later request
    still hits and decodes bit-identically."""
    cfg, params = model
    prompts = _shared_prompts(cfg, n=2)
    cold, _ = _run(params, cfg, prompts, prefix=False, batch_slots=1)

    eng = ServeEngine(params, cfg, ServeConfig(
        batch_slots=1, max_seq=64, paged=True, block_size=8,
        prefill_chunk=4, prefix_cache=True))
    eng.submit(Request(rid=0, prompt=prompts[0], max_new_tokens=5))
    out = {r.rid: r.out_tokens for r in eng.run()}
    assert eng.prefix.size > 0
    eng.defrag()                          # relabel under live cached blocks
    eng.submit(Request(rid=1, prompt=prompts[1], max_new_tokens=5))
    out.update({r.rid: r.out_tokens for r in eng.run()})
    assert out == cold
    assert eng.stats.finished[-1].prefix_hit_tokens > 0


def test_cache_evicted_under_pressure_never_breaks_decode(model):
    """A pool sized for ~1.5 requests forces the reclaimer to evict cached
    leaves on admission; outputs must still match the cache-off run."""
    cfg, params = model
    prompts = _shared_prompts(cfg, n=4, prefix_len=16, seed=4)
    kw = dict(batch_slots=1, max_seq=64, kv_blocks=6)
    cold, _ = _run(params, cfg, prompts, prefix=False, **kw)
    warm, eng = _run(params, cfg, prompts, prefix=True, **kw)
    assert warm == cold
    s = eng.metrics_summary()
    assert s["kv_blocks_free"] + s["prefix_cached_blocks"] <= 6


# ---------------------------------------------------------------------------
# QoS classes
# ---------------------------------------------------------------------------


def test_qos_format_selection_tracks_registry():
    assert qos.select_format("latency") == "int2_g128"
    assert qos.select_format("memory") == "tl2"
    assert qos.select_format("standard") == "i2s"
    # restricted candidate sets re-resolve instead of hard-coding names
    assert qos.select_format("latency", ["i2s", "tl1_g128"]) == "tl1_g128"
    # no grouped LUT format in range (e.g. K % 128 != 0): an ungrouped
    # true-LUT GEMV format still beats the balanced fallback for decode
    assert qos.select_format("latency", ["i2s", "tl1", "int2"]) == "int2"
    assert qos.select_format("memory", ["i2s", "tl1"]) == "tl1"
    assert qos.select_format("memory", ["i2s", "fp"]) == "i2s"  # fallback
    with pytest.raises(KeyError, match="unknown QoS class"):
        qos.select_format("turbo")


def test_qos_boost_orders_admission(model):
    """A latency-class submission jumps the standard-class queue (boost 2
    beats default 0) without callers touching raw priorities."""
    cfg, params = model
    eng = ServeEngine(params, cfg, ServeConfig(batch_slots=1, max_seq=32,
                                               paged=True, block_size=8))
    eng.submit(Request(rid=0, prompt=[1, 2, 3], max_new_tokens=2),
               qos="standard")
    eng.submit(Request(rid=1, prompt=[4, 5, 6], max_new_tokens=2),
               qos="latency")
    done = eng.run()
    assert [r.rid for r in done] == [1, 0]
    assert [m.qos for m in eng.stats.finished] == ["latency", "standard"]
