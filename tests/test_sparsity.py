"""Zero-occupancy skip walk + bit-contiguous packing (DESIGN.md §11).

Three contracts on top of the generic conformance harness (which already
runs every registered kernel on the ``_bc``/``_z`` formats against the fp64
oracle at atol=0):

  (a) skip ≡ dense, bit for bit, on mixed zero/nonzero and all-zero weight
      columns — for the MAD GEMM path AND the true-LUT GEMV path;
  (b) the bit-contiguous stream really is bit-contiguous: int3_bc packs at
      3.0 bpw (≤ 3.2 with occupancy metadata), codes round-trip, and the
      unit math matches the documented 3-byte/4-code/8-weight layout;
  (c) the dispatch cost hints see occupancy: skip-kernel hints scale with
      the nonzero-block fraction, other kernels ignore it.

Plus the tl2-fold regression: the mirror-consolidated kernel now living in
``elut_matmul.py`` must stay bit-identical to the XLA int32 reference — the
exact contract the retired ``kernels/tl2_matmul.py`` was pinned to.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dispatch, formats, packing
from repro.core.qtensor import pack_quantized, pack_ternary, unpack_weight
from repro.kernels import ops, ref

INTERPRET = True  # CPU container: kernel bodies execute in Python

OCC = formats.OCC_BLOCK_COLS


def _zero_columns(w: np.ndarray, blocks) -> np.ndarray:
    """Zero whole OCC-column blocks across every output row (the
    column-structured sparsity the bm-wide skip predicate can exploit)."""
    w = w.copy()
    for blk in blocks:
        w[:, blk * OCC:(blk + 1) * OCC] = 0
    return w


def _sparse_fixture(fmt: str, n: int, k: int, m: int, blocks, seed=0):
    spec = formats.get(fmt)
    lo, hi = spec.levels
    rng = np.random.default_rng(seed)
    w = _zero_columns(
        rng.integers(lo, hi + 1, size=(m, k)).astype(np.int8), blocks)
    x_q = jnp.asarray(rng.integers(-127, 128, size=(n, k)), jnp.int8)
    pw = pack_quantized(jnp.asarray(w), jnp.float32(0.5), fmt)
    return w, pw, x_q


# ---------------------------------------------------------------------------
# (a) skip walk ≡ dense walk ≡ oracle, bit for bit
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("blocks", [(), (1, 3, 4, 6), tuple(range(8))],
                         ids=["dense", "mixed", "all-zero"])
@pytest.mark.parametrize("fmt", formats.occupancy_formats())
def test_mad_skip_bit_identical_to_dense(fmt, blocks):
    w, pw, x_q = _sparse_fixture(fmt, 5, 8 * OCC, 128, blocks)
    y_skip = ops.mpgemm_pallas(x_q, jnp.float32(2.0), pw,
                               interpret=INTERPRET, zero_skip=True)
    y_dense = ops.mpgemm_pallas(x_q, jnp.float32(2.0), pw,
                                interpret=INTERPRET, zero_skip=False)
    np.testing.assert_array_equal(np.asarray(y_skip), np.asarray(y_dense))
    # 0.5 · 2.0 = 1 → fp32 result equals the raw int32 accumulation exactly
    y_ref = np.asarray(ref.mpgemm_int32(x_q, jnp.asarray(w)))
    np.testing.assert_array_equal(np.asarray(y_skip, np.int64),
                                  y_ref.astype(np.int64))


@pytest.mark.parametrize("blocks", [(1, 3, 4, 6), tuple(range(8))],
                         ids=["mixed", "all-zero"])
@pytest.mark.parametrize("fmt", [f for f in formats.occupancy_formats()
                                 if formats.get(f).supports_lut_gemv()])
def test_gemv_skip_bit_identical_to_dense(fmt, blocks):
    w, pw, x_q = _sparse_fixture(fmt, 1, 8 * OCC, 128, blocks)
    y_skip = ops.lut_gemv(x_q, jnp.float32(2.0), pw,
                          interpret=INTERPRET, zero_skip=True)
    y_dense = ops.lut_gemv(x_q, jnp.float32(2.0), pw,
                           interpret=INTERPRET, zero_skip=False)
    np.testing.assert_array_equal(np.asarray(y_skip), np.asarray(y_dense))
    y_ref = np.asarray(ref.mpgemm_int32(x_q, jnp.asarray(w)))
    np.testing.assert_array_equal(np.asarray(y_skip, np.int64),
                                  y_ref.astype(np.int64))


def test_occupancy_map_and_measured_fraction():
    w = np.ones((4, 4 * OCC), np.int8)
    w[:, OCC:2 * OCC] = 0                    # block 1 dead in every row
    w[0, 3 * OCC] = 0                        # one zero does NOT kill a block
    occ = np.asarray(packing.occupancy_map(jnp.asarray(w), OCC))
    assert occ.shape == (4, 4) and occ.dtype == np.uint8
    np.testing.assert_array_equal(occ[:, 1], 0)
    np.testing.assert_array_equal(occ[:, [0, 2, 3]], 1)
    pw = pack_ternary(jnp.asarray(w), jnp.float32(1.0), "tl1_z")
    assert pw.occupancy() == pytest.approx(0.75)
    assert pack_ternary(jnp.asarray(np.ones((4, 4 * OCC), np.int8)),
                        jnp.float32(1.0), "tl1").occupancy() == 1.0
    with pytest.raises(ValueError, match="needs K %"):
        packing.occupancy_map(jnp.asarray(w[:, :OCC + 8]), OCC)


# ---------------------------------------------------------------------------
# (b) bit-contiguous stream: layout math, bpw budget, code roundtrip
# ---------------------------------------------------------------------------


def test_bc_unit_math_and_registry_bpw():
    assert packing.bc_unit(6) == (3, 4)      # int3_bc: 3-byte / 4-code unit
    assert packing.bc_unit(4) == (1, 2)      # byte-aligned degenerates to ub=1
    bc = formats.get("int3_bc")
    assert (bc.code_bits, bc.unit_bytes, bc.codes_per_unit,
            bc.weights_per_unit) == (6, 3, 4, 8)
    assert bc.bpw == 3.0                      # true 3 bpw vs int3's 4.0
    assert formats.get("int3").bpw == 4.0     # byte-field cost, unchanged
    assert formats.get("tl1_z").bpw == pytest.approx(2.0 + 8 / OCC)
    assert formats.get("int3_bc_z").bpw == pytest.approx(3.0 + 8 / OCC)
    assert formats.occupancy_formats() == ("tl1_z", "int3_bc_z")


def test_int3_bc_z_packs_within_bpw_budget():
    rng = np.random.default_rng(7)
    w = jnp.asarray(rng.integers(-4, 4, size=(128, 1024)), jnp.int8)
    pw = pack_quantized(w, jnp.float32(1.0), "int3_bc_z")
    assert pw.bpw() <= 3.2                    # acceptance: ≤ 3.2 incl metadata
    assert pw.bpw() == pytest.approx(3.0 + 8 / OCC)
    np.testing.assert_array_equal(np.asarray(unpack_weight(pw)), np.asarray(w))


def test_bc_codes_agree_with_byte_field_codes():
    """Same (b, g) code sequence through both layouts — the stream changes,
    the codes must not."""
    rng = np.random.default_rng(11)
    w = jnp.asarray(rng.integers(-4, 4, size=(16, 64)), jnp.int8)
    p_bc = packing.elut_pack_bc(w, 8, 2, 6)
    p_by = packing.elut_pack(w, 8, 2, 8)
    codes_bc = np.asarray(packing.elut_codes_bc(p_bc, 6))
    codes_by = np.asarray(packing.elut_codes(p_by, 8))
    np.testing.assert_array_equal(codes_bc, codes_by)
    assert p_bc.shape[1] * 8 == 6 * codes_bc.shape[1]   # no slack bits
    np.testing.assert_array_equal(
        np.asarray(packing.elut_unpack_bc(p_bc, 64, 8, 2, 6)), np.asarray(w))


# ---------------------------------------------------------------------------
# (c) occupancy-aware cost hints
# ---------------------------------------------------------------------------


def test_cost_hints_scale_with_occupancy():
    pal, xla = dispatch.REGISTRY["pallas"], dispatch.REGISTRY["xla"]
    shape = ("tl1_z", 128, 1024, 1024)
    assert pal.hbm_bytes(*shape, 0.25) < pal.hbm_bytes(*shape, 0.5) \
        < pal.hbm_bytes(*shape, 1.0)
    assert pal.cost(*shape, 0.25) < pal.cost(*shape, 1.0)
    # occupancy metadata is always streamed: the floor is not zero
    assert pal.hbm_bytes(*shape, 0.0) > 128 * 1024  # > activations alone
    # non-skip kernels and non-occupancy formats ignore the hint
    assert xla.cost(*shape, 0.25) == xla.cost(*shape, 1.0)
    assert pal.cost("tl1", 128, 1024, 1024, 0.25) == \
        pal.cost("tl1", 128, 1024, 1024, 1.0)
    ex = dispatch.explain("tl1_z", 128, 1024, 1024, occupancy=0.25)
    assert ex["occupancy"] == 0.25
    cand = dict(ex["candidates"])
    assert cand["pallas"] == pytest.approx(
        dispatch.REGISTRY["pallas"].cost("tl1_z", 128, 1024, 1024, 0.25),
        abs=1e-3)


# ---------------------------------------------------------------------------
# tl2-fold regression: the parametric mirror kernel keeps the retired
# tl2_matmul.py contract (kernel ≡ XLA int32 reference, bit for bit)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,k,m", [(8, 1536, 128), (5, 1600, 128)],
                         ids=["pure-2k", "tl1-tail"])
def test_tl2_fold_keeps_retired_kernel_contract(n, k, m):
    rng = np.random.default_rng(n + k)
    w = jnp.asarray(rng.integers(-1, 2, size=(m, k)), jnp.int8)
    x_q = jnp.asarray(rng.integers(-127, 128, size=(n, k)), jnp.int8)
    pw = pack_ternary(w, jnp.float32(0.5), "tl2k")
    y = ops.mpgemm_pallas(x_q, jnp.float32(2.0), pw, interpret=INTERPRET)
    y_ref = np.asarray(ref.mpgemm_int32(x_q, w))
    np.testing.assert_array_equal(np.asarray(y, np.int64),
                                  y_ref.astype(np.int64))
