"""Core library tests: packing roundtrips, mpGEMM equivalence, losslessness.

The paper's central claims, as testable invariants:
  * every packing format is a bijection on ternary matrices (roundtrip);
  * all formats compute the identical mpGEMM (bit-exact int32 accumulation);
  * LUT-based lossless (TL*_1) == MAD-based exactly (paper §3.2.1);
  * the lossy `_0` variants and the Q8_K block scheme deviate boundedly;
  * the quantized integer forward reproduces the QAT fake-quant forward
    (the "lossless inference for BitNet b1.58" claim, Figure 2).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypo import given, settings, st

from repro.core import bitlinear, mpgemm, packing, quant
from repro.core.qtensor import FORMAT_BPW, pack_ternary, pack_weight, unpack_weight

# Every integer format: ternary {-1,0,1} is a valid code set for all of
# them, so the ternary equivalence sweeps cover int2/int3 too (full-range
# non-ternary coverage lives in test_formats.py).
FORMATS = ["i2s", "tl1", "tl2", "tl2k", "tq1", "int4", "int2", "int3"]


def random_ternary(rng: np.random.Generator, m: int, k: int) -> jnp.ndarray:
    return jnp.asarray(rng.integers(-1, 2, size=(m, k)), jnp.int8)


# ---------------------------------------------------------------------------
# Packing roundtrips (property-based)
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 9),
    k_units=st.integers(1, 6),
    seed=st.integers(0, 2**31 - 1),
    fmt=st.sampled_from(FORMATS),
)
def test_pack_roundtrip_property(m, k_units, seed, fmt):
    k = 768 * k_units  # satisfies every format's alignment (24 | 768, 4 | 768)
    rng = np.random.default_rng(seed)
    w = random_ternary(rng, m, k)
    pw = pack_ternary(w, jnp.float32(1.0), fmt)
    rt = unpack_weight(pw)
    np.testing.assert_array_equal(np.asarray(rt, np.int8), np.asarray(w))


def test_bpw_accounting():
    rng = np.random.default_rng(0)
    w = random_ternary(rng, 64, 768)
    for fmt in FORMATS:
        pw = pack_ternary(w, jnp.float32(1.0), fmt)
        assert pw.bpw() == pytest.approx(FORMAT_BPW[fmt], rel=0.05), fmt


def test_tl2_mirror_consolidation_table():
    """Paper Table 6: sign+idx encoding covers 0..26 with idx ≤ 13."""
    w = jnp.array([[a, b, c] for a in (-1, 0, 1) for b in (-1, 0, 1) for c in (-1, 0, 1)], jnp.int8)
    idx, sign = packing.tl2_encode_groups(w)
    assert int(idx.max()) <= 13  # fits a nibble: 3^3/2 < 2^4 (paper §3.1.1)
    # center (0,0,0) is self-mirrored with sign 0
    center = 13
    assert int(idx[center, 0]) == 13 and int(sign[center, 0]) == 0
    # mirror symmetry: w and -w share idx, differ in sign (except center)
    for i in range(27):
        j = 26 - i
        assert int(idx[i, 0]) == int(idx[j, 0])
        if i != center:
            assert int(sign[i, 0]) != int(sign[j, 0])


def test_tl2_split_k_block_fitting():
    three_k, two_k = packing.tl2_split_k(1000)
    assert three_k % 24 == 0 and three_k + two_k == 1000 and two_k % 4 == 0
    with pytest.raises(ValueError):
        packing.tl2_split_k(1001)  # K must be 4-aligned


# ---------------------------------------------------------------------------
# mpGEMM equivalence across formats (bit-exact)
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(1, 5))
def test_mpgemm_formats_bit_identical(seed, n):
    rng = np.random.default_rng(seed)
    k, m = 768, 32
    w = random_ternary(rng, m, k)
    x_q = jnp.asarray(rng.integers(-127, 128, size=(n, k)), jnp.int8)
    ys = {}
    for fmt in FORMATS:
        pw = pack_ternary(w, jnp.float32(1.0), fmt)
        ys[fmt] = np.asarray(mpgemm.mpgemm_xla(x_q, jnp.float32(1.0), pw))
    base = ys["i2s"]
    for fmt, y in ys.items():
        np.testing.assert_array_equal(y, base, err_msg=fmt)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_lut_lossless_equals_mad(seed):
    """TL1_1 / TL2_1 (pack-and-unpack) are bit-identical to the MAD path."""
    rng = np.random.default_rng(seed)
    k, m, n = 768, 24, 3
    w = random_ternary(rng, m, k)
    x_q = jnp.asarray(rng.integers(-127, 128, size=(n, k)), jnp.int8)
    ref = np.asarray(mpgemm.mpgemm_xla(x_q, jnp.float32(1.0), pack_ternary(w, jnp.float32(1.0), "i2s")))
    y1 = np.asarray(mpgemm.tl1_lut(x_q, 1.0, pack_ternary(w, jnp.float32(1.0), "tl1"), lossless=True))
    y2 = np.asarray(mpgemm.tl2_lut(x_q, 1.0, pack_ternary(w, jnp.float32(1.0), "tl2"), lossless=True))
    np.testing.assert_array_equal(y1, ref)
    np.testing.assert_array_equal(y2, ref)


def test_mpgemm_q8_block_per_block_semantics():
    """Q8_K-style per-block scales against an independent numpy triple loop."""
    rng = np.random.default_rng(9)
    n, k, m, block = 3, 512, 32, 128
    w = random_ternary(rng, m, k)
    x_q = jnp.asarray(rng.integers(-127, 128, size=(n, k)), jnp.int8)
    s_b = jnp.asarray(rng.uniform(0.01, 2.0, size=(n, k // block)), jnp.float32)
    pw = pack_ternary(w, jnp.float32(0.25), "i2s")
    y = np.asarray(mpgemm.mpgemm_q8_block(x_q, s_b, pw, block))

    xn, wn, sn = np.asarray(x_q, np.int64), np.asarray(w, np.int64), np.asarray(s_b)
    y_ref = np.zeros((n, m))
    for i in range(n):
        for j in range(m):
            acc = 0.0
            for b in range(k // block):
                sl = slice(b * block, (b + 1) * block)
                acc += float(xn[i, sl] @ wn[j, sl]) * sn[i, b]  # scale PER BLOCK
            y_ref[i, j] = acc * 0.25
    # f64 loop vs the f32 partial-sum reassociation: tolerance, not bit-exact
    np.testing.assert_allclose(y, y_ref, rtol=1e-5, atol=1e-4)

    # uniform block scales collapse to the per-tensor scheme exactly
    s_u = jnp.full((n, k // block), 0.5, jnp.float32)
    y_u = np.asarray(mpgemm.mpgemm_q8_block(x_q, s_u, pw, block))
    y_t = np.asarray(mpgemm.mpgemm_xla(x_q, jnp.float32(0.5), pw))
    np.testing.assert_allclose(y_u, y_t, rtol=1e-6)


@pytest.mark.parametrize("k", [16, 1000])
def test_tl2_lut_twok_tail_fallback(k):
    """Block-fitting split (paper §3.1.2): K=16 is ALL TwoK tail (three_k=0),
    K=1000 mixes a 984 ThreeK prefix with a 16-wide TL1 tail."""
    rng = np.random.default_rng(k)
    m, n = 24, 3
    w = random_ternary(rng, m, k)
    x_q = jnp.asarray(rng.integers(-127, 128, size=(n, k)), jnp.int8)
    pw = pack_ternary(w, jnp.float32(1.0), "tl2")
    three_k, two_k = packing.tl2_split_k(k)
    assert (three_k, two_k) == ((0, 16) if k == 16 else (984, 16))
    assert pw.three_k == three_k
    ref = np.asarray(mpgemm.mpgemm_xla(
        x_q, jnp.float32(1.0), pack_ternary(w, jnp.float32(1.0), "i2s")))
    y1 = np.asarray(mpgemm.tl2_lut(x_q, jnp.float32(1.0), pw, lossless=True))
    np.testing.assert_array_equal(y1, ref)
    y0 = np.asarray(mpgemm.tl2_lut(x_q, jnp.float32(1.0), pw, lossless=False))
    rel = np.abs(y0 - ref).max() / max(np.abs(ref).max(), 1)
    assert rel < 0.05


def test_lut_lossy_bounded():
    """TL*_0 (int8-requantized LUT) deviate, but boundedly (paper Table 2)."""
    rng = np.random.default_rng(3)
    k, m, n = 1536, 64, 4
    w = random_ternary(rng, m, k)
    x_q = jnp.asarray(rng.integers(-127, 128, size=(n, k)), jnp.int8)
    ref = np.asarray(mpgemm.mpgemm_xla(x_q, jnp.float32(1.0), pack_ternary(w, jnp.float32(1.0), "i2s")))
    for fmt, fn in (("tl1", mpgemm.tl1_lut), ("tl2", mpgemm.tl2_lut)):
        y0 = np.asarray(fn(x_q, 1.0, pack_ternary(w, jnp.float32(1.0), fmt), lossless=False))
        rel = np.abs(y0 - ref).max() / np.abs(ref).max()
        assert 0 < rel < 0.05, (fmt, rel)  # lossy but small


# ---------------------------------------------------------------------------
# Quantization scheme properties
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_ternary_quant_range_and_scale(seed):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(size=(16, 64)), jnp.float32)
    w_t, s = quant.ternary_quant(w)
    assert set(np.unique(np.asarray(w_t))) <= {-1, 0, 1}
    assert float(s) == pytest.approx(float(jnp.mean(jnp.abs(w))), rel=1e-6)


def test_act_quant_per_tensor_vs_block_differ():
    """Q8_K-style block quant ≠ per-tensor quant — the paper's lossless gap."""
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(4, 512)) * np.linspace(0.1, 10, 512), jnp.float32)
    q_t, _ = quant.absmax_int8(x)
    q_b, _ = quant.q8_block(x, 256)
    assert np.abs(np.asarray(q_t, np.int32) - np.asarray(q_b, np.int32)).max() > 0


def test_ste_gradients_flow():
    w = jnp.ones((8, 8)) * 0.3
    g = jax.grad(lambda w: jnp.sum(quant.ternary_fake_quant(w) ** 2))(w)
    assert np.all(np.isfinite(np.asarray(g))) and float(jnp.abs(g).max()) > 0


# ---------------------------------------------------------------------------
# Lossless inference for BitNet b1.58 (Figure 2): QAT forward == integer path
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("fmt", ["i2s", "tl1", "tl2", "tl2k", "int4"])
def test_bitlinear_quant_matches_qat(fmt):
    key = jax.random.PRNGKey(0)
    p = bitlinear.init(key, 768, 128)
    x = jax.random.normal(jax.random.PRNGKey(1), (5, 768))
    y_qat = bitlinear.apply(p, x, bitlinear.QuantConfig(mode="qat"))
    cfg = bitlinear.QuantConfig(mode="quant", fmt=fmt)
    y_q = bitlinear.apply(bitlinear.pack_tree(p, cfg), x, cfg)
    # identical up to fp32 reassociation of the final (tiny) rescale
    np.testing.assert_allclose(np.asarray(y_q), np.asarray(y_qat), atol=2e-5, rtol=1e-5)


def test_bitlinear_block_act_is_lossy():
    """Per-block activations (TQ semantics) break QAT alignment (paper §2.3)."""
    key = jax.random.PRNGKey(0)
    p = bitlinear.init(key, 512, 64)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 512)) * jnp.linspace(0.01, 5.0, 512)
    y_qat = bitlinear.apply(p, x, bitlinear.QuantConfig(mode="qat"))
    cfg = bitlinear.QuantConfig(mode="quant", fmt="i2s", act="block")
    y_b = bitlinear.apply(bitlinear.pack_tree(p, cfg), x, cfg)
    assert float(jnp.abs(y_b - y_qat).max()) > 1e-4  # measurably not lossless


def test_pack_tree_generic():
    key = jax.random.PRNGKey(0)
    params = {
        "attn": {"qkv": bitlinear.init(key, 256, 768), "o": bitlinear.init(key, 256, 256)},
        "norm": jnp.ones((256,)),
    }
    cfg = bitlinear.QuantConfig(fmt="i2s")
    packed = bitlinear.pack_tree(params, cfg)
    assert packed["attn"]["qkv"].w.fmt == "i2s"
    assert isinstance(packed["norm"], jax.Array)
    assert bitlinear.packed_bits(packed) == 2 * (256 * 768 + 256 * 256)
