"""End-to-end TP serving bit-identity (DESIGN.md §12).

The production claim behind the sharded conformance tier: a ServeEngine on
a TP=2 host mesh — paged KV, batched concurrent prefill, prefix cache, the
whole §7 serving stack — emits tokens BIT-IDENTICAL to the unsharded engine
for the same seed and workload at act=token, and a packed checkpoint
round-tripped through ckpt/store.py onto the mesh serves identically.

M-sharded packed planes keep every kernel's per-output-row arithmetic
identical to unsharded (full-K contraction per row), act=token keeps the
quantization composition-invariant, and greedy sampling is argmax — so
token equality is exact, not approximate.

Mesh tests self-skip below 2 devices; tier-1's single-device run covers
them via a subprocess with ``XLA_FLAGS=--xla_force_host_platform_device_
count=2`` executing this file's ``__main__`` (the CI ``tp-host-mesh`` leg
runs in-process on 4 forced devices).
"""

import os
import subprocess
import sys
import tempfile

import jax
import numpy as np
import pytest
from jax.sharding import Mesh

from repro import configs
from repro.ckpt import store
from repro.core.bitlinear import QuantConfig
from repro.distributed import sharding
from repro.models import lm
from repro.serve import Request, ServeConfig, ServeEngine

NDEV = len(jax.devices())
needs_mesh2 = pytest.mark.skipif(
    NDEV < 2, reason="needs >=2 devices "
    "(XLA_FLAGS=--xla_force_host_platform_device_count=2)")

SERVE_KW = dict(batch_slots=2, max_seq=64, paged=True, block_size=8,
                prefill_chunk=4, prefill_budget=8, prefix_cache=True)


def _cfg():
    return configs.smoke("qwen1.5-0.5b").replace(
        dtype="float32",
        quant=QuantConfig(mode="quant", fmt="i2s", act="token"))


def _prompts(cfg, n=4):
    rng = np.random.default_rng(0)
    return [rng.integers(0, cfg.vocab, size=rng.integers(5, 9)).tolist()
            for _ in range(n)]


def _tp_mesh(n=2) -> Mesh:
    return Mesh(np.array(jax.devices()[:n]).reshape(1, n), ("data", "model"))


def _serve_tokens(params, cfg, mesh, *, pack=True):
    eng = ServeEngine(params, cfg, ServeConfig(**SERVE_KW), pack=pack,
                      mesh=mesh)
    for i, p in enumerate(_prompts(cfg)):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=5))
    return {r.rid: r.out_tokens for r in eng.run()}, eng


def run_tp_bit_identity() -> None:
    """TP=2 engine == unsharded engine, token for token, on the paged +
    batched-prefill + prefix-cache workload (also run by __main__)."""
    cfg = _cfg()
    params = lm.init(jax.random.PRNGKey(0), cfg)
    ref, _ = _serve_tokens(params, cfg, None)
    tokens, eng = _serve_tokens(params, cfg, _tp_mesh(2))
    assert tokens == ref, f"TP=2 tokens diverged:\n{tokens}\nvs\n{ref}"
    ms = eng.metrics_summary()
    assert ms["tp"] == 2 and ms["mesh_axes"] == {"data": 1, "model": 2}
    # the serving workload really exercised the sharded stack
    assert ms["kv_blocks_shared"] >= 0 and ms["requests"] == 4


def run_ckpt_roundtrip_bit_identity(ckpt_dir: str) -> None:
    """Packed params → store.save → store.restore(mesh=TP mesh) serve the
    same tokens as the unsharded engine over the raw weights."""
    cfg = _cfg()
    params = lm.init(jax.random.PRNGKey(0), cfg)
    ref, _ = _serve_tokens(params, cfg, None)
    packed = lm.pack(params, cfg)
    store.save(packed, ckpt_dir, 0)
    mesh = _tp_mesh(2)
    restored, _extra = store.restore(packed, ckpt_dir, 0, mesh=mesh)
    # restore placed every leaf on the mesh with the §12 rules already
    for leaf in jax.tree_util.tree_leaves(restored):
        assert len(leaf.sharding.device_set) >= 1
    tokens, _ = _serve_tokens(restored, cfg, mesh, pack=False)
    assert tokens == ref, "checkpoint-restored TP engine diverged"


@needs_mesh2
def test_tp2_engine_bit_identical():
    run_tp_bit_identity()


@needs_mesh2
def test_tp2_ckpt_roundtrip_serves_identically(tmp_path):
    run_ckpt_roundtrip_bit_identity(str(tmp_path))


def test_restore_rejects_mesh_and_shardings(tmp_path):
    cfg = _cfg()
    params = lm.pack(lm.init(jax.random.PRNGKey(0), cfg), cfg)
    store.save(params, str(tmp_path), 0)
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    with pytest.raises(ValueError, match="not both"):
        store.restore(params, str(tmp_path), 0, mesh=mesh,
                      shardings=sharding.shard_params(params, mesh, "infer"))


def test_grouped_scale_plane_spec_travels_with_columns():
    """The dense-only-rules bug this PR fixes: a grouped [K//G, M] scale
    plane under a BitLinear param must shard its COLUMNS (M, with the code
    rows), never its K//G group rows."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    import jax.numpy as jnp
    plane = jnp.zeros((4, 64), jnp.float32)   # [K//G, M]
    spec = sharding.param_spec(["q", "w", "scale"], plane, mesh, "infer")
    assert spec == jax.sharding.PartitionSpec(None, "model")
    stacked = jnp.zeros((2, 4, 64), jnp.float32)  # scanned: [L, K//G, M]
    spec = sharding.param_spec(["stack", "scan", "q", "w", "scale"],
                               stacked, mesh, "infer")
    assert spec == jax.sharding.PartitionSpec(None, None, "model")
    scalar = jnp.float32(1.0)
    assert sharding.param_spec(["q", "w", "scale"], scalar, mesh, "infer") \
        == jax.sharding.PartitionSpec()


def test_fit_drop_is_counted_and_observable():
    """Satellite fix: the _fit divisibility fallback is counted and surfaces
    through the obs metrics registry instead of silently replicating."""
    from repro import obs as obs_mod

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    before = sharding.axes_dropped()
    sharding._fit(("model",), (63,), mesh)  # 63 % 1 == 0: no drop
    assert sharding.axes_dropped() == before

    class FakeMesh:  # a 2-wide model axis without needing 2 devices
        shape = {"data": 1, "model": 2}
        axis_names = ("data", "model")

    sharding._fit(("model",), (63,), FakeMesh())  # 63 % 2 != 0: DROP
    assert sharding.axes_dropped() == before + 1
    o = obs_mod.make(tracing=False, kernel_timing=False)
    blob = obs_mod.metrics_blob(o)
    assert blob["sharding"]["axes_dropped"] == sharding.axes_dropped()
    assert blob["metrics"]["counters"]["sharding_axes_dropped"] == \
        sharding.axes_dropped()


@pytest.mark.skipif(NDEV >= 2, reason="mesh tests already ran in-process")
def test_tp_serve_subprocess():
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = {**os.environ,
           "XLA_FLAGS": "--xla_force_host_platform_device_count=2",
           "PYTHONPATH": "src" + os.pathsep + "tests"}
    r = subprocess.run([sys.executable, os.path.abspath(__file__)],
                       capture_output=True, text=True, env=env, cwd=repo)
    assert r.returncode == 0, (r.stdout[-2000:], r.stderr[-2000:])
    assert "TP SERVE OK" in r.stdout


if __name__ == "__main__":
    assert NDEV >= 2, f"run with XLA_FLAGS forcing >=2 host devices, got {NDEV}"
    run_tp_bit_identity()
    print("tp2 bit-identity ok", flush=True)
    with tempfile.TemporaryDirectory() as d:
        run_ckpt_roundtrip_bit_identity(d)
    print("ckpt roundtrip ok")
    print("TP SERVE OK")
