"""Per-kernel validation: interpret-mode Pallas vs pure-jnp/numpy oracles.

Every kernel is swept over shapes (including non-tile-aligned N, block-fitting
K splits) and checked allclose/bit-exact against ``repro.kernels.ref``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypo import given, settings, st

from repro.core import quant
from repro.core.qtensor import pack_ternary
from repro.kernels import ops, ref

INTERPRET = True  # CPU container: kernel bodies execute in Python


def _data(seed, n, k, m):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.integers(-1, 2, size=(m, k)), jnp.int8)
    x_q = jnp.asarray(rng.integers(-127, 128, size=(n, k)), jnp.int8)
    return x_q, w


MATMUL_SWEEP = [
    # (n, k, m) — aligned, row-padded, multi-k-tile, tl1-tail split
    (8, 768, 128),
    (3, 768, 256),      # n padded to tile
    (130, 1536, 128),   # n > one tile and padded
    (16, 2304, 384),    # 3 k-tiles (tl2), m not 128-multiple
    (5, 1600, 128),     # tl2 block-fitting: three_k=1536, tl1 tail=64
]


@pytest.mark.parametrize("fmt", ["i2s", "tl1", "tl2k"])
@pytest.mark.parametrize("n,k,m", MATMUL_SWEEP)
def test_mpgemm_kernels_vs_oracle(fmt, n, k, m):
    x_q, w = _data(42 + n + k + m, n, k, m)
    y_ref = np.asarray(ref.mpgemm_int32(x_q, w))
    pw = pack_ternary(w, jnp.float32(0.5), fmt)
    y = ops.mpgemm_pallas(x_q, jnp.float32(2.0), pw, interpret=INTERPRET)
    # scales 0.5 * 2.0 = 1.0 → result equals raw int32 accumulation exactly
    np.testing.assert_array_equal(np.asarray(y, np.int64), y_ref.astype(np.int64))


@pytest.mark.parametrize("fmt", ["int2", "int3"])
@pytest.mark.parametrize("n,k,m", [(8, 768, 128), (3, 768, 256)])
def test_mpgemm_kernel_nonternary_full_range(fmt, n, k, m):
    """The parametric MAD kernel at (4,2)/(8,2) over the full code range."""
    from repro.core import formats
    from repro.core.qtensor import pack_quantized

    lo, hi = formats.get(fmt).levels
    rng = np.random.default_rng(n + k)
    w = jnp.asarray(rng.integers(lo, hi + 1, size=(m, k)), jnp.int8)
    x_q = jnp.asarray(rng.integers(-127, 128, size=(n, k)), jnp.int8)
    pw = pack_quantized(w, jnp.float32(1.0), fmt)
    y = ops.mpgemm_pallas(x_q, jnp.float32(1.0), pw, interpret=INTERPRET)
    np.testing.assert_array_equal(
        np.asarray(y, np.int64),
        np.asarray(ref.mpgemm_int32(x_q, w), np.int64))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       fmt=st.sampled_from(["i2s", "tl1", "tl2k", "int2", "int3"]))
def test_mpgemm_kernels_property(seed, fmt):
    x_q, w = _data(seed, 4, 768, 128)
    pw = pack_ternary(w, jnp.float32(1.0), fmt)
    y = ops.mpgemm_pallas(x_q, jnp.float32(1.0), pw, interpret=INTERPRET)
    np.testing.assert_array_equal(
        np.asarray(y, np.int64), np.asarray(ref.mpgemm_int32(x_q, w), np.int64)
    )


def test_mpgemm_kernel_vs_naive_loop():
    """Tiny shape against the fully independent numpy triple loop."""
    x_q, w = _data(7, 2, 768, 8)
    pw = pack_ternary(w, jnp.float32(1.0), "i2s")
    y = ops.mpgemm_pallas(x_q, jnp.float32(1.0), pw, interpret=INTERPRET)
    np.testing.assert_array_equal(
        np.asarray(y, np.int64),
        ref.ternary_matmul_naive(np.asarray(x_q), np.asarray(w)).astype(np.int64),
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(8, 512), (3, 1024), (260, 512)])
def test_act_quant_kernel(shape, dtype):
    x = (jax.random.normal(jax.random.PRNGKey(0), shape) * 3).astype(dtype)
    q_k, s_k = ops.act_quant(x, interpret=INTERPRET)
    q_r, s_r = ref.absmax_int8(x)
    np.testing.assert_array_equal(np.asarray(q_k), np.asarray(q_r))
    assert float(s_k) == pytest.approx(float(s_r), rel=1e-6)


LUT_GEMV_CASES = [  # (fmt, k, m): full shape sweep for tl1, spot for int2/int3
    ("tl1", 512, 128), ("tl1", 1024, 256), ("tl1", 512, 64),
    ("int2", 512, 128), ("int3", 512, 64),
]


@pytest.mark.parametrize("lossless", [True, False])
@pytest.mark.parametrize("fmt,k,m", LUT_GEMV_CASES)
def test_lut_gemv_kernel(k, m, lossless, fmt):
    """True-LUT GEMV, parametric over (b, g): ternary tl1 plus the
    non-ternary int2/int3 ELUT instances, full code range."""
    from repro.core import formats

    lo, hi = formats.get(fmt).levels
    rng = np.random.default_rng(k + m)
    w = jnp.asarray(rng.integers(lo, hi + 1, size=(m, k)), jnp.int8)
    x_q = jnp.asarray(rng.integers(-127, 128, size=(k,)), jnp.int8)
    pw = pack_ternary(w, jnp.float32(1.0), fmt)
    y = ops.lut_gemv(x_q, jnp.float32(1.0), pw, lossless=lossless, interpret=INTERPRET)
    y_ref = np.asarray(ref.mpgemm_int32(x_q[None], w))[0]
    if lossless:
        np.testing.assert_array_equal(np.asarray(y, np.int64), y_ref.astype(np.int64))
    else:
        rel = np.abs(np.asarray(y) - y_ref).max() / max(np.abs(y_ref).max(), 1)
        assert rel < 0.05


def test_tl2k_kernel_twok_tail_only():
    """K below one g-tile (3·256) → _tl2k takes the pure TL1 tail path."""
    from repro.core import packing

    rng = np.random.default_rng(2)
    k, m = 16, 8
    w = jnp.asarray(rng.integers(-1, 2, size=(m, k)), jnp.int8)
    x_q = jnp.asarray(rng.integers(-127, 128, size=(3, k)), jnp.int8)
    assert packing.tl2k_split_k(k) == (0, 16)
    pw = pack_ternary(w, jnp.float32(1.0), "tl2k")
    assert pw.three_k == 0 and set(pw.planes) == {"tail"}
    y = ops.mpgemm_pallas(x_q, jnp.float32(1.0), pw, interpret=INTERPRET)
    np.testing.assert_array_equal(
        np.asarray(y, np.int64),
        np.asarray(ref.mpgemm_int32(x_q, w), np.int64))


@pytest.mark.parametrize("lossless", [True, False])
def test_lut_gemv_batched_fallback(lossless):
    """Multi-row inputs route through the registry's batched LUT kernels
    instead of silently building a LUT from the first row only."""
    from repro.core import dispatch

    rng = np.random.default_rng(8)
    k, m = 512, 64
    w = jnp.asarray(rng.integers(-1, 2, size=(m, k)), jnp.int8)
    pw = pack_ternary(w, jnp.float32(1.0), "tl1")
    x2 = jnp.asarray(rng.integers(-127, 128, size=(3, k)), jnp.int8)
    mark = dispatch.decision_count()
    y = ops.lut_gemv(x2, jnp.float32(1.0), pw, lossless=lossless, interpret=INTERPRET)
    assert y.shape == (3, m)
    dec = dispatch.decisions_since(mark)[0]
    assert dec.source == "lut_gemv_fallback"
    assert dec.kernel == ("tl1_lut" if lossless else "tl1_lut_lossy")
    y_ref = np.asarray(ref.mpgemm_int32(x2, w))
    if lossless:
        np.testing.assert_array_equal(np.asarray(y, np.int64), y_ref.astype(np.int64))
    else:
        rel = np.abs(np.asarray(y) - y_ref).max() / max(np.abs(y_ref).max(), 1)
        assert rel < 0.05


def test_lut_gemv_accepts_leading_singletons():
    rng = np.random.default_rng(4)
    k, m = 512, 64
    w = jnp.asarray(rng.integers(-1, 2, size=(m, k)), jnp.int8)
    pw = pack_ternary(w, jnp.float32(1.0), "tl1")
    x = jnp.asarray(rng.integers(-127, 128, size=(k,)), jnp.int8)
    y1 = ops.lut_gemv(x, jnp.float32(1.0), pw, interpret=INTERPRET)
    y2 = ops.lut_gemv(x[None, :], jnp.float32(1.0), pw, interpret=INTERPRET)
    y3 = ops.lut_gemv(x[None, None, :], jnp.float32(1.0), pw, interpret=INTERPRET)
    assert y1.shape == (m,) and y2.shape == (1, m) and y3.shape == (1, 1, m)
    np.testing.assert_array_equal(np.asarray(y2)[0], np.asarray(y1))
    np.testing.assert_array_equal(np.asarray(y3)[0, 0], np.asarray(y1))


def test_lut_gemv_shape_validation():
    rng = np.random.default_rng(5)
    k, m = 512, 64
    w = jnp.asarray(rng.integers(-1, 2, size=(m, k)), jnp.int8)
    pw_tl1 = pack_ternary(w, jnp.float32(1.0), "tl1")
    pw_i2s = pack_ternary(w, jnp.float32(1.0), "i2s")
    x = jnp.asarray(rng.integers(-127, 128, size=(k,)), jnp.int8)
    with pytest.raises(ValueError, match="grouped ELUT format"):
        ops.lut_gemv(x, jnp.float32(1.0), pw_i2s, interpret=INTERPRET)
    with pytest.raises(ValueError, match="does not match"):
        ops.lut_gemv(x[: k // 2], jnp.float32(1.0), pw_tl1, interpret=INTERPRET)
    with pytest.raises(ValueError, match="scalar activation scale"):
        ops.lut_gemv(x, jnp.ones((4,), jnp.float32), pw_tl1, interpret=INTERPRET)


def test_lut_gemv_matches_algorithm3_literal():
    rng = np.random.default_rng(11)
    w = jnp.asarray(rng.integers(-1, 2, size=(16, 256)), jnp.int8)
    x_q = jnp.asarray(rng.integers(-127, 128, size=(256,)), jnp.int8)
    pw = pack_ternary(w, jnp.float32(1.0), "tl1")
    y = ops.lut_gemv(x_q, jnp.float32(1.0), pw, lossless=True, interpret=INTERPRET)
    np.testing.assert_array_equal(
        np.asarray(y, np.int64),
        ref.lut_gemv_naive(np.asarray(x_q), np.asarray(w)).astype(np.int64),
    )


@pytest.mark.parametrize("chunk", [32, 64])
@pytest.mark.parametrize("bh,L,p,s", [(2, 128, 16, 8), (4, 256, 32, 16)])
def test_ssd_scan_kernel(bh, L, p, s, chunk):
    keys = jax.random.split(jax.random.PRNGKey(bh * L), 4)
    a_log = -jnp.abs(jax.random.normal(keys[0], (bh, L))) * 0.1
    xbar = jax.random.normal(keys[1], (bh, L, p))
    b = jax.random.normal(keys[2], (bh, L, s)) * 0.3
    c = jax.random.normal(keys[3], (bh, L, s)) * 0.3
    y_k = ops.ssd_scan(a_log, xbar, b, c, chunk=chunk, interpret=INTERPRET)
    y_r = ref.ssd_sequential(a_log, xbar, b, c)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r), rtol=3e-4, atol=3e-4)
