"""Serving subsystem (DESIGN.md §7): paged KV, chunked prefill, scheduler.

The load-bearing claims, as executable assertions:

  * paged block-gather decode reproduces dense decode logits BIT-FOR-BIT at
    matched cache geometry (same gathered length as the dense padded width);
  * paged + chunked serving generates the same tokens as the dense
    token-by-token engine on greedy smoke runs, in every step-composition-
    invariant numerics mode (fp, and quantized with per-token act scales —
    per-TENSOR act quant ties logits to each step's batch composition, a
    property of the b1.58 scheme itself, not of the serving layer);
  * prefill chunks dispatch the GEMM/MAD regime while single-slot decode
    keeps the GEMV (``lut_gemv``) regime;
  * admission is gated on free KV blocks; preemption evicts, re-enqueues,
    and resumes losslessly; defrag is a pure relabeling;
  * empty prompts are rejected instead of crashing the tick loop.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import dispatch
from repro.core.bitlinear import QuantConfig
from repro.infer.engine import Engine, generate
from repro.models import lm
from repro.serve import (PagedKVConfig, Request, ServeConfig, ServeEngine,
                         Submission)
from repro.serve.kvcache import BlockAllocator
from repro.serve.scheduler import AdmissionScheduler

KEY = jax.random.PRNGKey(0)


def _cfg(**kw):
    quant = kw.pop("quant", QuantConfig(mode="quant", fmt="i2s", act="token"))
    return configs.smoke("qwen1.5-0.5b").replace(
        dtype="float32", quant=quant, **kw)


@pytest.fixture(scope="module")
def model():
    cfg = _cfg()
    return cfg, lm.init(KEY, cfg)


def _prompts(cfg, n, lo=5, hi=9):
    rng = np.random.default_rng(0)
    return [rng.integers(0, cfg.vocab, size=rng.integers(lo, hi)).tolist()
            for _ in range(n)]


def _serve(params, cfg, **kw):
    pack = kw.pop("pack", cfg.quant.mode == "quant")
    return ServeEngine(params, cfg, ServeConfig(**kw), pack=pack)


def _tokens(done):
    return {r.rid: r.out_tokens for r in done}


# ---------------------------------------------------------------------------
# Empty prompts (the legacy r.out_tokens[-1] IndexError)
# ---------------------------------------------------------------------------


def test_empty_prompt_rejected(model):
    cfg, params = model
    eng = Engine(params, cfg, batch_slots=2, max_seq=32)
    with pytest.raises(ValueError, match="empty prompt"):
        eng.submit(Request(rid=0, prompt=[]))
    # the engine stays usable afterwards
    eng.submit(Request(rid=1, prompt=[3, 4], max_new_tokens=2))
    assert len(eng.run()[0].out_tokens) == 2


# ---------------------------------------------------------------------------
# Paged numerics
# ---------------------------------------------------------------------------


def test_paged_decode_matches_dense_logits_bitexact(model):
    """Block-gather decode == dense decode, bit for bit, when the gathered
    length (L·block_size = 16·16) equals the dense padded width (256)."""
    cfg, params = model
    packed = lm.pack(params, cfg)
    dense = lm.init_state(cfg, 1, max_seq=255)           # padded to 256
    paged = lm.init_paged_state(cfg, 1, num_blocks=16, block_size=16)
    table = jnp.asarray(np.arange(16, dtype=np.int32)[None, :])
    toks = np.array([3, 141, 59, 265, 358, 97, 93], np.int32)
    for t, tok in enumerate(toks):
        tk = jnp.asarray([[tok]], jnp.int32)
        ps = jnp.asarray([t], jnp.int32)
        ld, dense = lm.decode_step(packed, tk, ps, cfg, dense)
        lp, paged = lm.decode_step(packed, tk, ps, cfg, paged, table=table)
        np.testing.assert_array_equal(np.asarray(ld), np.asarray(lp),
                                      err_msg=f"step {t}")


@pytest.mark.parametrize("quant", [
    QuantConfig(mode="fp"),
    QuantConfig(mode="quant", fmt="i2s", act="token"),
], ids=["fp", "i2s-act-token"])
def test_paged_chunked_tokens_match_dense_engine(quant):
    """The acceptance claim: paged + chunked serving emits the same greedy
    tokens as the dense token-by-token engine."""
    cfg = _cfg(quant=quant)
    params = lm.init(KEY, cfg)
    prompts = _prompts(cfg, 4)
    pack = quant.mode == "quant"
    eng = Engine(params, cfg, batch_slots=2, max_seq=64, pack=pack)
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=5))
    ref = _tokens(eng.run())
    se = _serve(params, cfg, batch_slots=2, max_seq=64, paged=True,
                block_size=8, prefill_chunk=4, pack=pack)
    for i, p in enumerate(prompts):
        se.submit(Request(rid=i, prompt=p, max_new_tokens=5))
    assert _tokens(se.run()) == ref


# ---------------------------------------------------------------------------
# Batched concurrent prefill (PR 4): one [S, C] call per tick at N = S·C
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("paged", [False, True], ids=["dense", "paged"])
def test_batched_prefill_tokens_match_sequential_mixed_occupancy(model, paged):
    """The tentpole acceptance claim: at act=token, batched concurrent
    prefill emits tokens BIT-IDENTICAL to sequential chunked prefill across
    mixed occupancy — prompts of different lengths (slots finish their
    chunk streams at different ticks), short final chunks (padded rows),
    and more requests than slots (admission waves leave padding rows)."""
    cfg, params = model
    prompts = _prompts(cfg, 5, lo=3, hi=12)   # lengths 3..11, chunk 4 →
    #                                           full AND partial final chunks

    def run(budget):
        se = _serve(params, cfg, batch_slots=3, max_seq=64, paged=paged,
                    block_size=8, prefill_chunk=4, prefill_budget=budget)
        for i, p in enumerate(prompts):
            se.submit(Request(rid=i, prompt=p, max_new_tokens=5))
        return _tokens(se.run())

    assert run(budget=12) == run(budget=0)    # 3 rows of 4 vs per-slot chunks


def test_batched_prefill_matches_sequential_recurrent_arch():
    """Padded final chunks must be IDENTITY steps for recurrent state and
    invisible to the conv-history carry (RG-LRU): batched tokens must equal
    sequential tokens on a recurrent-block architecture too."""
    cfg = configs.smoke("recurrentgemma-2b").replace(
        dtype="float32", quant=QuantConfig(mode="quant", fmt="i2s", act="token"))
    params = lm.init(KEY, cfg)
    prompts = _prompts(cfg, 3, lo=3, hi=10)

    def run(budget):
        se = _serve(params, cfg, batch_slots=2, max_seq=48, paged=True,
                    block_size=8, prefill_chunk=4, prefill_budget=budget)
        for i, p in enumerate(prompts):
            se.submit(Request(rid=i, prompt=p, max_new_tokens=4))
        return _tokens(se.run())

    assert run(budget=8) == run(budget=0)


def test_batched_prefill_dispatches_one_gemm_at_s_times_c():
    """The throughput mechanism: the batched tick's mpGEMM flattens to
    N = S·C (one call), not S calls at N = C — and the engine pins an exact
    autotune bucket for that batch."""
    cfg = _cfg(quant=QuantConfig(mode="quant", fmt="tl1"))
    params = lm.init(KEY, cfg)
    se = _serve(params, cfg, batch_slots=3, max_seq=32, paged=True,
                block_size=8, prefill_chunk=4, prefill_budget=12)
    for i in range(3):
        se.submit(Request(rid=i, prompt=[1 + i, 2, 3, 4, 5, 6, 7, 8],
                          max_new_tokens=2))
    se.run()
    gemm_ns = {d.n for d in se.kernel_decisions() if d.regime == "gemm"}
    assert 12 in gemm_ns, \
        f"batched prefill must flatten to N = S*C = 12, got {gemm_ns}"
    assert 4 not in gemm_ns, \
        "no per-slot N = C chunk call may survive in batched mode " \
        f"(got {gemm_ns}; N=3 is the batched decode tick)"
    assert dispatch.n_bucket(12) == 12, \
        "the batched tick's N = S*C must get its own autotune bucket"


def test_prefill_budget_zero_keeps_sequential_path(model):
    """Regression: prefill_budget=0 must stay trace-for-trace identical to
    the PR-2 sequential path — same jitted per-slot chunk callable, no
    batched machinery, and every prefill GEMM at N ≤ chunk (never S·C)."""
    cfg, params = model
    se = _serve(params, cfg, batch_slots=3, max_seq=64, paged=True,
                block_size=8, prefill_chunk=4)          # budget defaults to 0
    assert se._bchunk_fn is None
    from repro.serve.engine import _jitted_chunk
    # the obs jit-boundary wrapper (repro.obs.kernels) is identity-
    # transparent: underneath it must still be the SHARED lru-cached
    # per-(cfg, paged) callable, not a private re-jit
    assert se._chunk_fn.fn is _jitted_chunk(se.cfg, True), \
        "budget=0 must reuse the shared PR-2 per-slot chunk callable"
    for i, p in enumerate(_prompts(cfg, 3)):
        se.submit(Request(rid=i, prompt=p, max_new_tokens=3))
    se.run()
    # decisions log at TRACE time and the per-(cfg, paged) callables are
    # shared across engines, so a warm cache records nothing new — assert
    # only that nothing dispatched ABOVE the sequential shapes (chunk C=4
    # per slot, slots=3 for the batched decode tick): no stacked S·C call.
    gemm_ns = {d.n for d in se.kernel_decisions() if d.regime == "gemm"}
    assert all(n <= 4 for n in gemm_ns), \
        f"sequential prefill must dispatch at N <= chunk, got {gemm_ns}"


def test_prefill_budget_requires_chunking(model):
    cfg, params = model
    with pytest.raises(ValueError, match="prefill_budget"):
        _serve(params, cfg, batch_slots=2, max_seq=32,
               prefill_chunk=1, prefill_budget=8)


def test_prefill_row_packing_is_starvation_free():
    """Under a tight budget, rows go to the queue-order BEST submissions
    (priority desc, then arrival), not the lowest slot index — admission
    fills low slots first, so slot order would let every new arrival jump
    a half-prefilled request in a high slot forever."""
    from repro.serve.scheduler import plan_prefill_rows

    old = Submission(req=Request(rid=0, prompt=[1]))   # arrival 0
    new = Submission(req=Request(rid=1, prompt=[1]))
    old.arrival, new.arrival = 0, 7
    assert plan_prefill_rows([(0, new), (2, old)]) == [2, 0]
    urgent = Submission(req=Request(rid=2, prompt=[1]), priority=5)
    urgent.arrival = 9
    assert plan_prefill_rows([(0, new), (1, urgent), (2, old)]) == [1, 2, 0]


def test_prefill_budget_throttles_rows_per_tick(model):
    """A budget of ONE chunk serves one slot per tick (the others wait
    their turn) and still completes every request with identical tokens."""
    cfg, params = model
    prompts = _prompts(cfg, 3)

    def run(budget):
        se = _serve(params, cfg, batch_slots=3, max_seq=64, paged=True,
                    block_size=8, prefill_chunk=4, prefill_budget=budget)
        for i, p in enumerate(prompts):
            se.submit(Request(rid=i, prompt=p, max_new_tokens=3))
        return _tokens(se.run())

    assert run(budget=4) == run(budget=0)     # 1 row/tick, same tokens


# ---------------------------------------------------------------------------
# Dispatch regimes (PR 1 interaction)
# ---------------------------------------------------------------------------


def test_chunks_route_gemm_decode_routes_gemv():
    cfg = _cfg(quant=QuantConfig(mode="quant", fmt="tl1"))
    params = lm.init(KEY, cfg)
    se = _serve(params, cfg, batch_slots=1, max_seq=32, paged=True,
                block_size=8, prefill_chunk=4)
    se.submit(Request(rid=0, prompt=[1, 2, 3, 4, 5, 6, 7, 8], max_new_tokens=3))
    se.run()
    decs = se.kernel_decisions()
    chunk = [d for d in decs if d.regime == "gemm"]
    decode = [d for d in decs if d.regime == "gemv"]
    assert chunk and all(d.n == 4 and d.kernel != "lut_gemv" for d in chunk), \
        "prefill chunks must flatten to N=chunk and take the MAD/MXU kernels"
    assert decode and all(d.kernel == "lut_gemv" for d in decode), \
        "single-slot decode must keep the paper's true-LUT GEMV"


def test_chunk_size_gets_exact_autotune_bucket():
    dispatch.register_chunk_bucket(48)
    assert dispatch.n_bucket(48) == 48        # pinned: the shape that runs
    assert dispatch.n_bucket(47) == 64        # neighbours keep pow-2 buckets
    assert dispatch.n_bucket(1) == 1


# ---------------------------------------------------------------------------
# Scheduler: admission gating, ordering, preemption
# ---------------------------------------------------------------------------


def test_scheduler_priority_deadline_fifo_order():
    s = AdmissionScheduler()
    a = s.submit(Submission(req=Request(rid=0, prompt=[1])))
    b = s.submit(Submission(req=Request(rid=1, prompt=[1]), priority=2))
    c = s.submit(Submission(req=Request(rid=2, prompt=[1]), priority=2,
                            deadline=5.0))
    d = s.submit(Submission(req=Request(rid=3, prompt=[1])))
    order = [s.pop_best().req.rid for _ in range(4)]
    assert order == [2, 1, 0, 3]              # prio desc, deadline, FIFO
    assert not s.pending
    assert isinstance(s._q, __import__("collections").deque)


def test_admission_blocked_when_kv_blocks_exhausted(model):
    cfg, params = model
    # pool fits exactly one sequence: admission needs blocks_for(9 + 1) = 3,
    # and the first request takes all 3 of them
    se = _serve(params, cfg, batch_slots=2, max_seq=12, paged=True,
                block_size=4, kv_blocks=3, prefill_chunk=4)
    for i in range(2):
        se.submit(Request(rid=i, prompt=[5, 6, 7, 8, 9, 10, 11, 12, 13],
                          max_new_tokens=3))
    se.step()
    busy = [i for i, sl in enumerate(se.slots) if sl is not None]
    assert busy == [0], "second request must wait for free KV blocks"
    assert se.sched.pending
    done = se.run()                            # completes serially
    assert sorted(r.rid for r in done) == [0, 1]
    assert all(len(r.out_tokens) == 3 for r in done)
    waits = {m.rid: m.queue_wait for m in se.stats.finished}
    assert waits[1] > waits[0]


def test_preemption_reenqueue_roundtrips_tokens_losslessly(model):
    cfg, params = model

    def baseline(rid, prompt, max_new):
        se = _serve(params, cfg, batch_slots=2, max_seq=16, paged=True,
                    block_size=4, kv_blocks=4, prefill_chunk=4)
        se.submit(Request(rid=rid, prompt=prompt, max_new_tokens=max_new))
        return se.run()[0].out_tokens

    # A holds 2 of 4 blocks when B arrives; B needs blocks_for(11 + 1) = 3,
    # which only fits after evicting A — admission-driven preemption.
    pa, pb = [5, 6, 7, 8, 9], [11, 12, 13, 14, 15, 16, 17, 18, 19, 20, 21]
    ref_a, ref_b = baseline(0, pa, 8), baseline(1, pb, 3)

    se = _serve(params, cfg, batch_slots=2, max_seq=16, paged=True,
                block_size=4, kv_blocks=4, prefill_chunk=4)
    se.submit(Request(rid=0, prompt=pa, max_new_tokens=8))
    for _ in range(4):                         # A prefills + decodes a bit
        se.step()
    assert se.slots[0] is not None and se.slots[0].sub.req.out_tokens
    se.submit(Request(rid=1, prompt=pb, max_new_tokens=3), priority=5)
    done = _tokens(se.run())
    ms = {m.rid: m for m in se.stats.finished}
    assert ms[0].n_preemptions >= 1, "low-priority request was never evicted"
    assert done[1] == ref_b, "high-priority request altered by preemption"
    assert done[0] == ref_a, "evicted request must resume losslessly"


def _assert_trash_clean(se):
    """The trash block's pos rows must stay −1 at all times: one real
    position written there is attendable by EVERY slot (all table tails
    point at trash), poisoning unrelated sequences' logits."""
    for st in list(se.state["scan"]) + list(se.state["rest"]):
        if st is not None and isinstance(st, dict) and "pos" in st:
            trash_pos = np.asarray(st["pos"])[..., -1, :]   # last block rows
            assert (trash_pos == -1).all(), "trash pos invariant violated"


def test_mid_tick_growth_preemption_drops_staged_victim(model):
    """A slot growing its allocation mid-decode-tick may evict a LOWER-slot
    sequence that was already staged into the batched step; the tick must
    drop the evictee (not crash), must not write the evictee's position into
    the trash block, and both requests must still complete."""
    cfg, params = model
    pa, pb = [1, 2, 3, 4], [4, 5, 6]

    def solo(rid, prompt, max_new):
        se = _serve(params, cfg, batch_slots=2, max_seq=12, paged=True,
                    block_size=4, kv_blocks=3, prefill_chunk=1)
        se.submit(Request(rid=rid, prompt=prompt, max_new_tokens=max_new))
        return se.run()[0].out_tokens

    ref_a, ref_b = solo(0, pa, 6), solo(1, pb, 4)
    se = _serve(params, cfg, batch_slots=2, max_seq=12, paged=True,
                block_size=4, kv_blocks=3, prefill_chunk=1)
    # stagger admissions so the victim's cursor at eviction is NOT a block
    # multiple — a trash write at offset 0 would be masked by the next
    # paused-slot write; off-multiple offsets persist and must never happen
    se.submit(Request(rid=0, prompt=pa, max_new_tokens=6))           # slot 0
    se.step()
    _assert_trash_clean(se)
    se.submit(Request(rid=1, prompt=pb, max_new_tokens=4), priority=5)
    done = []
    while se.sched.pending or any(s is not None for s in se.slots):
        done.extend(se.step())                                        # no crash
        _assert_trash_clean(se)   # per-tick: catches transient pollution too
    done = _tokens(done)
    assert done[1] == ref_b
    assert done[0] == ref_a, "staged-then-evicted request must resume losslessly"
    assert {m.rid: m.n_preemptions for m in se.stats.finished}[0] >= 1


def test_stall_error_names_blocked_slots_and_block_demand(model):
    """The stall detector must diagnose, not just die: the error names each
    blocked slot (rid, phase, position), its outstanding KV-block demand,
    and the pool's free count, so the operator knows WHAT to resize."""
    cfg, params = model
    # pool of 2 blocks admits the request (history 7 + 1 → 2 blocks) but can
    # never grow to position 9; preemption off → nothing evictable → stall
    se = _serve(params, cfg, batch_slots=1, max_seq=16, paged=True,
                block_size=4, kv_blocks=2, prefill_chunk=4, preemption=False)
    se.submit(Request(rid=7, prompt=[1, 2, 3, 4, 5, 6, 7], max_new_tokens=8))
    with pytest.raises(RuntimeError) as ei:
        se.run()
    msg = str(ei.value)
    assert "slot 0" in msg and "rid 7" in msg, msg
    assert "1 more KV block" in msg, msg
    assert "0 of 2 KV blocks free" in msg, msg
    assert "preemption=False" in msg, msg


def test_overlong_prompt_rejected(model):
    cfg, params = model
    se = _serve(params, cfg, batch_slots=1, max_seq=16, paged=True,
                block_size=4, prefill_chunk=4)
    with pytest.raises(ValueError, match="cannot fit max_seq"):
        se.submit(Request(rid=0, prompt=list(range(16)), max_new_tokens=2))
    se.submit(Request(rid=1, prompt=list(range(15)), max_new_tokens=2))
    assert len(se.run()) == 1                 # boundary-length prompt serves


def test_explicit_preempt_slot_resumes_losslessly(model):
    cfg, params = model
    prompt = [3, 1, 4, 1, 5]
    base = _serve(params, cfg, batch_slots=1, max_seq=32, paged=True,
                  block_size=8, prefill_chunk=4)
    base.submit(Request(rid=0, prompt=prompt, max_new_tokens=6))
    ref = base.run()[0].out_tokens
    se = _serve(params, cfg, batch_slots=1, max_seq=32, paged=True,
                block_size=8, prefill_chunk=4)
    se.submit(Request(rid=0, prompt=prompt, max_new_tokens=6))
    for _ in range(3):
        se.step()
    se.preempt_slot(0)
    assert se.slots[0] is None and se.allocator.free_count == se.pcfg.num_blocks
    assert se.run()[0].out_tokens == ref


# ---------------------------------------------------------------------------
# Block allocator + defrag
# ---------------------------------------------------------------------------


def test_block_allocator_alloc_free_compact():
    pcfg = PagedKVConfig(block_size=8, num_blocks=8, max_blocks_per_seq=4)
    al = BlockAllocator(pcfg)
    a = al.alloc(0, 3)
    b = al.alloc(1, 2)
    assert len(set(a + b)) == 5 and al.free_count == 3
    assert al.alloc(2, 4) is None and al.free_count == 3  # all-or-nothing
    al.release(0)
    assert al.free_count == 6
    src, remap = al.compact()
    assert al.owned(1) == [0, 1]               # packed to the front, in order
    assert [src[i] for i in range(2)] == b     # gather sources = old ids
    assert [remap[x] for x in b] == [0, 1]
    assert sorted(src.tolist()) == list(range(pcfg.num_blocks + 1))
    assert src[pcfg.num_blocks] == pcfg.num_blocks  # trash never moves


def test_defrag_preserves_generation(model):
    cfg, params = model
    prompts = _prompts(cfg, 3)

    def run(defrag_at):
        se = _serve(params, cfg, batch_slots=2, max_seq=48, paged=True,
                    block_size=8, prefill_chunk=4)
        for i, p in enumerate(prompts):
            se.submit(Request(rid=i, prompt=p, max_new_tokens=4))
        done, tick = [], 0
        while se.sched.pending or any(s is not None for s in se.slots):
            done.extend(se.step())
            tick += 1
            if tick == defrag_at:
                se.defrag()
        return _tokens(done)

    assert run(defrag_at=10**9) == run(defrag_at=4)


# ---------------------------------------------------------------------------
# Batched sampling + telemetry
# ---------------------------------------------------------------------------


def test_temperature_sampling_batched(model):
    cfg, params = model
    se = _serve(params, cfg, batch_slots=2, max_seq=32, paged=True,
                block_size=8, prefill_chunk=4)
    for i in range(3):
        se.submit(Request(rid=i, prompt=[2 + i, 3, 4], max_new_tokens=4,
                          temperature=0.8))
    done = se.run()
    assert len(done) == 3
    for r in done:
        assert len(r.out_tokens) == 4
        assert all(0 <= t < cfg.padded_vocab for t in r.out_tokens)


def test_request_telemetry_populated(model):
    cfg, params = model
    se = _serve(params, cfg, batch_slots=2, max_seq=32, paged=True,
                block_size=8, prefill_chunk=4)
    for i, p in enumerate(_prompts(cfg, 3)):
        se.submit(Request(rid=i, prompt=p, max_new_tokens=3))
    se.run()
    summ = se.metrics_summary()
    assert summ["requests"] == 3 and summ["generated_tokens"] == 9
    assert summ["throughput_tok_s"] and summ["throughput_tok_s"] > 0
    assert summ["ttft_p50"] is not None and summ["ttft_p95"] >= summ["ttft_p50"]
    assert summ["kv_blocks_free"] == summ["kv_blocks"]  # all released
    for m in se.stats.finished:
        assert m.ttft is not None and m.queue_wait is not None
        assert m.n_prefill_chunks >= 1


def test_generate_facade_unchanged(model):
    """The legacy convenience wrapper still round-trips prompt batches."""
    cfg, params = model
    outs = generate(params, cfg, [[5, 7, 9], [3, 1]], max_new_tokens=3,
                    batch_slots=2, max_seq=32)
    assert [len(o) for o in outs] == [3, 3]


# ---------------------------------------------------------------------------
# Grouped weight scales through the serving stack (ISSUE 5)
# ---------------------------------------------------------------------------


def _grouped_cfg():
    """Smoke config with every BitLinear K a multiple of G=128."""
    return configs.smoke("qwen1.5-0.5b").replace(
        dtype="float32", d_model=256, d_head=64, d_ff=384,
        quant=QuantConfig(mode="quant", fmt="int2_g128", act="token"))


def _dequantized_params(params, fmt):
    """fp params whose BitLinear weights are the EXACT dequantized grouped
    codes (codes · per-group scales) — the oracle model the grouped engine
    must reproduce."""
    from repro.core import bitlinear, formats, packing

    spec = formats.get(fmt)

    def dq(w):
        if w.ndim > 2:
            return jax.vmap(dq)(w)
        codes, sc = spec.quantize(w)
        return codes.astype(jnp.float32) * packing.expand_group_scales(
            sc, w.shape[1])

    return jax.tree_util.tree_map(
        lambda p: bitlinear.BitLinearParams(w=dq(p.w), b=p.b)
        if bitlinear.is_bitlinear(p) else p,
        params, is_leaf=bitlinear.is_bitlinear)


def test_grouped_serve_paged_batched_matches_dense_and_dequant():
    """ServeEngine smoke on a grouped-int2 config: paged + batched
    concurrent prefill emits the same greedy tokens as (1) the dense
    sequential engine on the same grouped weights — exact, act=token is
    step-composition-invariant — and (2) the dense run of the SAME
    dequantized weights in fp (the losslessness claim at token level)."""
    cfg = _grouped_cfg()
    params = lm.init(KEY, cfg)
    prompts = _prompts(cfg, 3)

    se = _serve(params, cfg, batch_slots=2, max_seq=64, paged=True,
                block_size=8, prefill_chunk=4, prefill_budget=8)
    eng = Engine(params, cfg, batch_slots=2, max_seq=64, pack=True)
    cfg_fp = cfg.replace(quant=QuantConfig(mode="fp"))
    eng_fp = Engine(_dequantized_params(params, "int2_g128"), cfg_fp,
                    batch_slots=2, max_seq=64, pack=False)
    for e in (se, eng, eng_fp):
        for i, p in enumerate(prompts):
            e.submit(Request(rid=i, prompt=p, max_new_tokens=4))
    toks = _tokens(se.run())
    assert toks == _tokens(eng.run())
    assert toks == _tokens(eng_fp.run())


def test_grouped_single_slot_decode_routes_lut_gemv():
    """Single-slot decode on a grouped format keeps the paper's true-LUT
    GEMV regime — the grouped scale plane rides the kernel, not a fallback."""
    cfg = _grouped_cfg()
    params = lm.init(KEY, cfg)
    eng = Engine(params, cfg, batch_slots=1, max_seq=32)
    eng.submit(Request(rid=0, prompt=[3, 1, 4], max_new_tokens=3))
    done = eng.run()
    assert len(done) == 1 and len(done[0].out_tokens) == 3
    gemv = [d for d in eng.kernel_decisions() if d.regime == "gemv"]
    assert gemv and all(d.kernel == "lut_gemv" for d in gemv)
    assert all(d.fmt == "int2_g128" for d in gemv)


def test_grouped_packed_checkpoint_roundtrip_serves(tmp_path):
    """A packed grouped checkpoint (codes + [K//G, M] scale planes) saves,
    restores, and serves end to end with identical tokens."""
    from repro.ckpt import store

    cfg = _grouped_cfg()
    params = lm.init(KEY, cfg)
    packed = lm.pack(params, cfg)
    store.save(packed, str(tmp_path), 0)
    restored, _ = store.restore(packed, str(tmp_path), 0)
    prompts = _prompts(cfg, 2)
    outs = {}
    for tag, tree in (("orig", packed), ("restored", restored)):
        eng = Engine(tree, cfg, batch_slots=2, max_seq=48, pack=False)
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=p, max_new_tokens=4))
        outs[tag] = _tokens(eng.run())
    assert outs["orig"] == outs["restored"]
