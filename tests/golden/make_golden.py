"""Regenerate the per-tensor golden fixtures (tests/test_regression_golden.py).

The committed ``golden_per_tensor.json`` was produced by running this script
at the commit IMMEDIATELY BEFORE grouped weight scales landed — it pins the
``group_scale_cols=None`` path (packed bytes, absmean scales, mpGEMM outputs,
smoke-model logits) to the pre-grouped-scales numerics, bit for bit.  Only
rerun it if a deliberate, reviewed numeric change to the per-tensor path is
being made; the diff of the fixture IS the numeric diff under review.

    PYTHONPATH=src python tests/golden/make_golden.py
"""

from __future__ import annotations

import base64
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

FIXTURE = os.path.join(os.path.dirname(__file__), "golden_per_tensor.json")
FMTS = ("i2s", "tl1", "tq1")
M, K = 8, 256
SEED = 20260731


def b64(a: np.ndarray) -> str:
    return base64.b64encode(np.ascontiguousarray(a).tobytes()).decode()


def main() -> None:
    from repro import configs
    from repro.core import dispatch, formats
    from repro.core.bitlinear import QuantConfig
    from repro.core.dispatch import KernelPlan
    from repro.core.qtensor import pack_weight
    from repro.models import lm

    rng = np.random.default_rng(SEED)
    w_fp = jnp.asarray(rng.normal(size=(M, K)), jnp.float32)
    x_q1 = jnp.asarray(rng.integers(-127, 128, size=(1, K)), jnp.int8)
    x_q3 = jnp.asarray(rng.integers(-127, 128, size=(3, K)), jnp.int8)
    s_x = jnp.float32(0.0123)

    blob: dict = {"seed": SEED, "m": M, "k": K, "formats": {}}
    for fmt in FMTS:
        pw = pack_weight(w_fp, fmt)
        entry = {
            "scale": float(np.asarray(pw.scale, np.float32)),
            "scale_hex": np.asarray(pw.scale, np.float32).tobytes().hex(),
            "planes": {name: {"shape": list(p.shape),
                              "dtype": str(np.asarray(p).dtype),
                              "b64": b64(np.asarray(p))}
                       for name, p in pw.planes.items()},
        }
        # dispatch through the canonical XLA reference kernel: int32
        # accumulation + one elementwise fp32 rescale — platform-stable bytes.
        for tag, x_q in (("gemv", x_q1), ("gemm", x_q3)):
            y = dispatch.mpgemm(x_q, s_x, pw, KernelPlan(gemv="xla", gemm="xla"))
            entry[f"y_{tag}_b64"] = b64(np.asarray(y, np.float32))
        blob["formats"][fmt] = entry

    # smoke-model logits per format (float32 end to end, greedy determinism)
    tokens = jnp.asarray(rng.integers(0, 512, size=(1, 8)), jnp.int32)
    blob["tokens"] = np.asarray(tokens).tolist()
    for fmt in FMTS:
        cfg = configs.smoke("qwen1.5-0.5b").replace(
            dtype="float32",
            quant=QuantConfig(mode="quant", fmt=fmt, act="tensor"))
        params = lm.pack(lm.init(jax.random.PRNGKey(0), cfg), cfg)
        logits, _ = lm.forward(params, {"tokens": tokens}, cfg)
        blob["formats"][fmt]["logits_b64"] = b64(np.asarray(logits, np.float32))
        blob["formats"][fmt]["logits_shape"] = list(logits.shape)

    with open(FIXTURE, "w") as f:
        json.dump(blob, f, indent=1, sort_keys=True)
    print(f"wrote {FIXTURE}")


if __name__ == "__main__":
    main()
