"""Distributed substrate tests: checkpointing, fault tolerance, sharding rules.

Multi-device sharding behaviour is exercised in a subprocess with 8 fake host
devices so the main pytest process keeps the default 1-device jax config
(the dry-run, and only the dry-run, uses 512).
"""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.ckpt import store
from repro.data.pipeline import DataConfig, DataIterator
from repro.distributed import fault
from repro.models import lm
from repro.train import loop as train_loop


def _tiny():
    cfg = configs.smoke("qwen1.5-0.5b").replace(dtype="float32")
    tcfg = train_loop.TrainConfig(opt=train_loop.opt.OptConfig(lr=1e-3, warmup_steps=2, total_steps=50))
    return cfg, tcfg


# ---------------------------------------------------------------------------
# Checkpoint store
# ---------------------------------------------------------------------------


def test_ckpt_roundtrip_and_crc(tmp_path):
    cfg, tcfg = _tiny()
    state = train_loop.init_train_state(jax.random.PRNGKey(0), cfg, tcfg)
    path = store.save(state, str(tmp_path), 7, extra={"data_step": 3})
    assert path.endswith("step_7")
    restored, extra = store.restore(state, str(tmp_path), 7)
    assert extra["data_step"] == 3
    for a, b in zip(jax.tree_util.tree_leaves(state), jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_ckpt_corruption_detected(tmp_path):
    cfg, tcfg = _tiny()
    state = train_loop.init_train_state(jax.random.PRNGKey(0), cfg, tcfg)
    path = store.save(state, str(tmp_path), 1)
    # flip bytes in one leaf
    victim = os.path.join(path, "leaf_3.npy")
    raw = bytearray(open(victim, "rb").read())
    raw[-1] ^= 0xFF
    open(victim, "wb").write(bytes(raw))
    with pytest.raises(IOError, match="crc"):
        store.restore(state, str(tmp_path), 1)


def test_ckpt_atomicity_and_gc(tmp_path):
    cfg, tcfg = _tiny()
    state = train_loop.init_train_state(jax.random.PRNGKey(0), cfg, tcfg)
    for s in (1, 2, 3, 4):
        store.save(state, str(tmp_path), s, keep_last_k=2)
    assert store.available_steps(str(tmp_path)) == [3, 4]
    assert not any(n.endswith(".tmp") for n in os.listdir(tmp_path))


def test_ckpt_async_saver(tmp_path):
    cfg, tcfg = _tiny()
    state = train_loop.init_train_state(jax.random.PRNGKey(0), cfg, tcfg)
    saver = store.AsyncSaver()
    saver.save(state, str(tmp_path), 5)
    saver.wait()
    assert store.latest_step(str(tmp_path)) == 5


def test_ckpt_packed_weights_roundtrip(tmp_path):
    """Packed inference params (uint8 planes, int4) survive the store."""
    cfg, _ = _tiny()
    from repro.core.bitlinear import QuantConfig

    cfg = cfg.replace(quant=QuantConfig(mode="quant", fmt="tl2k"))
    params = lm.pack(lm.init(jax.random.PRNGKey(0), cfg), cfg)
    store.save(params, str(tmp_path), 0)
    restored, _ = store.restore(params, str(tmp_path), 0)
    for a, b in zip(jax.tree_util.tree_leaves(params), jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# Fault tolerance: replay-exact restart
# ---------------------------------------------------------------------------


def test_resilient_runner_replay_exact(tmp_path):
    cfg, tcfg = _tiny()
    dc = DataConfig(vocab=cfg.vocab, seq_len=16, global_batch=4)
    step_fn = jax.jit(train_loop.make_train_step(cfg, tcfg))

    def run(fail_at):
        state = train_loop.init_train_state(jax.random.PRNGKey(0), cfg, tcfg)
        runner = fault.ResilientRunner(
            step_fn, str(tmp_path / f"ckpt_{len(fail_at)}"), ckpt_every=4,
            fault_hook=fault.FaultInjector(fail_at), async_save=False)
        return runner.run(state, DataIterator(dc), 12)

    state_clean, hist_clean = run(set())
    state_faulty, hist_faulty = run({6, 13})  # two injected failures

    losses_clean = [float(m["loss"]) for m in hist_clean]
    losses_faulty = [float(m["loss"]) for m in hist_faulty]
    assert losses_clean == pytest.approx(losses_faulty, rel=1e-6)
    for a, b in zip(jax.tree_util.tree_leaves(state_clean["params"]),
                    jax.tree_util.tree_leaves(state_faulty["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-7)


def test_resilient_runner_gives_up_after_max_restarts(tmp_path):
    cfg, tcfg = _tiny()
    dc = DataConfig(vocab=cfg.vocab, seq_len=16, global_batch=4)
    step_fn = jax.jit(train_loop.make_train_step(cfg, tcfg))
    state = train_loop.init_train_state(jax.random.PRNGKey(0), cfg, tcfg)
    runner = fault.ResilientRunner(
        step_fn, str(tmp_path / "c"), ckpt_every=2, max_restarts=2,
        fault_hook=fault.FaultInjector({1, 2, 3, 4, 5, 6}), async_save=False)
    with pytest.raises(fault.InjectedFault):
        runner.run(state, DataIterator(dc), 8)


def test_straggler_policy():
    p = fault.StragglerPolicy(timeout_factor=2.0, window=8)
    for i in range(8):
        assert not p.observe(i, 0.1)
    assert p.observe(8, 0.5)       # 5× the median → flagged
    assert not p.observe(9, 0.11)
    assert len(p.events) == 1


# ---------------------------------------------------------------------------
# Data pipeline determinism / elasticity
# ---------------------------------------------------------------------------


def test_data_deterministic_and_checkpointable():
    dc = DataConfig(vocab=512, seq_len=8, global_batch=4)
    it = DataIterator(dc)
    first = [next(it) for _ in range(3)]
    ck = it.checkpoint()
    nxt = next(it)
    it2 = DataIterator.restore(dc, ck)
    np.testing.assert_array_equal(np.asarray(next(it2)["tokens"]), np.asarray(nxt["tokens"]))


def test_data_host_sharding_is_a_partition():
    dc = DataConfig(vocab=512, seq_len=8, global_batch=4)
    full = DataIterator(dc)
    h0 = DataIterator(DataConfig(vocab=512, seq_len=8, global_batch=4, n_hosts=2, host_id=0))
    h1 = DataIterator(DataConfig(vocab=512, seq_len=8, global_batch=4, n_hosts=2, host_id=1))
    f, a, b = next(full), next(h0), next(h1)
    np.testing.assert_array_equal(np.asarray(f["tokens"]),
                                  np.concatenate([a["tokens"], b["tokens"]]))


# ---------------------------------------------------------------------------
# Sharding rules (pure logic) + multi-device subprocess integration
# ---------------------------------------------------------------------------


def test_param_specs_modes():
    from repro.distributed import sharding as shd

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    w2 = jnp.zeros((64, 32))
    # live params: TP only in both modes
    assert shd.param_spec(["stack", "scan", "q", "w"], w2, mesh, "infer")[1] == "model"
    spec_t = shd.param_spec(["params", "stack", "scan", "q", "w"], w2, mesh, "train")
    assert spec_t[1] == "model"
    # optimizer master: FSDP in train mode
    spec_m = shd.param_spec(["opt", "master", "stack", "scan", "q", "w"], w2, mesh, "train")
    assert spec_m[1] == ("data", "model")
    # norms replicate
    assert shd.param_spec(["ln1", "w"], jnp.zeros((64,)), mesh, "train") == jax.sharding.PartitionSpec(None,)


def test_sharded_train_step_subprocess():
    """8 fake devices: pjit train step with the production sharding rules."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from repro import configs
        from repro.models import lm
        from repro.train import loop as train_loop
        from repro.distributed import sharding
        from repro.data.pipeline import DataConfig, DataIterator

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        sharding.set_mesh(mesh)  # version-compat shim (jax.set_mesh is 0.5+)
        cfg = configs.smoke("qwen1.5-0.5b").replace(
            dtype="float32", d_model=192, n_heads=4, n_kv_heads=4, d_head=48,
            act_shard=(("data",), None, None))
        tcfg = train_loop.TrainConfig(grad_spec="fsdp", microbatches=2)
        state = train_loop.init_train_state(jax.random.PRNGKey(0), cfg, tcfg)
        sh = sharding.shard_params(state, mesh, "train")
        state = jax.device_put(state, sh)
        dc = DataConfig(vocab=cfg.vocab, seq_len=16, global_batch=4)
        it = DataIterator(dc)
        bsh = sharding.shard_batch(next(DataIterator(dc)), mesh)
        step = jax.jit(train_loop.make_train_step(cfg, tcfg),
                       in_shardings=(sh, bsh), out_shardings=(sh, None))
        for i in range(4):
            state, m = step(state, jax.device_put(next(it), bsh))
        print("LOSS", float(m["loss"]))
        assert np.isfinite(float(m["loss"]))
        # unsharded reference: same numbers on 1 logical device config
        print("OK")
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True, text=True,
                       env={**os.environ, "PYTHONPATH": "src"}, cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout


def test_sharded_decode_subprocess():
    """8 fake devices: pjit serve_step with state shardings + int8 KV."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from repro import configs
        from repro.models import lm
        from repro.distributed import sharding
        from repro.core.bitlinear import QuantConfig

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        sharding.set_mesh(mesh)  # version-compat shim (jax.set_mesh is 0.5+)
        cfg = configs.smoke("qwen1.5-0.5b").replace(
            dtype="float32", d_model=192, n_heads=4, n_kv_heads=4, d_head=48,
            quant=QuantConfig(mode="quant", fmt="i2s"))
        params = lm.pack(lm.init(jax.random.PRNGKey(0), cfg), cfg)
        params = jax.device_put(params, sharding.shard_params(params, mesh, "infer"))
        state = lm.init_state(cfg, 8, 32)
        st_sh = sharding.shard_state(state, mesh, batch=8)
        state = jax.device_put(state, st_sh)
        tok = jnp.ones((8, 1), jnp.int32)
        step = jax.jit(lambda p, t, pos, s: lm.decode_step(p, t, pos, cfg, s),
                       in_shardings=(None, None, None, st_sh), out_shardings=(None, st_sh))
        logits, state = step(params, tok, jnp.int32(0), state)
        logits, state = step(params, tok, jnp.int32(1), state)
        assert np.isfinite(np.asarray(logits)).all()
        print("OK")
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True, text=True,
                       env={**os.environ, "PYTHONPATH": "src"}, cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout


def test_elastic_restore_across_mesh_shapes():
    """Checkpoint written unsharded restores onto a 4-device mesh (and back)."""
    code = textwrap.dedent("""
        import os, tempfile
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from repro import configs
        from repro.models import lm
        from repro.train import loop as train_loop
        from repro.distributed import sharding
        from repro.ckpt import store

        cfg = configs.smoke("qwen1.5-0.5b").replace(dtype="float32")
        tcfg = train_loop.TrainConfig()
        state = train_loop.init_train_state(jax.random.PRNGKey(0), cfg, tcfg)
        d = tempfile.mkdtemp()
        store.save(state, d, 0)
        # restore onto a (2,4) mesh
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        sh = sharding.shard_params(state, mesh, "train")
        restored, _ = store.restore(state, d, 0, shardings=sh)
        leaf = jax.tree_util.tree_leaves(restored)[3]
        assert len(leaf.sharding.device_set) >= 1
        for a, b in zip(jax.tree_util.tree_leaves(state), jax.tree_util.tree_leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        print("OK")
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True, text=True,
                       env={**os.environ, "PYTHONPATH": "src"}, cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout
