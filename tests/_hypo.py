"""``hypothesis`` or a tiny stub: property tests degrade to fixed-seed sweeps.

The container image does not always ship ``hypothesis``; tier-1 collection
must not depend on it.  When the real library is available we use it
unchanged.  Otherwise ``@given`` runs the test body over a small number of
deterministically sampled examples (seeded RNG, capped at 5 per test), and
``@settings`` only caps that count — enough to keep the properties exercised
everywhere while CI with the real dependency gets the full search.
"""

try:  # pragma: no cover - exercised implicitly by whichever env runs
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    import random

    HAVE_HYPOTHESIS = False
    _STUB_EXAMPLES = 5  # fixed-seed examples per property when stubbed

    class _Strategy:
        def __init__(self, sample):
            self._sample = sample

        def sample(self, rng: random.Random):
            return self._sample(rng)

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def sampled_from(elements):
            elems = list(elements)
            return _Strategy(lambda rng: elems[rng.randrange(len(elems))])

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.getrandbits(1)))

    st = _Strategies()

    def settings(max_examples: int = 10, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    def given(**strategies):
        def deco(fn):
            def run(*args, **kwargs):
                n = min(getattr(run, "_max_examples", 10), _STUB_EXAMPLES)
                rng = random.Random(0xB17)
                for _ in range(n):
                    drawn = {k: s.sample(rng) for k, s in strategies.items()}
                    fn(*args, **drawn, **kwargs)

            # NOTE: no functools.wraps — pytest must see the (*args, **kwargs)
            # signature, not the wrapped one, or it would demand fixtures named
            # after the strategy kwargs.
            run.__name__ = fn.__name__
            run.__doc__ = fn.__doc__
            run.__module__ = fn.__module__
            return run

        return deco
