"""Speculative decoding (DESIGN.md §10): draft/verify/accept/rollback.

The load-bearing claims, as executable assertions:

  * the [B, k+1] verify forward scores the SAME greedy token per position
    as sequential decode steps over those positions (the acceptance rule's
    foundation);
  * greedy speculative serving is bit-identical to the non-speculative
    engine — dense and paged, self-drafted and independently drafted (a
    disagreeing draft exercises rejection + KV rollback and the output
    STILL cannot change);
  * rollback-as-truncation leaves block tables, refcounts and the prefix
    trie consistent: pools drain back to full after a run, rejected-draft
    blocks never reach the trie, and a mid-run defrag survives;
  * k=0 IS the plain engine: trace-for-trace — zero new dispatch decisions
    against an already-traced config — not merely token-identical;
  * the guard rails refuse per-tensor activation quant, recurrent stacks,
    and a dangling draft model;
  * the verify batch rides the GEMM regime at exactly N = B·(k+1);
  * admission accounts for the draft pool.
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro import configs
from repro.core import dispatch
from repro.core.bitlinear import QuantConfig
from repro.models import lm
from repro.serve import Request, ServeConfig, ServeEngine, Submission
from repro.serve import spec as spec_mod
from repro.serve.scheduler import AdmissionScheduler

KEY = jax.random.PRNGKey(0)


def _cfg(**kw):
    quant = kw.pop("quant", QuantConfig(mode="quant", fmt="i2s", act="token"))
    return configs.smoke("qwen1.5-0.5b").replace(
        dtype="float32", quant=quant, **kw)


@pytest.fixture(scope="module")
def model():
    cfg = _cfg()
    return cfg, lm.init(KEY, cfg)


@pytest.fixture(scope="module")
def indep_draft(model):
    cfg, _ = model
    raw = lm.init(jax.random.PRNGKey(7), cfg)  # disagrees with the target
    return spec_mod.make_draft(raw, cfg, label="indep")


def _prompts(cfg, n, lo=5, hi=9):
    rng = np.random.default_rng(0)
    return [rng.integers(0, cfg.vocab, size=rng.integers(lo, hi)).tolist()
            for _ in range(n)]


def _run(params, cfg, scfg, prompts, max_new=8, **kw):
    eng = ServeEngine(params, cfg, scfg, seed=0, **kw)
    for rid, p in enumerate(prompts):
        eng.submit(Request(rid=rid, prompt=p, max_new_tokens=max_new))
    done = eng.run()
    return {r.rid: list(r.out_tokens) for r in done}, eng


PAGED = dict(batch_slots=2, max_seq=64, paged=True, block_size=8,
             prefill_chunk=4)
DENSE = dict(batch_slots=2, max_seq=64, paged=False)


# ---------------------------------------------------------------------------
# Verify forward == sequential decode (model level)
# ---------------------------------------------------------------------------


def test_verify_matches_sequential_decode(model):
    cfg, raw = model
    params = lm.pack(raw, cfg)
    state = lm.init_state(cfg, 1, 32)
    toks = [3, 7, 11, 2, 9, 4]
    seq_logits = []
    for p, t in enumerate(toks):
        lg, state = lm.decode_step(
            params, np.asarray([[t]], np.int32), np.asarray([p], np.int32),
            cfg, state)
        seq_logits.append(np.asarray(lg[0, 0]))
    vstate = lm.init_state(cfg, 1, 32)
    vlog, _ = lm.verify_chunk_batched(
        params, np.asarray([toks], np.int32),
        np.asarray([list(range(len(toks)))], np.int32), cfg, vstate)
    vlog = np.asarray(vlog[0])
    for p in range(len(toks)):
        assert int(np.argmax(vlog[p])) == int(np.argmax(seq_logits[p])), p
    np.testing.assert_allclose(vlog, np.stack(seq_logits),
                               rtol=2e-5, atol=2e-5)


def test_verify_padding_rows_inert(model):
    """A pos = −1 row neither contributes logits that matter nor corrupts
    the cache of a live row (the idle-slot contract of the verify tick)."""
    cfg, raw = model
    params = lm.pack(raw, cfg)
    state = lm.init_state(cfg, 2, 32)
    toks = np.asarray([[3, 7, 11], [0, 0, 0]], np.int32)
    pos = np.asarray([[0, 1, 2], [-1, -1, -1]], np.int32)
    vlog, state = lm.verify_chunk_batched(params, toks, pos, cfg, state)
    lg, _ = lm.decode_step(params, np.asarray([[2], [0]], np.int32),
                           np.asarray([3, -1], np.int32), cfg, state)
    solo = lm.init_state(cfg, 2, 32)
    for p, t in enumerate([3, 7, 11]):
        ref, solo = lm.decode_step(
            params, np.asarray([[t], [0]], np.int32),
            np.asarray([p, -1], np.int32), cfg, solo)
    ref, _ = lm.decode_step(params, np.asarray([[2], [0]], np.int32),
                            np.asarray([3, -1], np.int32), cfg, solo)
    assert int(np.argmax(np.asarray(lg[0, 0]))) == \
        int(np.argmax(np.asarray(ref[0, 0])))


# ---------------------------------------------------------------------------
# Bit-identity: spec on == spec off (the tentpole contract)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("base_kw", [PAGED, DENSE],
                         ids=["paged", "dense"])
def test_spec_identity_self_draft(model, base_kw):
    cfg, params = model
    prompts = _prompts(cfg, 4)
    plain, _ = _run(params, cfg, ServeConfig(**base_kw), prompts)
    for k in (1, 2, 3):
        spec, eng = _run(params, cfg,
                         ServeConfig(**base_kw, speculate_k=k), prompts)
        assert spec == plain, f"k={k}"
        s = eng.metrics_summary()
        assert s["spec_acceptance_rate"] == 1.0   # self-draft agrees always
        assert s["spec_accepted_per_step"] > 1.0


@pytest.mark.parametrize("base_kw", [PAGED, DENSE],
                         ids=["paged", "dense"])
def test_spec_identity_independent_draft(model, indep_draft, base_kw):
    """A draft that DISAGREES with the target exercises rejection and KV
    rollback on nearly every tick — and the greedy output still cannot
    change, because every committed token is the target's own argmax."""
    cfg, params = model
    prompts = _prompts(cfg, 4)
    plain, _ = _run(params, cfg, ServeConfig(**base_kw), prompts, max_new=10)
    spec, eng = _run(params, cfg, ServeConfig(**base_kw, speculate_k=3),
                     prompts, max_new=10, draft=indep_draft)
    assert spec == plain
    assert eng.metrics_summary()["spec_tokens_rejected"] > 0


def test_spec_identity_repacked_self_draft(model):
    """Self-speculation at a different registry format (the --draft-fmt
    path): the draft re-packs the target's raw weights."""
    cfg, params = model
    d = spec_mod.self_draft(params, cfg, fmt="tl1")
    assert d.label == "self:tl1"
    prompts = _prompts(cfg, 3)
    plain, _ = _run(params, cfg, ServeConfig(**PAGED), prompts)
    spec, _ = _run(params, cfg, ServeConfig(**PAGED, speculate_k=2),
                   prompts, draft=d)
    assert spec == plain


def test_ngram_propose():
    """Prompt-lookup proposal rule: most recent match wins, the
    continuation cycles periodically to fill all k columns, and thin
    history / no recurrence / k=0 return empty."""
    toks = [5, 1, 2, 9, 1, 2]
    # key (1,2) recurs at j=1; continuation [9,1,2] cycles to length 4
    assert spec_mod.ngram_propose(toks, 5, 4, 2) == [9, 1, 2, 9]
    # unigram: key (7,) recurs at j=0; continuation [3,7] cycles
    assert spec_mod.ngram_propose([7, 3, 7], 2, 3, 1) == [3, 7, 3]
    # most RECENT occurrence is preferred over an earlier one
    assert spec_mod.ngram_propose([4, 8, 4, 9, 4], 4, 2, 1) == [9, 4]
    assert spec_mod.ngram_propose([1, 2], 1, 3, 2) == []    # too short
    assert spec_mod.ngram_propose([1, 2, 3, 4], 3, 3, 2) == []  # no match
    assert spec_mod.ngram_propose(toks, 5, 0, 2) == []      # k = 0


@pytest.mark.parametrize("base_kw", [PAGED, DENSE],
                         ids=["paged", "dense"])
def test_spec_identity_lookup_draft(model, base_kw):
    """The model-free prompt-lookup draft: proposals from each slot's own
    history, no draft KV at all — greedy output still bit-identical, with
    real acceptances once the output self-repeats (greedy decode of the
    smoke model loops quickly)."""
    cfg, params = model
    prompts = _prompts(cfg, 4)
    plain, _ = _run(params, cfg, ServeConfig(**base_kw), prompts,
                    max_new=14)
    spec, eng = _run(params, cfg, ServeConfig(**base_kw, speculate_k=3),
                     prompts, max_new=14, draft=spec_mod.LookupDraft())
    assert spec == plain
    s = eng.metrics_summary()
    assert s["spec_draft"] == "ngram:2"
    assert s["spec_tokens_accepted"] > 0
    assert s["spec_accepted_per_step"] > 1.0
    # no draft pool exists: the runner is the degenerate no-op kind
    assert eng.spec.lookup and eng.spec.pcfg is None
    assert "draft_kv_blocks_free" not in s


def test_spec_identity_near_max_seq(model):
    """Horizon clamping: generation runs into max_seq, so n_extra shrinks to
    0 at the boundary and the finish condition fires exactly as non-spec."""
    cfg, params = model
    kw = dict(batch_slots=2, max_seq=16, paged=True, block_size=8,
              prefill_chunk=4)
    prompts = _prompts(cfg, 3)
    plain, _ = _run(params, cfg, ServeConfig(**kw), prompts, max_new=32)
    spec, _ = _run(params, cfg, ServeConfig(**kw, speculate_k=3), prompts,
                   max_new=32)
    assert spec == plain


def test_spec_sampled_slots_degrade(model):
    """temperature > 0 slots take the width-1 verify path (no speculation,
    no crash); greedy slots in the same batch still speculate."""
    cfg, params = model
    eng = ServeEngine(params, cfg, ServeConfig(**PAGED, speculate_k=2),
                      seed=0)
    eng.submit(Request(rid=0, prompt=[3, 5, 9, 4], max_new_tokens=6,
                       temperature=0.8))
    eng.submit(Request(rid=1, prompt=[2, 7, 1, 8], max_new_tokens=6))
    done = eng.run()
    assert sorted(r.rid for r in done) == [0, 1]
    assert all(len(r.out_tokens) == 6 for r in done)
    assert eng.metrics_summary()["spec_tokens_drafted"] > 0


# ---------------------------------------------------------------------------
# Rollback consistency: tables, refcounts, trie, defrag
# ---------------------------------------------------------------------------


def test_rollback_leaves_pools_consistent(model, indep_draft):
    cfg, params = model
    scfg = ServeConfig(batch_slots=2, max_seq=64, paged=True, block_size=8,
                       prefill_chunk=8, prefix_cache=True, speculate_k=3)
    # block-sized shared prefix so the trie actually holds blocks
    shared = list(range(2, 18))
    prompts = [shared + [30 + i] for i in range(4)]
    out, eng = _run(params, cfg, scfg, prompts, max_new=10,
                    draft=indep_draft)
    assert eng.metrics_summary()["spec_tokens_rejected"] > 0
    # every non-trie block drained back to the free list; trie blocks carry
    # exactly the index's reference (rejected-draft blocks were scrubbed
    # and freed, never published)
    assert (eng.allocator.free_count + eng.prefix.size
            == eng.pcfg.num_blocks)
    for blk in eng.prefix.blocks():
        assert eng.allocator.refcount(blk) == 1
    # the draft pool never shares: it must drain completely
    assert eng.spec.allocator.free_count == eng.spec.pcfg.num_blocks
    assert all(c == 0 for c in eng.spec.cursors)


def test_rollback_survives_defrag(model, indep_draft):
    cfg, params = model
    scfg = ServeConfig(batch_slots=2, max_seq=64, paged=True, block_size=8,
                       prefill_chunk=4)
    prompts = _prompts(cfg, 4)
    plain, _ = _run(params, cfg, ServeConfig(batch_slots=2, max_seq=64,
                                             paged=True, block_size=8,
                                             prefill_chunk=4), prompts,
                    max_new=10)
    eng = ServeEngine(params, cfg, dataclasses.replace(scfg, speculate_k=3),
                      seed=0, draft=indep_draft)
    for rid, p in enumerate(prompts):
        eng.submit(Request(rid=rid, prompt=p, max_new_tokens=10))
    done, i = [], 0
    while eng.sched.pending or any(s is not None for s in eng.slots):
        done.extend(eng.step())
        i += 1
        if i % 3 == 0:
            eng.defrag()  # compacts BOTH pools mid-flight
    assert {r.rid: list(r.out_tokens) for r in done} == plain


def test_release_tail_guards_shared_blocks():
    from repro.serve.kvcache import BlockAllocator, PagedKVConfig
    pcfg = PagedKVConfig(num_blocks=8, block_size=4, max_blocks_per_seq=8)
    alloc = BlockAllocator(pcfg)
    got = alloc.alloc(1, 3)
    alloc.ref_inc(got[2])  # simulate an (illegal) share of the tail
    with pytest.raises(RuntimeError, match="refcount"):
        alloc.release_tail(1, 1)
    assert alloc.release_tail(1, 2) == []  # nothing freed: tail was shared
    alloc.ref_dec(got[2])


# ---------------------------------------------------------------------------
# k=0 is the plain engine, trace-for-trace
# ---------------------------------------------------------------------------


def test_k0_disables_trace_for_trace(model):
    cfg, params = model
    prompts = _prompts(cfg, 3)
    plain, _ = _run(params, cfg, ServeConfig(**PAGED), prompts)
    mark = dispatch.decision_count()
    k0, eng = _run(params, cfg, ServeConfig(**PAGED, speculate_k=0), prompts)
    assert k0 == plain
    assert eng.spec is None
    # zero NEW dispatch decisions: the k=0 engine reuses the plain engine's
    # cached executables — the very same traces, not equivalent ones
    assert dispatch.decisions_since(mark) == ()
    assert "spec_steps" not in eng.metrics_summary()


# ---------------------------------------------------------------------------
# Guard rails
# ---------------------------------------------------------------------------


def test_guard_act_tensor_refused(model):
    cfg, params = model
    tcfg = _cfg(quant=QuantConfig(mode="quant", fmt="i2s", act="tensor"))
    with pytest.raises(ValueError, match="TENSOR"):
        ServeEngine(params, tcfg, ServeConfig(**PAGED, speculate_k=2))


def test_guard_recurrent_refused():
    cfg = configs.smoke("recurrentgemma-2b").replace(
        dtype="float32", quant=QuantConfig(mode="quant", fmt="i2s",
                                           act="token"))
    params = lm.init(KEY, cfg)
    with pytest.raises(ValueError, match="recurrent"):
        ServeEngine(params, cfg, ServeConfig(batch_slots=2, max_seq=32,
                                             speculate_k=2))


def test_guard_draft_without_k(model, indep_draft):
    cfg, params = model
    with pytest.raises(ValueError, match="speculate_k"):
        ServeEngine(params, cfg, ServeConfig(**PAGED), draft=indep_draft)


# ---------------------------------------------------------------------------
# Regime: the verify batch rides GEMM at exactly N = B·(k+1)
# ---------------------------------------------------------------------------


def test_verify_batch_dispatches_gemm(model):
    cfg, params = model
    k, slots = 3, 3
    # a (fmt, shape) pair no other test traces, so the verify trace of THIS
    # engine actually re-dispatches (jitted executables are lru-shared per
    # config across engines — an already-traced shape records nothing)
    vcfg = _cfg(quant=QuantConfig(mode="quant", fmt="tq1", act="token"))
    prompts = _prompts(vcfg, 3)
    _, eng = _run(params, vcfg, ServeConfig(batch_slots=slots, max_seq=64,
                                            paged=True, block_size=8,
                                            prefill_chunk=4, speculate_k=k),
                  prompts)
    ns = {(d.regime, d.n) for d in eng.kernel_decisions()}
    assert ("gemm", slots * (k + 1)) in ns, ns


# ---------------------------------------------------------------------------
# Draft-aware admission
# ---------------------------------------------------------------------------


def test_admissible_checks_draft_pool(model):
    from repro.serve.kvcache import PagedKVConfig
    pcfg = PagedKVConfig(num_blocks=16, block_size=4, max_blocks_per_seq=16)
    sub = Submission(req=Request(rid=0, prompt=list(range(10))))
    assert AdmissionScheduler.admissible(sub, 16, pcfg)
    assert AdmissionScheduler.admissible(sub, 16, pcfg,
                                         draft_free_blocks=16,
                                         draft_pcfg=pcfg)
    # a dry DRAFT pool refuses admission even when the target pool has room
    assert not AdmissionScheduler.admissible(sub, 16, pcfg,
                                             draft_free_blocks=0,
                                             draft_pcfg=pcfg)
    # dense target + paged draft accounting still gates on the draft side
    assert not AdmissionScheduler.admissible(sub, None, None,
                                             draft_free_blocks=1,
                                             draft_pcfg=pcfg)
