"""Demonstrates the lossless-inference claim END TO END across formats and
the block-fitting weight split, on a model with K dims that are NOT
multiples of 3 (the paper's §3.1.2 case), plus a mini fault-injection drill
of the training runner.

    PYTHONPATH=src python examples/multi_pod_lossless.py
"""

import tempfile

import jax
import jax.numpy as jnp

from repro import configs
from repro.core.bitlinear import QuantConfig
from repro.data.pipeline import DataConfig, DataIterator
from repro.distributed import fault
from repro.models import lm
from repro.train import loop as train_loop


def main():
    # gemma3 family: d_ff=288 smoke -> tl2k needs the tl1 tail (288 % 768 != 0)
    cfg = configs.smoke("gemma3-4b").replace(dtype="float32")
    params = lm.init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    c_qat = cfg.replace(quant=QuantConfig(mode="qat"))
    ref, _ = lm.forward(params, {"tokens": toks, "labels": toks}, c_qat)
    c = cfg.replace(quant=QuantConfig(mode="quant", fmt="tl2k"))
    got, _ = lm.forward(lm.pack(params, c), {"tokens": toks, "labels": toks}, c)
    print(f"gemma3 tl2k (block-fitting split) vs QAT: max err "
          f"{float(jnp.abs(got - ref).max()):.2e}")

    # fault drill: inject 2 failures, verify the run completes with restarts
    tcfg = train_loop.TrainConfig()
    dc = DataConfig(vocab=cfg.vocab, seq_len=16, global_batch=4)
    step = jax.jit(train_loop.make_train_step(cfg.replace(quant=QuantConfig(mode="qat")), tcfg))
    state = train_loop.init_train_state(jax.random.PRNGKey(0), cfg, tcfg)
    with tempfile.TemporaryDirectory() as d:
        runner = fault.ResilientRunner(step, d, ckpt_every=3,
                                       fault_hook=fault.FaultInjector({4, 9}),
                                       async_save=False)
        state, hist = runner.run(state, DataIterator(dc), 10)
    print(f"fault drill: 10 steps completed with {runner.restarts} restarts; "
          f"final loss {hist[-1]['loss']:.3f}")


if __name__ == "__main__":
    main()
