"""End-to-end serving driver: batched requests through the continuous-
batching engine, comparing the paper's kernel formats (the paper's kind of
system — inference — so serving is the e2e path).

    PYTHONPATH=src python examples/serve_ternary.py
"""

import time

import jax
import numpy as np

from repro import configs
from repro.core import dispatch
from repro.core.bitlinear import QuantConfig
from repro.core.dispatch import KernelPlan
from repro.infer.engine import Engine, Request
from repro.models import lm
from repro.serve import ServeConfig, ServeEngine


def main():
    base = configs.smoke("qwen1.5-0.5b").replace(dtype="float32")
    params = lm.init(jax.random.PRNGKey(0), base)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, base.vocab, size=rng.integers(3, 9)).tolist()
               for _ in range(6)]

    # (fmt, KernelPlan): auto lets the registry pick per regime; the tl1
    # entries pin the paper's LUT computation model (TL1_1 / TL1_0).
    variants = (
        ("fp", KernelPlan()),
        ("i2s", KernelPlan()),
        ("tl2k", KernelPlan()),
        ("tl1_lossless", dispatch.lut_plan("tl1", lossless=True)),
        ("tl1_lossy", dispatch.lut_plan("tl1", lossless=False)),
    )
    results = {}
    for name, plan in variants:
        fmt = name.split("_")[0]
        cfg = base.replace(quant=QuantConfig(
            mode="quant" if fmt != "fp" else "fp",
            fmt=fmt if fmt != "fp" else "i2s", plan=plan))
        eng = Engine(params, cfg, batch_slots=3, max_seq=96,
                     pack=(fmt != "fp"))
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=p, max_new_tokens=12))
        t0 = time.perf_counter()
        done = eng.run()
        dt = time.perf_counter() - t0
        toks = sum(len(r.out_tokens) for r in done)
        results[name] = [r.out_tokens for r in sorted(done, key=lambda r: r.rid)]
        print(f"{name:14s}: {toks} tokens in {dt:5.2f}s ({toks/dt:6.1f} tok/s CPU)")

    same = results["i2s"] == results["tl2k"] == results["tl1_lossless"]
    print("lossless formats generate identically:", same)

    # the serving subsystem (DESIGN.md §7): paged KV + BATCHED concurrent
    # prefill (prefill_budget = slots · chunk → one [3, 8] call per tick at
    # mpGEMM N = 24) + admission scheduling, same tokens as the dense
    # engine in the composition-invariant act="token" quant mode.
    cfg = base.replace(quant=QuantConfig(mode="quant", fmt="i2s", act="token"))
    dense = Engine(params, cfg, batch_slots=3, max_seq=96)
    srv = ServeEngine(params, cfg, ServeConfig(
        batch_slots=3, max_seq=96, paged=True, block_size=16,
        prefill_chunk=8, prefill_budget=24))
    for i, p in enumerate(prompts):
        dense.submit(Request(rid=i, prompt=p, max_new_tokens=12))
        srv.submit(Request(rid=i, prompt=p, max_new_tokens=12),
                   priority=i % 2)
    ref = {r.rid: r.out_tokens for r in dense.run()}
    t0 = time.perf_counter()
    got = {r.rid: r.out_tokens for r in srv.run()}
    s = srv.metrics_summary()
    print(f"paged+batched : {s['generated_tokens']} tokens in "
          f"{time.perf_counter() - t0:5.2f}s, ttft p95 {s['ttft_p95']:.2f}s, "
          f"matches dense: {got == ref}")


if __name__ == "__main__":
    main()
