"""Quickstart: train a tiny ternary LM with the b1.58 QAT scheme, pack it to
the paper's sub-2-bpw formats, verify losslessness, and generate.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core.bitlinear import QuantConfig
from repro.data.pipeline import DataConfig, DataIterator
from repro.infer.engine import generate
from repro.models import lm
from repro.train import loop as train_loop


def main():
    # 1. QAT-train a reduced qwen-family model (absmean ternary weights +
    #    per-tensor int8 activations -> the BitNet b1.58 training scheme).
    cfg = configs.smoke("qwen1.5-0.5b").replace(dtype="float32")
    tcfg = train_loop.TrainConfig(
        opt=train_loop.opt.OptConfig(lr=3e-3, warmup_steps=5, total_steps=60))
    dc = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=8)
    state, hist = train_loop.train(cfg, tcfg, DataIterator(dc), n_steps=40)
    print(f"QAT loss: {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}")

    # 2. Pack to each mpGEMM format and check LOSSLESS inference (Figure 2).
    toks = next(DataIterator(dc))["tokens"][:2]
    qat_logits, _ = lm.forward(state["params"], {"tokens": toks, "labels": toks}, cfg)
    for fmt, bpw in (("i2s", 2.0), ("tl1", 2.0), ("tl2k", 1.67)):
        c = cfg.replace(quant=QuantConfig(mode="quant", fmt=fmt))
        packed = lm.pack(state["params"], c)
        got, _ = lm.forward(packed, {"tokens": toks, "labels": toks}, c)
        err = float(jnp.abs(got - qat_logits).max())
        print(f"  {fmt:5s} ({bpw} bpw): max |logit delta| vs QAT forward = {err:.2e}")

    # 3. Serve: continuous-batching greedy generation from the packed model.
    c = cfg.replace(quant=QuantConfig(mode="quant", fmt="i2s"))
    outs = generate(lm.pack(state["params"], c), c,
                    [[1, 8, 15], [2, 9, 16, 23]], max_new_tokens=8, max_seq=64)
    print("generations:", outs)


if __name__ == "__main__":
    main()
